"""Shared intent-benchmark machinery: run a corpus through an orchestrator
and aggregate the paper's four metrics (success, checks/task, completion
time, tokens/task)."""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.core import CORPUS, DeterministicInterpreter, Orchestrator, satisfies


def run_corpus(interpreter=None, entries=None, stabilization_s: float = 0.0
               ) -> List[Dict]:
    """Returns one record per intent: domain/complexity/success/checks/
    time/tokens. Success uses the benchmark (gold-assertion) criterion,
    exactly like the paper's validator."""
    orch = Orchestrator(interpreter=interpreter,
                        stabilization_s=stabilization_s)
    gold_parser = DeterministicInterpreter()
    out = []
    for e in (entries or CORPUS):
        t0 = time.time()
        r = orch.submit(e.text)
        wall = time.time() - t0
        if r.success:
            gold = gold_parser.interpret(e.text, orch.fabric,
                                         orch.components).intent
            ok, _ = satisfies(gold, r.policy.config, orch.fabric,
                              orch.components)
            outcome = "enforce" if ok else "fail-open-detected"
        else:
            outcome = "fail-closed"
        success = outcome == ("enforce" if e.expect == "enforce"
                              else "fail-closed")
        out.append({
            "domain": e.domain,
            "complexity": e.complexity,
            "success": success,
            "checks": r.report.n_checks,
            "time_s": wall,
            "tokens": r.prompt_tokens + r.completion_tokens,
        })
    return out


def aggregate(records: Sequence[Dict], key: Optional[str] = None) -> Dict:
    def agg(rs):
        n = max(len(rs), 1)
        return {
            "n": len(rs),
            "success_rate": 100.0 * sum(r["success"] for r in rs) / n,
            "avg_checks": sum(r["checks"] for r in rs) / n,
            "avg_time_s": sum(r["time_s"] for r in rs) / n,
            "avg_tokens": sum(r["tokens"] for r in rs) / n,
        }

    if key is None:
        return {"overall": agg(records)}
    groups: Dict[str, list] = {}
    for r in records:
        groups.setdefault(r[key], []).append(r)
    return {k: agg(v) for k, v in sorted(groups.items())}
