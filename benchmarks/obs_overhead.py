"""Flight-recorder overhead benchmark: recording must be ~free.

    PYTHONPATH=src:. python benchmarks/obs_overhead.py

Three measured facts, asserted as the contract:

  1. **Recording overhead < 2% of the replay loop.** Run-to-run wall
     noise on a shared single-vCPU box exceeds 20% for *identical*
     unrecorded replays (we measured it), so an end-to-end on/off wall
     comparison cannot resolve a 2% budget — it would be a coin flip.
     Instead the overhead is attributed mechanistically: a tight
     microbenchmark times the recorder's actual hot-path operations
     (a ``request.complete`` emit — the most expensive kind: bus ring
     + counter + two quantile-sketch folds — and a ``route`` span),
     the recorded replay reports exactly how many of each it performed
     (``rec.bus.emitted`` / ``rec.trace.added``), and the attributed
     cost — times a 2x cold-cache safety factor — must stay under
     ``OVERHEAD_BUDGET`` of the replay loop's wall time. The raw
     end-to-end on/off walls are still reported for the artifact.
  2. **Recording never perturbs the simulation.** Every run, recorded
     or not, must report bit-identical simulated duration and
     completion counts (the recorder timestamps with non-advancing
     clock reads) — so the only possible cost IS the attributed one.
  3. **The exported trace is Perfetto-loadable.** `validate_chrome`
     checks the Chrome ``trace_event`` schema of the recorded run's
     export; the artifact records the event/span counts.

Emits ``name,value,derived`` CSV rows and returns the artifact dict
(`run.py` writes it to BENCH_obs.json, mirrored at the repo root).
"""
from __future__ import annotations

import os
import time as wall

SEED = 11
#: attributed recorder share of the replay loop (fraction). The
#: recorder's work per request is a few dict allocations + ring stores;
#: 2% of a replay whose per-step cost is real decode math is generous.
OVERHEAD_BUDGET = 0.02
#: cold-cache margin on the microbenchmarked per-op cost: in-situ calls
#: miss caches a warm timing loop hits
SAFETY_FACTOR = 2.0
#: microbenchmark iterations per op
MICRO_N = 20_000


def _per_op_costs() -> dict:
    """Seconds per recorder hot-path operation, measured warm."""
    from repro.obs import Recorder

    rec = Recorder(capacity=MICRO_N + 1, trace_capacity=MICRO_N + 1)
    t0 = wall.perf_counter()
    for i in range(MICRO_N):
        rec.emit("request.complete", engine="e0", rid=i, label="phi",
                 ttft_s=0.1, tpot_s=0.01, tokens_out=8)
    emit_s = (wall.perf_counter() - t0) / MICRO_N

    t0 = wall.perf_counter()
    for i in range(MICRO_N):
        with rec.span("route", track="cluster", rid=i) as args:
            args["engine"] = "e0"
    span_s = (wall.perf_counter() - t0) / MICRO_N
    return {"emit_s": emit_s, "span_s": span_s}


def bench_obs_overhead(emit=None) -> dict:
    import json
    import tempfile

    from repro.obs import Recorder, SLOLedger, validate_chrome
    from repro.traffic.replay import recorded_replay

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    n_requests = int(os.environ.get("OBS_REQUESTS", "1000"))
    repeats = max(1, int(os.environ.get("OBS_REPEATS", "2")))

    def run(recorder):
        timings = {}
        stats, rec, planner = recorded_replay(
            n_requests, seed=SEED, recorder=recorder, timings=timings)
        return stats, rec, planner, timings["replay_wall_s"]

    # warm-up: the first run pays one-time process costs (imports,
    # BLAS/thread-pool spin-up) that would otherwise land on one mode
    run(False)
    walls_off, walls_on = [], []
    stats0 = rec = planner = None
    for _ in range(repeats):                     # interleaved: drift-fair
        stats_off, _, _, w_off = run(False)      # recording disabled
        stats_on, rec, planner, w_on = run(Recorder())
        walls_off.append(w_off)
        walls_on.append(w_on)
        if stats0 is None:
            stats0 = stats_off
        # recording never advances the simulated clock: every run, on
        # or off, reproduces the identical simulated results
        for s in (stats_off, stats_on):
            assert s.completed == stats0.completed, (s, stats0)
            assert s.duration_s == stats0.duration_s, (s, stats0)
            assert s.dropped == stats0.dropped == 0

    wall_off, wall_on = min(walls_off), min(walls_on)
    costs = _per_op_costs()
    attributed_s = SAFETY_FACTOR * (rec.bus.emitted * costs["emit_s"]
                                    + rec.trace.added * costs["span_s"])
    overhead = attributed_s / wall_on

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "replay.trace.json")
        rec.export_chrome(path)
        doc = json.loads(open(path).read())
    n_trace_events = validate_chrome(doc)

    ledger = SLOLedger.from_policy(planner).consume(rec.events())

    contract = {
        "overhead_under_budget": overhead < OVERHEAD_BUDGET,
        "trace_valid": n_trace_events > 0,
        "identical_sim_results": True,           # asserted every run above
        "no_event_drops": rec.bus.dropped == 0,
    }
    assert contract["overhead_under_budget"], (
        f"attributed recording overhead {overhead:.2%} >= "
        f"{OVERHEAD_BUDGET:.0%} ({rec.bus.emitted} events x "
        f"{costs['emit_s'] * 1e6:.2f}us + {rec.trace.added} spans x "
        f"{costs['span_s'] * 1e6:.2f}us, x{SAFETY_FACTOR:g} margin, "
        f"over a {wall_on:.2f}s replay loop)")
    assert contract["trace_valid"]

    emit("obs_requests", stats0.completed)
    emit("obs_replay_wall_off_s", round(wall_off, 3),
         f"replay loop only, recorder off, min of {repeats}")
    emit("obs_replay_wall_on_s", round(wall_on, 3),
         f"replay loop only, recorder on, min of {repeats}")
    emit("obs_emit_cost_us", round(costs["emit_s"] * 1e6, 3),
         "per request.complete emit (bus + counter + 2 sketches)")
    emit("obs_span_cost_us", round(costs["span_s"] * 1e6, 3),
         "per route span")
    emit("obs_attributed_overhead_pct", round(100 * overhead, 3),
         f"contract: < {100 * OVERHEAD_BUDGET:.0f} "
         f"(x{SAFETY_FACTOR:g} cold-cache margin)")
    emit("obs_events_recorded", rec.bus.emitted)
    emit("obs_events_dropped", rec.bus.dropped, "contract: 0")
    emit("obs_spans_recorded", rec.trace.added)
    emit("obs_trace_events", n_trace_events, "Perfetto-loadable")
    emit("obs_slo_attainment_overall",
         round(ledger.attainment_overall(), 4)
         if ledger.attainment_overall() is not None else "n/a",
         "from the event stream (SLOLedger)")

    return {
        "seed": SEED,
        "requests": stats0.completed,
        "repeats": repeats,
        "replay_wall_off_s": wall_off,
        "replay_wall_on_s": wall_on,
        "replay_walls_off_s": walls_off,
        "replay_walls_on_s": walls_on,
        "emit_cost_us": costs["emit_s"] * 1e6,
        "span_cost_us": costs["span_s"] * 1e6,
        "attributed_overhead_pct": 100 * overhead,
        "overhead_budget_pct": 100 * OVERHEAD_BUDGET,
        "safety_factor": SAFETY_FACTOR,
        "events_recorded": rec.bus.emitted,
        "events_dropped": rec.bus.dropped,
        "spans_recorded": rec.trace.added,
        "spans_dropped": rec.trace.dropped,
        "trace_events": n_trace_events,
        "slo_attainment": dict(ledger.attainment(),
                               overall=ledger.attainment_overall()),
        "pauses": ledger.pause_accounting(),
        "contract": contract,
    }


if __name__ == "__main__":
    bench_obs_overhead()
