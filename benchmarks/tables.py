"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from the
dry-run JSON records.

    PYTHONPATH=src python -m benchmarks.tables [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def load(d: Path):
    recs = {}
    for f in sorted(d.glob("*.json")):
        r = json.loads(f.read_text())
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_table(recs, mesh: str) -> str:
    rows = ["| arch | shape | compile_s | peak GiB | fits 16GiB | HLO TFLOP/dev | HLO GB/dev | wire GB/dev (ici/dcn) |",
            "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or r.get("status") != "ok":
            continue
        c = r["collectives"]
        rows.append(
            f"| {arch} | {shape} | {r['compile_s']} | "
            f"{fmt_bytes(r['memory']['peak_bytes'])} | "
            f"{'Y' if r['memory']['fits'] else 'N'} | "
            f"{r['cost']['hlo_flops_per_device']/1e12:.2f} | "
            f"{r['cost']['hlo_bytes_per_device']/1e9:.1f} | "
            f"{c.get('ici_bytes', c['wire_bytes_per_device'])/1e9:.2f}"
            f"/{c.get('dcn_bytes', 0)/1e9:.2f} |")
    return "\n".join(rows)


def roofline_table(recs, mesh: str = "16x16") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | bottleneck | MODEL_FLOPS | useful ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh or r.get("status") != "ok":
            continue
        rf = r["roofline"]
        rows.append(
            f"| {arch} | {shape} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['bottleneck'].replace('_s','')} | "
            f"{rf['model_flops']:.2e} | {rf['useful_flops_ratio']:.2f} | "
            f"{rf['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--kind", default="roofline",
                    choices=("roofline", "dryrun"))
    args = ap.parse_args()
    recs = load(Path(args.dir))
    if args.kind == "roofline":
        print(roofline_table(recs, args.mesh))
    else:
        print(dryrun_table(recs, args.mesh))


if __name__ == "__main__":
    main()
