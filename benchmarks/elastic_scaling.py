"""Elastic-scaling benchmark: replay a bursty two-label trace against the
autoscaled `ServingCluster` and report downtime, TTFT/TPOT overhead, and
the engine-count trajectory.

    PYTHONPATH=src:. python benchmarks/elastic_scaling.py

Trace shape (virtual ticks): `general` arrives at a steady trickle for the
whole run; `phi` bursts hard in the middle. The autoscaler must

  * spawn >= 1 dedicated engine for the hot `phi` label (through the
    PREPARE-phase AOT path — spawns never JIT on the serving path),
  * retire the extra capacity after the burst, strictly after drain,
  * finalize every scale event's `DowntimeReport`,
  * never route a request to a draining engine (asserted per submission).

Emitted ``name,value,derived`` CSV rows:

  elastic_spawns / elastic_retires / elastic_rebalances
  elastic_peak_engines, elastic_final_engines
  elastic_spawn_prepare_s_mean    background AOT compile per spawn
  elastic_spawn_install_s_max     spawn install window (not serving downtime)
  elastic_swap_downtime_s_max     worst blocking window of any swap event
  elastic_retire_downtime_s_max   always 0 — draining never blocks
  elastic_<label>_ttft_mean_s / _tpot_mean_s
  elastic_trajectory              engine count per tick (|-joined)
"""
from __future__ import annotations

import dataclasses


def bench_elastic_scaling(arch: str = "minitron_4b", ticks: int = 20,
                          burst: range = range(4, 11), burst_rate: int = 8,
                          steady_rate: int = 1, emit=None) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import (
        Autoscaler,
        ElasticPolicy,
        LoadTracker,
        Request,
        ServingCluster,
        ServingEngine,
    )

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    def factory(label: str) -> ServingEngine:
        return ServingEngine(model, params, n_slots=2, s_max=32)

    cluster = ServingCluster()
    cluster.register("base0", factory("*"))
    scaler = Autoscaler(
        cluster, factory,
        policy=ElasticPolicy(spawn_depth=3.0, retire_rate=0.25, sustain=2,
                             cooldown=2, default_bounds=(0, 4),
                             prefer_rebalance=False),
        tracker=LoadTracker(alpha=0.5))
    rng = np.random.default_rng(0)
    rid = 0

    def submit(label: str) -> None:
        nonlocal rid
        draining = set(cluster.draining())
        name = cluster.submit(Request(
            rid, rng.integers(2, cfg.vocab_size, size=6).astype(np.int32),
            max_new_tokens=4, labels={"data-type": label}))
        assert name not in draining, \
            f"request {rid} routed to draining engine {name}"
        rid += 1

    # ---- replay the bursty two-label trace ----
    for t in range(ticks):
        for _ in range(steady_rate):
            submit("general")
        if t in burst:
            for _ in range(burst_rate):
                submit("phi")
        scaler.tick()
        cluster.step()
        cluster.step()
    cluster.run()
    # quiet tail: the autoscaler sees the cold labels and scales back down
    for _ in range(8):
        scaler.tick()
        cluster.run()

    # ---- acceptance checks (the ISSUE's criteria, enforced here) ----
    spawns = [(d, r) for d, r in scaler.events if d.kind == "spawn"]
    retires = [(d, r) for d, r in scaler.events if d.kind == "retire"]
    rebalances = [(d, r) for d, r in scaler.events if d.kind == "rebalance"]
    assert any(d.label == "phi" for d, _ in spawns), \
        "autoscaler never spawned for the hot phi label"
    assert any(d.label == "phi" for d, _ in retires), \
        "autoscaler never retired the phi burst capacity"
    assert cluster.pending_reports() == [], \
        f"unfinalized DowntimeReports: {cluster.pending_reports()}"
    by_label = cluster.metrics_by_label()
    total_arrived = sum(cluster.arrivals().values())
    assert cluster.metrics()["completed"] == total_arrived, \
        "requests were lost across scale events"

    trajectory = [snap["total"] for snap in scaler.trajectory]
    emit("elastic_spawns", len(spawns), "scale-ups for hot labels")
    emit("elastic_retires", len(retires), "drained scale-downs")
    emit("elastic_rebalances", len(rebalances), "resizes beating cold spawns")
    emit("elastic_peak_engines", max(trajectory))
    emit("elastic_final_engines", trajectory[-1],
         "back to steady-state size after the burst")
    emit("elastic_spawn_prepare_s_mean",
         round(float(np.mean([r.prepare_s for _, r in spawns])), 4),
         "background AOT compile (serving continues)")
    emit("elastic_spawn_install_s_max",
         round(max(r.downtime_s for _, r in spawns), 4),
         "spawn install window (new engine only — cluster keeps serving)")
    swap_windows = [r.downtime_s for _, r in rebalances] or [0.0]
    emit("elastic_swap_downtime_s_max", round(max(swap_windows), 4),
         "worst blocking swap window (paper target <0.05)")
    emit("elastic_retire_downtime_s_max",
         round(max(r.downtime_s for _, r in retires), 4),
         "drain-mode retires never block (0); migrate-mode pays the "
         "relocation window (see live_migration.py)")
    for label in ("general", "phi"):
        m = by_label[label]
        emit(f"elastic_{label}_completed", int(m["completed"]))
        emit(f"elastic_{label}_ttft_mean_s", round(m["ttft_mean_s"], 4))
        emit(f"elastic_{label}_tpot_mean_s", round(m["tpot_mean_s"], 4))
    emit("elastic_trajectory", "|".join(map(str, trajectory)),
         "registered engines per tick")
    return {"scaler": scaler, "cluster": cluster, "trajectory": trajectory,
            "by_label": by_label}


if __name__ == "__main__":
    bench_elastic_scaling()
