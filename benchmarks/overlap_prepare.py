"""Overlapped-PREPARE benchmark: background AOT compilation must overlap
with serving instead of adding to the wall clock.

    PYTHONPATH=src:. python benchmarks/overlap_prepare.py

The contract (ISSUE-4 acceptance bar), asserted here:

  * wall clock of (serve trace + CONCURRENT reconfigure) is strictly
    below (serve trace) + (inline PREPARE cost) — compilation overlaps
    serving rather than serializing with it;
  * decode throughput while the swap is PREPARING stays within 10% of
    the host's *concurrent-serving capacity* (see below; OVERLAP_TOL
    overrides);
  * the committed swap's blocking window stays under the 50 ms budget
    (DOWNTIME_BUDGET_S overrides);
  * no request is ever routed to the engine mid-swap.

Compile isolation. A JAX compile is GIL-hostile: tracing/lowering holds
the GIL through long C++ calls, so an in-process background compile can
strangle a CPU-bound serving loop no matter how many cores exist. On
accelerator fabrics this does not matter (decode runs on the device,
compilation on host CPU), but this CPU harness demonstrates the
production pattern explicitly: the PREPARE's `warm` hook compiles the
same modules in a SUBPROCESS against JAX's persistent compilation cache,
after which the in-process compile — the part that must hold the GIL —
is a cheap cache hit. This is the serverless-LLM cold-start lever
(arXiv 2411.15664): move compile/load cost out of the serving process's
critical path.

Calibration. The throughput criterion is judged against the host's
CONCURRENT-SERVING CAPACITY: steady-state throughput measured while an
IDENTICAL compile workload runs fully out of process (throwaway cache,
disjoint shapes — perfectly isolated from serving). On a machine with a
true spare core this equals steady state and the criterion is the
verbatim "within 10% of steady"; on a starved/shared container (this
harness's CI box advertises 2 vCPUs but sustains only ~1.4 cores of
parallel work) it is the throughput ANY fully-isolated PREPARE would
permit — the honest yardstick for whether *the overlap machinery*
(rather than the hypervisor) is stealing serving cycles. Both numbers
land in the artifact (``parallel_headroom`` = capacity / steady).

Emits ``name,value,derived`` CSV rows and returns the JSON-able dict CI
writes to ``benchmarks/BENCH_overlap.json``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import threading
import time

# The COMPILE SERVER: a resident child process that pays the jax import +
# model build once at startup (amortized across every swap, like a
# production compile daemon), then compiles the modules of each request
# line — the same modules `ServingEngine.aot_executables` will lower
# (identical ShapeDtypeStructs and shardings -> identical
# persistent-cache keys), so the parent's in-process compile becomes a
# cache hit. Protocol: prints "ready" after boot, then one "done" line
# per JSON request line on stdin.
_WARM_SERVER = r'''
import json, sys
boot = json.loads(sys.argv[1])
import jax
jax.config.update("jax_compilation_cache_dir", boot["cache_dir"])
jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
import dataclasses
import numpy as np
import jax.numpy as jnp
from repro.configs import get_reduced_config
from repro.models import build_model
from repro.sharding import ShardingPlan, plan_to_shardings

cfg = dataclasses.replace(get_reduced_config(boot["arch"]),
                          param_dtype="float32", activ_dtype="float32")
model = build_model(cfg)
mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1, 1),
                         ("pod", "data", "model"))
n_slots, s_max = boot["n_slots"], boot["s_max"]
sds = jax.ShapeDtypeStruct
p_shapes = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
c_shapes = jax.eval_shape(lambda: model.init_cache(n_slots, s_max))

def batch_sds(S, padded):
    b = {"tokens": sds((1, S), jnp.int32)}
    if padded:
        b["true_len"] = sds((), jnp.int32)
    if cfg.pos_type == "mrope":
        b["positions"] = sds((3, 1, S), jnp.int32)
    return b

print("ready", flush=True)
for line in sys.stdin:
    req = json.loads(line)
    plan = ShardingPlan(
        device_constraints=tuple(tuple(p) for p in req["pins"]),
        forbidden_collective_axes=tuple(req["forbidden"]))
    sh = plan_to_shardings(cfg, plan, mesh, n_slots=n_slots)
    p_sds = jax.tree.map(lambda x, s: sds(x.shape, x.dtype, sharding=s),
                         p_shapes, sh["params"])
    c_sds = jax.tree.map(lambda x, s: sds(x.shape, x.dtype, sharding=s),
                         c_shapes, sh["cache"])
    jax.jit(model.decode_step, donate_argnums=(2,)).lower(
        p_sds, sds((n_slots, 1), jnp.int32), c_sds,
        sds((n_slots,), jnp.int32)).compile()
    for S in req["prefill_lengths"]:
        jax.jit(model.prefill).lower(p_sds, batch_sds(S, False)).compile()
    for S in req["bucket_lengths"]:
        jax.jit(model.prefill).lower(p_sds, batch_sds(S, True)).compile()
    print("done", flush=True)
'''


class _WarmServer:
    """Handle on one resident compile-server child process."""

    def __init__(self, arch, n_slots, s_max, cache_dir, env):
        boot = json.dumps({"arch": arch, "n_slots": n_slots,
                           "s_max": s_max, "cache_dir": cache_dir})
        self.proc = subprocess.Popen(
            [sys.executable, "-c", _WARM_SERVER, boot], env=env,
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True,
            bufsize=1)
        assert self.proc.stdout.readline().strip() == "ready", \
            "compile server failed to boot"

    def request(self, prefill_lengths, bucket_lengths=(), pins=(),
                forbidden=()):
        """Ask the server to compile one module set; blocks until done
        (call from a worker thread to overlap with serving)."""
        self.proc.stdin.write(json.dumps({
            "prefill_lengths": list(prefill_lengths),
            "bucket_lengths": list(bucket_lengths),
            "pins": [list(p) for p in pins],
            "forbidden": list(forbidden)}) + "\n")
        reply = self.proc.stdout.readline().strip()
        assert reply == "done", f"compile server died mid-request: {reply!r}"

    def stop(self):
        self.proc.stdin.close()
        self.proc.wait()


def _enable_compile_cache(cache_dir: str) -> None:
    import jax
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    try:
        # the cache singleton latches on first use: when another benchmark
        # already compiled in this process, config alone is a no-op and
        # the warm subprocess' entries would never be read — force re-init
        from jax._src.compilation_cache import reset_cache
        reset_cache()
    except ImportError:                    # private API moved: standalone
        pass                               # runs still work (cache set
                                           # before the first compile)


def bench_overlap_prepare(arch: str = "minitron_4b",
                          max_new_tokens: int = 32, emit=None) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import Request, ServingCluster, ServingEngine
    from repro.sharding import ShardingPlan, default_plan

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    budget_s = float(os.environ.get("DOWNTIME_BUDGET_S", "0.05"))
    tol = float(os.environ.get("OVERLAP_TOL", "0.10"))
    cache_dir = tempfile.mkdtemp(prefix="bench_overlap_jaxcache_")
    _enable_compile_cache(cache_dir)

    n_slots, s_max = 16, 48
    lengths = (5, 6, 7, 8, 9, 10, 11, 12)  # the live traffic shapes
    # the overlapped PREPARE compiles len(lengths) exact prefills + the
    # 4-step padded-bucket ladder (8/16/32/48) + decode; the inline
    # baseline compiles an equal COUNT of disjoint cold prefills, so the
    # two phases do comparable compile work (the persistent cache makes
    # repeated identical modules nearly free — only cold work compares)
    inline_lengths = tuple(range(13, 25))  # 12 disjoint cold modules

    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    cluster = ServingCluster()
    cluster.register("e0", ServingEngine(model, params, n_slots=n_slots,
                                         s_max=s_max))
    rng = np.random.default_rng(0)
    rid_seq = [0]

    def load(n):
        for _ in range(n):
            S = lengths[rid_seq[0] % len(lengths)]
            cluster.submit(Request(
                rid_seq[0],
                rng.integers(2, cfg.vocab_size, size=S).astype(np.int32),
                max_new_tokens=max_new_tokens,
                labels={"data-type": "phi"}))
            rid_seq[0] += 1

    def serve(track_ticket=None):
        """Drain the cluster; returns (wall_s, tokens, during_tokens,
        during_s) with the ``during_*`` pair covering decode steps taken
        while ``track_ticket`` was still PREPARING."""
        tokens = during_tokens = 0
        during_s = 0.0
        t0 = time.perf_counter()
        while True:
            preparing = (track_ticket is not None
                         and track_ticket.state == "preparing")
            s0 = time.perf_counter()
            n = cluster.step()             # commits a READY swap first
            dt = time.perf_counter() - s0
            tokens += n
            if preparing and n:
                during_tokens += n
                during_s += dt
            if n == 0:
                if track_ticket is not None and not track_ticket.done():
                    time.sleep(0.001)      # idle; the worker still at work
                    continue
                break
        return time.perf_counter() - t0, tokens, during_tokens, during_s

    # ---- warmup: JIT fallbacks + the shared AOT decode executable ----
    load(2 * n_slots)
    serve()
    cluster.reconfigure("e0", default_plan(), prefill_lengths=())
    serve()

    # ---- probe throughput, then size the trace to outlast PREPARE ----
    load(4 * n_slots)
    probe_wall, probe_tokens, _, _ = serve()
    probe_tok_s = probe_tokens / probe_wall
    # the warm subprocess runs several seconds (import + 13 cold
    # compiles); span ~12 s so the trace strictly covers warm + install
    # + commit with no idle tail
    n_requests = max(128, int(probe_tok_s * 12.0 / max_new_tokens))

    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    # boot both compile servers BEFORE the measured phases: a resident
    # compile daemon pays jax import + model build once, not per swap
    warm_server = _WarmServer(arch, n_slots, s_max, cache_dir, env)
    calib_server = _WarmServer(
        arch, n_slots, s_max,
        tempfile.mkdtemp(prefix="bench_overlap_calib_"), env)

    # ---- steady state: the trace with no reconfiguration ----
    load(n_requests)
    steady_wall, steady_tokens, _, _ = serve()
    steady_tok_s = steady_tokens / steady_wall

    def serve_during(fn):
        """Run ``fn`` on a thread; serve (refilling the queue) until it
        returns. Returns tokens/second over that window."""
        done = threading.Event()

        def runner():
            try:
                fn()
            finally:
                done.set()

        th = threading.Thread(target=runner)
        tokens = 0
        t0 = time.perf_counter()
        th.start()
        while not done.is_set():
            n = cluster.step()
            tokens += n
            if n == 0:
                load(n_slots)
        rate = tokens / (time.perf_counter() - t0)
        th.join()
        serve()                            # drain the refill remainder
        return rate

    # ---- calibration: concurrent-serving capacity of this host ----
    # The reference load is an IDENTICAL compile workload running fully
    # out of process against a throwaway cache (equal count of cold
    # modules, disjoint shapes) — i.e. the throughput the host physically
    # permits while a perfectly-isolated PREPARE runs. On a machine with
    # a true spare core this equals steady state and the assertion below
    # is the verbatim "within 10% of steady"; on a shared/starved box it
    # removes the hypervisor's share from the judgement so only overhead
    # added by the in-process overlap machinery can fail the bar. The
    # capacity is measured twice — BRACKETING the overlapped phase — and
    # the smaller reading is used, so drifting host load (shared CI
    # boxes) biases the bar down rather than failing the run.
    n_cold = len(lengths) + 4
    calib_before = serve_during(lambda: calib_server.request(
        range(25, 25 + n_cold)))

    # ---- overlapped: trace + concurrent reconfigure (warmed PREPARE) ----
    pinned = ShardingPlan(device_constraints=(("pod", 0),),
                          forbidden_collective_axes=("pod",))
    buckets = cluster.engine("e0").bucket_lengths()

    def warm():
        warm_server.request(lengths, buckets, pinned.device_constraints,
                            pinned.forbidden_collective_axes)

    load(n_requests)
    ticket = cluster.reconfigure_async("e0", pinned,
                                       prefill_lengths=lengths,
                                       prefill_buckets=True, warm=warm)
    overlap_wall, overlap_tokens, during_tokens, during_s = serve(ticket)
    warm_server.stop()
    assert ticket.state == "swapped", f"swap never committed: {ticket!r}"
    report = ticket.result()
    during_tok_s = during_tokens / during_s if during_s > 0 else float("nan")

    # closing calibration bracket (see above)
    calib_after = serve_during(lambda: calib_server.request(
        range(25 + n_cold, 25 + 2 * n_cold)))
    calib_server.stop()
    calib_tok_s = min(calib_before, calib_after)
    headroom = min(calib_tok_s / steady_tok_s, 1.0)

    # ---- inline baseline: a blocking PREPARE of equal cold work ----
    inline_report = cluster.reconfigure("e0", default_plan(),
                                        prefill_lengths=inline_lengths)
    prepare_inline_s = inline_report.prepare_s
    serve()                                # finalize reports

    saved_s = steady_wall + prepare_inline_s - overlap_wall
    emit("overlap_steady_wall_s", round(steady_wall, 3),
         "trace served with no reconfiguration")
    emit("overlap_steady_tok_s", round(steady_tok_s, 1))
    emit("overlap_calib_tok_s", round(calib_tok_s, 1),
         "concurrent-serving capacity (identical compile, isolated "
         "out of process; min of the two brackets)")
    emit("overlap_calib_bracket_tok_s",
         f"{calib_before:.0f}|{calib_after:.0f}",
         "capacity measured before|after the overlapped phase")
    emit("overlap_parallel_headroom", round(headroom, 3),
         "calib/steady: 1.0 == a true spare core exists")
    emit("overlap_prepare_inline_s", round(prepare_inline_s, 3),
         "blocking PREPARE cost (what an inline swap adds)")
    emit("overlap_prepare_async_s", round(report.prepare_s, 3),
         "background PREPARE: subprocess warm + cache-hit install")
    emit("overlap_wall_s", round(overlap_wall, 3),
         "trace + CONCURRENT reconfigure (must be < steady + inline)")
    emit("overlap_saved_s", round(saved_s, 3),
         "wall-clock the overlap reclaimed vs the inline baseline")
    emit("overlap_during_tok_s", round(during_tok_s, 1),
         f"decode throughput while compiling (>= {1-tol:.0%} of capacity)")
    emit("overlap_during_window_s", round(during_s, 3),
         "serving time spent inside the PREPARE window")
    emit("overlap_throughput_vs_capacity_pct",
         round(100.0 * during_tok_s / calib_tok_s, 1),
         "during-PREPARE vs concurrent capacity (the asserted bar)")
    emit("overlap_throughput_vs_steady_pct",
         round(100.0 * during_tok_s / steady_tok_s, 1),
         "during-PREPARE vs idle steady state (informational)")
    emit("overlap_downtime_ms", round(report.downtime_s * 1e3, 2),
         f"committed swap window (budget {budget_s*1e3:.0f} ms)")
    emit("overlap_aot_executables", report.compiled_in_prepare,
         "compiled in background, installed at the step boundary")
    emit("overlap_midswap_routes", cluster.midswap_routes,
         "routing decisions that hit an engine mid-swap (must be 0)")

    # ---- the contract (after the emits, so failed runs show numbers) ----
    assert overlap_wall < steady_wall + prepare_inline_s, (
        f"PREPARE did not overlap: trace+concurrent reconfigure took "
        f"{overlap_wall:.2f}s >= trace {steady_wall:.2f}s + inline "
        f"prepare {prepare_inline_s:.2f}s")
    assert report.downtime_s < budget_s, (
        f"swap downtime {report.downtime_s*1e3:.1f} ms blew the "
        f"{budget_s*1e3:.0f} ms budget")
    assert during_s > 0, "the trace never overlapped the PREPARE window"
    assert during_tok_s >= (1.0 - tol) * calib_tok_s, (
        f"throughput during PREPARE {during_tok_s:.0f} tok/s fell more "
        f"than {tol:.0%} below the host's concurrent-serving capacity "
        f"{calib_tok_s:.0f} tok/s (steady {steady_tok_s:.0f}, parallel "
        f"headroom {headroom:.2f})")
    assert cluster.midswap_routes == 0, (
        f"{cluster.midswap_routes} requests were routed to an engine "
        "inside its blocking swap window")

    return {
        "steady_wall_s": steady_wall,
        "steady_tok_s": steady_tok_s,
        "calib_tok_s": calib_tok_s,
        "calib_bracket_tok_s": [calib_before, calib_after],
        "parallel_headroom": headroom,
        "prepare_inline_s": prepare_inline_s,
        "prepare_async_s": report.prepare_s,
        "overlap_wall_s": overlap_wall,
        "saved_s": saved_s,
        "during_tok_s": during_tok_s,
        "during_window_s": during_s,
        "throughput_vs_capacity": during_tok_s / calib_tok_s,
        "throughput_vs_steady": during_tok_s / steady_tok_s,
        "downtime_s": report.downtime_s,
        "downtime_budget_s": budget_s,
        "aot_executables": report.compiled_in_prepare,
        "midswap_routes": cluster.midswap_routes,
        "n_requests": n_requests,
        "tokens_served": {"steady": steady_tokens, "overlap": overlap_tokens},
    }


if __name__ == "__main__":
    bench_overlap_prepare()
