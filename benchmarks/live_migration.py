"""Live-migration benchmark: migrate-mode retirement under load.

    PYTHONPATH=src:. python benchmarks/live_migration.py

Runs the same request trace twice — once uninterrupted, once with a
mid-flight migrate-mode retirement that relocates every in-flight request
(mid-decode slots AND still-queued ones) onto a freshly prepared peer —
and asserts the paper's contract:

  * generated-token streams are BITWISE IDENTICAL to the unmigrated run
    (the KV prefix moves verbatim; decode never re-runs prefill);
  * every per-request migration pause is under the downtime budget.
    The paper's figure is < 50 ms on the target fabric; this CPU
    harness applies the same 50 ms budget by default (tiny reduced
    models make the KV slices small enough that CPU transfers fit it)
    — override with MIGRATION_BUDGET_S for slower machines;
  * the retiring engine is reaped IMMEDIATELY (no drain latency);
  * the migration target admits migrated queued requests through its
    AOT executables (exact lengths + padded buckets) — no serving-path
    JIT.

Emitted ``name,value,derived`` CSV rows:

  migration_requests_moved / _decoding_moved / _queued_moved
  migration_pause_ms_max / _mean      per-request blocking pause
  migration_budget_ms                 the asserted budget
  migration_kv_mib_moved
  migration_retire_blocking_ms        whole relocation window (downtime_s)
  migration_streams_identical         1 == bitwise equal to baseline
  migration_target_aot_executables    compiled ahead on the target
"""
from __future__ import annotations

import dataclasses
import os


def bench_live_migration(arch: str = "minitron_4b", n_requests: int = 6,
                         n_slots: int = 4, s_max: int = 48,
                         max_new_tokens: int = 10, emit=None) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import Request, ServingCluster, ServingEngine
    from repro.sharding import default_plan

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    budget_s = float(os.environ.get("MIGRATION_BUDGET_S", "0.05"))
    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size,
                            size=int(rng.integers(5, 10))).astype(np.int32)
               for _ in range(n_requests)]

    def make_requests():
        return [Request(rid, prompts[rid], max_new_tokens=max_new_tokens,
                        labels={"data-type": "phi"})
                for rid in range(n_requests)]

    # ---- baseline: the same trace, never migrated ----
    base = ServingCluster()
    base.register("src", ServingEngine(model, params, n_slots=n_slots,
                                       s_max=s_max))
    base_reqs = make_requests()
    for r in base_reqs:
        base.submit(r)
    base.run()
    baseline = {r.rid: list(r.tokens_out) for r in base_reqs}

    # ---- migrated run: retire the engine mid-flight ----
    cluster = ServingCluster()
    cluster.register("src", ServingEngine(model, params, n_slots=n_slots,
                                          s_max=s_max))
    reqs = make_requests()
    for r in reqs:
        cluster.submit(r)
    for _ in range(3):
        cluster.step()        # slots mid-decode; the overflow still queued

    # prepare the target: AOT decode + the live prompt lengths + the
    # padded-bucket ladder, so nothing JITs when the migrants land
    cluster.register("dst", ServingEngine(model, params, n_slots=n_slots,
                                          s_max=s_max))
    prep = cluster.reconfigure(
        "dst", default_plan(),
        prefill_lengths=cluster.label_prompt_lengths("phi"),
        prefill_buckets=True)

    report = cluster.retire_engine("src", mode="migrate")
    assert "src" not in cluster.engines(), \
        "migrate-mode retirement must reap the engine immediately"
    assert len(report.migrations) == n_requests, \
        f"moved {len(report.migrations)}/{n_requests} requests"
    cluster.run()

    streams = {r.rid: list(r.tokens_out) for r in reqs}
    identical = streams == baseline
    assert identical, "migrated token streams diverged from the baseline"
    pauses = [m.pause_s for m in report.migrations]
    assert max(pauses) < budget_s, \
        (f"per-request migration pause {max(pauses)*1e3:.1f} ms blew the "
         f"{budget_s*1e3:.0f} ms budget")

    decoding = [m for m in report.migrations if m.phase == "decoding"]
    queued = [m for m in report.migrations if m.phase == "queued"]
    emit("migration_requests_moved", len(report.migrations),
         "in-flight requests relocated by one migrate-mode retirement")
    emit("migration_decoding_moved", len(decoding), "KV state moved")
    emit("migration_queued_moved", len(queued), "re-routed pre-prefill")
    emit("migration_pause_ms_max", round(max(pauses) * 1e3, 2),
         f"per-request blocking pause (budget {budget_s*1e3:.0f} ms, "
         "paper <50 ms)")
    emit("migration_pause_ms_mean",
         round(float(np.mean(pauses)) * 1e3, 2))
    emit("migration_budget_ms", round(budget_s * 1e3, 1),
         "MIGRATION_BUDGET_S env overrides")
    emit("migration_kv_mib_moved",
         round(report.migrate_bytes / 2**20, 3))
    emit("migration_retire_blocking_ms", round(report.downtime_s * 1e3, 2),
         "whole relocation window; engine reaped immediately after")
    emit("migration_streams_identical", int(identical),
         "token streams bitwise equal to the unmigrated run")
    emit("migration_target_aot_executables", prep.compiled_in_prepare,
         "decode + exact lengths + padded buckets, compiled in PREPARE")
    return {
        "requests_moved": len(report.migrations),
        "decoding_moved": len(decoding),
        "queued_moved": len(queued),
        "pause_s_max": max(pauses),
        "pause_s_mean": float(np.mean(pauses)),
        "budget_s": budget_s,
        "kv_bytes_moved": report.migrate_bytes,
        "retire_blocking_s": report.downtime_s,
        "streams_identical": identical,
        "target_aot_executables": prep.compiled_in_prepare,
    }


if __name__ == "__main__":
    bench_live_migration()
