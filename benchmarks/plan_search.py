"""Plan-search benchmark: the cost-model-driven `WorkloadPlanner` against
the threshold `ElasticPolicy` on a shifting two-label trace, plus the
heterogeneous-pool demo (A100-like vs L40s-like configuration choice).

    PYTHONPATH=src:. python benchmarks/plan_search.py

Part 1 — head-to-head (same trace, same intent, same factory):
`general` trickles steadily; `phi` bursts in the middle. An intent pins
the phi service level and scale ceiling through the orchestrator. The
threshold policy reacts to queue depth; the planner sizes capacity from
the roofline estimator against the LoadTracker forecast. Asserted
contract (the ISSUE's acceptance): planner SLO-attainment >= the
threshold policy's at <= its engine-seconds.

Part 2 — heterogeneity + execution machinery: the SAME forecast picks a
different configuration on an A100-like pool than on an L40s-like pool
(the L40s roofline is ~2.4x lower on the memory ceiling, so more engines
are needed); the switch executes through `spawn_engine_async` /
`reconfigure_async` / `migrate_requests`, and every committed swap stays
inside the 50 ms downtime budget (env-overridable like the other serving
benchmarks: DOWNTIME_BUDGET_S).

Device profiles are `scaled()` so the tiny CI model is "heavy" relative
to a device: scaling multiplies all rates by one constant, preserving
the inter-profile ratios that drive configuration choices (the scale is
CALIBRATED from the estimator's own unscaled step time, not hardcoded).

Emits ``name,value,derived`` CSV rows and returns the artifact dict
(`run.py` writes it to benchmarks/BENCH_planner.json).
"""
from __future__ import annotations

import dataclasses
import os


SLO_TTFT_S = 10.0      # generous CPU-wall-clock target (both policies
SLO_TPOT_S = 1.0       # attain it; engine-seconds decides the contest)


def _attainment(cluster) -> float:
    """Fraction of ARRIVED requests that completed within the SLO
    (rejected / never-completed demand counts against attainment)."""
    total = sum(cluster.arrivals().values())
    if total == 0:
        return 1.0
    done = []
    for name in cluster.engines():
        done.extend(cluster.engine(name).done)
    done.extend(cluster._retired_done)
    ok = sum(1 for r in done
             if r.ttft <= SLO_TTFT_S and r.tpot <= SLO_TPOT_S)
    return ok / total


def bench_plan_search(arch: str = "minitron_4b", ticks: int = 22,
                      burst: range = range(4, 13), burst_rate: int = 8,
                      steady_rate: int = 1, emit=None) -> dict:
    import jax
    import numpy as np

    from repro.core import Orchestrator
    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.planner import (
        A100,
        L40S,
        EngineSpec,
        LabelDemand,
        WorkloadPlanner,
        estimate,
        features_from_engine,
    )
    from repro.serving import (
        Autoscaler,
        ElasticPolicy,
        LoadTracker,
        Request,
        RoutingError,
        ServingCluster,
        ServingEngine,
    )
    from repro.sharding.plan import default_plan

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    budget_s = float(os.environ.get("DOWNTIME_BUDGET_S", "0.05"))
    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    spec = EngineSpec(plan=default_plan(), n_slots=2, s_max=32)

    def engine_factory(sp, label):
        return ServingEngine(model, params, n_slots=sp.n_slots,
                             s_max=sp.s_max)

    def label_factory(label):
        return ServingEngine(model, params, n_slots=spec.n_slots,
                             s_max=spec.s_max)

    # ---- calibrate the scaled device pool against the model ----
    # target: one A100-like engine serves ~24 tok/s, so the burst
    # (burst_rate req/s x 4 tok) genuinely needs >1 engine and the
    # ~2.4x-lower L40s roofline needs more than the A100 one
    feats = features_from_engine(ServingEngine(model, params,
                                               n_slots=spec.n_slots,
                                               s_max=spec.s_max))
    step_unscaled = estimate(feats, A100).step_s
    scale = 24.0 * step_unscaled / spec.n_slots
    a100, l40s = A100.scaled(scale), L40S.scaled(scale)

    intent_text = ("Keep TTFT under 10 seconds for phi traffic, and keep "
                   "at most four engines for phi traffic.")

    # ------------------------------------------------------------------
    # part 1: threshold policy vs planner on the same shifting trace
    # ------------------------------------------------------------------
    def run_trace(use_planner: bool) -> dict:
        cluster = ServingCluster()
        tracker = LoadTracker(alpha=0.5)
        if use_planner:
            planner = WorkloadPlanner(
                cluster, engine_factory, specs=[spec], profiles=[a100],
                tick_s=1.0, new_tokens=4.0, min_rate=0.5, dwell=1,
                horizon_s=60.0)
            scaler = Autoscaler(cluster, label_factory, planner=planner,
                                tracker=tracker)
        else:
            scaler = Autoscaler(
                cluster, label_factory,
                policy=ElasticPolicy(spawn_depth=3.0, retire_rate=0.25,
                                     sustain=2, cooldown=2,
                                     prefer_rebalance=False),
                tracker=tracker)
        orch = Orchestrator()
        res = orch.submit(intent_text, apply_to=scaler)
        assert res.success, res.report.summary()

        rng = np.random.default_rng(0)
        rid = 0
        rejected = 0
        for t in range(ticks):
            batch = [("general", steady_rate)]
            if t in burst:
                batch.append(("phi", burst_rate))
            for label, k in batch:
                for _ in range(k):
                    try:
                        cluster.submit(Request(
                            rid, rng.integers(2, cfg.vocab_size, size=6)
                            .astype(np.int32), max_new_tokens=4,
                            labels={"data-type": label}))
                    except RoutingError:
                        rejected += 1   # fail-closed; demand still counted
                    rid += 1
            scaler.tick()
            cluster.step()
            cluster.step()
        cluster.run()
        for _ in range(8):              # quiet tail: scale back down
            scaler.tick()
            cluster.run()
        return {
            "cluster": cluster, "scaler": scaler, "rejected": rejected,
            "attainment": _attainment(cluster),
            "engine_seconds": sum(s["total"] for s in scaler.trajectory),
            "peak_engines": max(s["total"] for s in scaler.trajectory),
            "final_engines": scaler.trajectory[-1]["total"],
        }

    thr = run_trace(use_planner=False)
    pln = run_trace(use_planner=True)

    emit("planner_slo_attainment", round(pln["attainment"], 4),
         f"TTFT<={SLO_TTFT_S}s TPOT<={SLO_TPOT_S}s, rejected counted")
    emit("planner_threshold_slo_attainment", round(thr["attainment"], 4))
    emit("planner_engine_seconds", pln["engine_seconds"],
         "sum of engine count over ticks")
    emit("planner_threshold_engine_seconds", thr["engine_seconds"])
    emit("planner_peak_engines", pln["peak_engines"])
    emit("planner_threshold_peak_engines", thr["peak_engines"])
    spawns = sum(1 for d, _ in pln["scaler"].events if d.kind == "spawn")
    retires = sum(1 for d, _ in pln["scaler"].events if d.kind == "retire")
    emit("planner_spawns", spawns)
    emit("planner_retires", retires)

    # ---- the ISSUE's acceptance contract ----
    assert spawns >= 1, "planner never scaled up for the burst"
    assert retires >= 1, "planner never scaled back down"
    assert pln["attainment"] >= thr["attainment"] - 1e-9, (
        f"planner attainment {pln['attainment']:.4f} below threshold "
        f"policy {thr['attainment']:.4f}")
    assert pln["engine_seconds"] <= thr["engine_seconds"], (
        f"planner spent {pln['engine_seconds']} engine-seconds vs "
        f"threshold {thr['engine_seconds']}")

    # ------------------------------------------------------------------
    # part 2: heterogeneous pools pick different configurations, and the
    # switch executes through the ticketed async machinery
    # ------------------------------------------------------------------
    demand = {"phi": LabelDemand(rate=float(burst_rate), prompt_len=6,
                                 new_tokens=4.0)}
    cluster2 = ServingCluster()
    pl_a = WorkloadPlanner(cluster2, engine_factory, specs=[spec],
                           profiles=[a100], new_tokens=4.0, dwell=0)
    pl_l = WorkloadPlanner(cluster2, engine_factory, specs=[spec],
                           profiles=[l40s], new_tokens=4.0, dwell=0)
    # the SAME service-level intent drives both planners' objectives —
    # only the device pool differs
    for pl in (pl_a, pl_l):
        res2 = Orchestrator().submit(intent_text, apply_to=pl)
        assert res2.success and pl.slo_targets["phi"][0] == SLO_TTFT_S
    n_a = pl_a.propose(demand).config["phi"].count
    n_l = pl_l.propose(demand).config["phi"].count
    emit("planner_hetero_engines_a100", n_a, "same demand, A100 pool")
    emit("planner_hetero_engines_l40s", n_l, "same demand, L40s pool")
    assert n_a < n_l, (
        f"heterogeneity lost: A100 pool chose {n_a} engines, L40s pool "
        f"chose {n_l} for the same demand")

    # deploy the A100 configuration through async spawn tickets
    acts = pl_a.plan(demand)
    assert all(a.kind == "spawn" for a in acts) and len(acts) == n_a
    pl_a.execute(acts, async_spawn=True)
    cluster2.run(wait_pending=True)
    assert len(cluster2.engines_for_label("phi")) == n_a

    # the pool "becomes" L40s-class: replanning tops capacity up through
    # spawn_engine_async (ticket-aware: pending capacity never doubles)
    acts = pl_l.plan(demand)
    assert all(a.kind == "spawn" for a in acts) and len(acts) == n_l - n_a
    pl_l.execute(acts, async_spawn=True)
    assert pl_l.plan(demand) == []      # in-flight tickets count
    cluster2.run(wait_pending=True)
    assert len(cluster2.engines_for_label("phi")) == n_l

    # a new route constraint makes the deployed plans stale: the planner
    # reconfigures every phi engine through reconfigure_async
    from repro.sharding.plan import ShardingPlan
    cluster2.set_route_constraint(
        "phi", ShardingPlan(device_constraints=(("pod", 0),),
                            forbidden_collective_axes=("pod",)))
    acts = pl_l.plan(demand)
    assert acts and all(a.kind == "reconfigure" for a in acts), acts
    tickets = [r for _, r in pl_l.execute(acts)]
    # commit at a step boundary only after EVERY background compile
    # finished: on a CPU-only host the in-process compiles hold the GIL,
    # and a swap window committed while peers still compile measures
    # GIL contention, not the swap (same calibration rationale as
    # benchmarks/overlap_prepare.py)
    import time as _time
    from repro.serving.prepare import READY
    while any(not t.done() and t.state != READY for t in tickets):
        _time.sleep(0.001)
    cluster2.commit_ready()
    cluster2.run(wait_pending=True)
    for name in cluster2.engines_for_label("phi"):
        assert dict(cluster2.engine(name).plan.device_constraints) \
            .get("pod") == 0

    # load the pool, then scale back to the A100 configuration: the
    # planner retires excess engines in MIGRATE mode (in-flight work
    # relocates through migrate_requests and the engine reaps at once)
    rng = np.random.default_rng(1)
    for i in range(n_l):               # one resident request per engine:
        cluster2.submit(Request(       # peers keep free slots, so the
            1000 + i,                  # retirement can relocate work
            rng.integers(2, cfg.vocab_size, size=6)
            .astype(np.int32), max_new_tokens=24,
            labels={"data-type": "phi"}))
    cluster2.step()                      # make the work resident
    pl_a._since_exec = pl_a.dwell + 1
    acts = pl_a.plan(demand)
    retire_acts = [a for a in acts if a.kind == "retire"]
    assert len(retire_acts) == n_l - n_a
    assert any(a.mode == "migrate" for a in retire_acts), retire_acts
    results = pl_a.execute(acts)
    migrated = sum(len(r.migrations) for a, r in results
                   if a.kind == "retire")
    emit("planner_hetero_migrated_requests", migrated,
         "relocated by migrate-mode retirement during scale-back")
    assert migrated >= 1, "migrate-mode retirement moved nothing"
    cluster2.run(wait_pending=True)
    assert len(cluster2.engines_for_label("phi")) == n_a
    total2 = sum(cluster2.arrivals().values())
    done2 = sum(m["completed"] for m in
                cluster2.metrics_by_label().values())
    assert done2 == total2, "requests lost across the pool switch"

    # ---- downtime contract over every committed swap ----
    swap_events = [r for r in cluster2.history
                   if r.event in ("reconfigure", "rebalance")]
    worst_swap = max((r.downtime_s for r in swap_events), default=0.0)
    emit("planner_swap_downtime_s_max", round(worst_swap, 4),
         f"budget {budget_s}s (paper <50 ms)")
    assert worst_swap < budget_s, (
        f"swap downtime {worst_swap*1e3:.1f} ms blew the "
        f"{budget_s*1e3:.0f} ms budget")

    return {
        "slo": {"ttft_s": SLO_TTFT_S, "tpot_s": SLO_TPOT_S},
        "planner": {
            "attainment": pln["attainment"],
            "engine_seconds": pln["engine_seconds"],
            "peak_engines": pln["peak_engines"],
            "final_engines": pln["final_engines"],
            "spawns": spawns, "retires": retires,
            "trajectory": [s["total"] for s in pln["scaler"].trajectory],
        },
        "threshold": {
            "attainment": thr["attainment"],
            "engine_seconds": thr["engine_seconds"],
            "peak_engines": thr["peak_engines"],
            "final_engines": thr["final_engines"],
            "trajectory": [s["total"] for s in thr["scaler"].trajectory],
        },
        "hetero": {
            "engines_a100": n_a, "engines_l40s": n_l,
            "profile_scale": scale,
            "migrated_requests": migrated,
            "swap_downtime_s_max": worst_swap,
            "downtime_budget_s": budget_s,
        },
    }


if __name__ == "__main__":
    bench_plan_search()
