"""§Perf hillclimbing helper: run one tagged dry-run variant and print the
three roofline terms next to a reference record.

    PYTHONPATH=src python -m benchmarks.perf --arch mamba2-370m \
        --shape train_4k --tag A1_no_tp \
        --plan '{"tp_axis": null, "batch_axes": ["data","model"], "fsdp_axes": ["data","model"]}'

Records land in experiments/perf/<arch>__<shape>__16x16__<tag>.json.
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path


def run(tag: str, arch: str, shape: str, *, multi_pod: bool = False,
        out="experiments/perf", **kw):
    from repro.launch.dryrun import run_cell
    plan_overrides = kw.pop("plan_overrides", None)
    rec = run_cell(arch, shape, multi_pod, Path(out),
                   plan_overrides=plan_overrides, tag=tag, **kw)
    if rec.get("status") == "ok":
        rf = rec["roofline"]
        print(f"[{tag}] compute={rf['compute_s']:.4f}s "
              f"memory={rf['memory_s']:.4f}s "
              f"collective={rf['collective_s']:.4f}s "
              f"bottleneck={rf['bottleneck']} rf={rf['roofline_fraction']:.3f} "
              f"peak={rec['memory']['peak_bytes']/2**30:.1f}GiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--plan", default=None, help="JSON plan overrides")
    ap.add_argument("--kw", default=None, help="JSON lower_cell kwargs")
    args = ap.parse_args()
    kw = json.loads(args.kw) if args.kw else {}
    if args.plan:
        plan = json.loads(args.plan)
        for k, v in list(plan.items()):
            if isinstance(v, list):
                plan[k] = tuple(v)
        kw["plan_overrides"] = plan
    run(args.tag, args.arch, args.shape, multi_pod=args.multi_pod, **kw)


if __name__ == "__main__":
    main()
