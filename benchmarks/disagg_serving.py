"""Disaggregated prefill/decode serving benchmark.

    PYTHONPATH=src:. python benchmarks/disagg_serving.py

Three contracts, one artifact (``BENCH_disagg.json``):

1. PLANNER CHOICE — on a long-prompt + long-decode mix over the
   heterogeneous A100/L40S catalog (profiles scaled so the tiny CI
   model stands in for a real one, which preserves every compute/
   bandwidth ratio), `best_candidate` over role-tagged specs selects a
   DISAGGREGATED configuration — a cheap L40S prefill tier feeding an
   A100 decode tier — that meets the joint TTFT/TPOT targets at zero
   violations, while every affordable unified configuration (priced
   with the prefill/decode interference disaggregation removes)
   violates them. The win is structural, not an enumeration artifact.

2. EXECUTION — a role-tagged cluster serves a trace through
   first-token handoffs: token streams are BITWISE IDENTICAL to the
   unified oracle (the KV prefix moves verbatim; decode never re-runs
   prefill), every per-request handoff pause is under the budget
   (paper figure < 50 ms; override with HANDOFF_BUDGET_S), and the
   pauses land in the SLO ledger under the dedicated "handoff" cause
   — never double-counted as plain migration.

3. REPLAY — a seeded synthetic trace replayed through the disaggregated
   cluster on the SIMULATED clock (the scale harness): zero drops,
   every request completes, completions land on the decode tier.

Emitted ``name,value,derived`` CSV rows:

  disagg_plan_selected                1 == the search picked disagg
  disagg_plan_prefill / _decode       chosen tier "profile x count"
  disagg_plan_cost / _unified_cost    engine-cost of each winner
  disagg_plan_ttft_s / _tpot_s        predicted latencies (disagg)
  disagg_unified_violations           best unified config's score (> 0)
  disagg_unified_tpot_s               its interference-inflated TPOT
  disagg_handoffs                     first-token handoffs executed
  disagg_pause_ms_max / _mean         per-request handoff pause
  disagg_budget_ms                    the asserted pause budget
  disagg_streams_identical            1 == bitwise equal to unified
  disagg_replay_requests / _dropped   replay harness scale + drops
  disagg_replay_decode_completions    completions on the decode tier
"""
from __future__ import annotations

import dataclasses
import os

PROMPT_LEN = 32768     # the "long prompt": prefill ~11x the decode step
NEW_TOKENS = 32
STEP_TIME_S = 4e-3     # simulated decode-step duration (replay part)


def _plan_part(feats, emit):
    """Part 1: the search chooses disaggregation on the A100/L40S pool."""
    from repro.planner import (A100, L40S, EngineSpec, LabelDemand,
                               TrafficMix, best_candidate, estimate,
                               score_current)
    from repro.sharding import default_plan

    # plan_search's scaling idiom: one A100 engine decodes its full
    # batch in n_slots/24 s. Scaling peak_flops/hbm_bw/link_bw together
    # preserves every ratio the choice depends on.
    step_unscaled = estimate(feats, A100).step_s
    scale = 24.0 * step_unscaled / feats.n_slots
    a100, l40s = A100.scaled(scale), L40S.scaled(scale)

    mix = TrafficMix(prompt_len=PROMPT_LEN, new_tokens=NEW_TOKENS)
    ea = estimate(feats, a100, mix)
    # arrival rate worth 1.7 engine-seconds/second of A100 prefill duty:
    # no single engine can absorb it, and the interference tax on a
    # unified pool stays visible at every affordable count
    rate = 1.7 / ea.prefill_s
    targets = {"phi": (20.0, 1.1 * ea.step_s)}
    demand = {"phi": LabelDemand(rate=rate, prompt_len=PROMPT_LEN,
                                 new_tokens=NEW_TOKENS)}
    specs = [EngineSpec(plan=default_plan(), n_slots=feats.n_slots,
                        s_max=64, role=r)
             for r in ("unified", "prefill", "decode")]

    best = best_candidate(demand, targets, specs=specs,
                          profiles=[a100, l40s],
                          features_fn=lambda s: feats,
                          bounds={"phi": (0, 6)}, max_engines_per_label=6)
    la = best.config["phi"]
    est = best.per_label["phi"]
    assert la.disaggregated, "search kept a unified config for the long mix"
    assert best.violations == 0, f"disagg config violates: {best.violations}"
    roles = la.by_role()
    assert roles["decode"].profile.name.startswith("a100"), \
        "decode tier must land on A100 (L40S step blows the TPOT target)"

    # best unified over the same catalog, priced WITH interference
    best_uni = None
    for prof in (a100, l40s):
        for count in range(1, 7):
            sc = score_current({"phi": (specs[0], prof, count)}, demand,
                               targets, features_fn=lambda s: feats,
                               interference=True)
            key = (sc.violations, sc.cost)
            if best_uni is None or key < best_uni[0]:
                best_uni = (key, prof, count, sc.per_label["phi"])
    (uni_viol, uni_cost), uni_prof, uni_count, uni_est = best_uni
    assert uni_viol > 0, "a unified config met the joint targets"

    def tier(a):
        return f"{a.profile.name.split('@')[0]} x {a.count}"

    emit("disagg_plan_selected", 1,
         "the search picked prefill+decode tiers over every unified config")
    emit("disagg_plan_prefill", tier(roles["prefill"]),
         f"prefill tier (prompt_len {PROMPT_LEN})")
    emit("disagg_plan_decode", tier(roles["decode"]), "decode tier")
    emit("disagg_plan_cost", round(best.cost, 3),
         f"vs {round(uni_cost, 3)} for the best unified attempt")
    emit("disagg_plan_ttft_s", round(est.ttft_s, 3),
         f"target {targets['phi'][0]}")
    emit("disagg_plan_tpot_s", round(est.tpot_s, 4),
         f"target {round(targets['phi'][1], 4)}")
    emit("disagg_unified_violations", round(uni_viol, 3),
         f"best unified ({uni_prof.name.split('@')[0]} x {uni_count}) "
         "still violates")
    emit("disagg_unified_tpot_s", round(uni_est.tpot_s, 4),
         "interference-inflated TPOT of that unified config")
    return {
        "selected_disagg": True,
        "prefill_tier": tier(roles["prefill"]),
        "decode_tier": tier(roles["decode"]),
        "cost": best.cost,
        "ttft_s": est.ttft_s,
        "tpot_s": est.tpot_s,
        "ttft_target_s": targets["phi"][0],
        "tpot_target_s": targets["phi"][1],
        "unified_best_violations": uni_viol,
        "unified_best_cost": uni_cost,
        "unified_best_tpot_s": uni_est.tpot_s,
    }


def _exec_part(model, params, cfg, emit):
    """Part 2: first-token handoffs — bitwise streams, bounded pauses,
    first-class accounting."""
    import numpy as np

    from repro.obs import Recorder, SLOLedger, recording
    from repro.serving import Request, ServingCluster, ServingEngine

    budget_s = float(os.environ.get("HANDOFF_BUDGET_S", "0.05"))
    n_requests, max_new = 8, 10
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size,
                            size=int(rng.integers(6, 12))).astype(np.int32)
               for _ in range(n_requests)]

    def make_requests():
        return [Request(rid, prompts[rid], max_new_tokens=max_new)
                for rid in range(n_requests)]

    # unified oracle: same trace, one engine, never handed off
    base = ServingCluster()
    base.register("uni", ServingEngine(model, params, n_slots=8, s_max=64))
    base_reqs = make_requests()
    for r in base_reqs:
        base.submit(r)
    base.run()
    baseline = {r.rid: list(r.tokens_out) for r in base_reqs}

    with recording(Recorder()) as rec:
        cluster = ServingCluster()
        cluster.register("pf0", ServingEngine(model, params, n_slots=4,
                                              s_max=64), role="prefill")
        cluster.register("pf1", ServingEngine(model, params, n_slots=4,
                                              s_max=64), role="prefill")
        cluster.register("dc", ServingEngine(model, params, n_slots=8,
                                             s_max=64), role="decode")
        reqs = make_requests()
        placed = [cluster.submit(r) for r in reqs]
        assert all(p.startswith("pf") for p in placed), \
            "new requests must route to the prefill tier only"
        cluster.run()

    streams = {r.rid: list(r.tokens_out) for r in reqs}
    identical = streams == baseline
    assert identical, "handed-off token streams diverged from the oracle"

    pauses = [e.data["pause_s"] for e in rec.events("migration.pause")
              if e.data["reason"] == "handoff"]
    assert len(pauses) == n_requests, \
        f"{len(pauses)}/{n_requests} requests handed off"
    assert max(pauses) < budget_s, \
        (f"handoff pause {max(pauses)*1e3:.1f} ms blew the "
         f"{budget_s*1e3:.0f} ms budget")
    ledger = SLOLedger().consume(rec.events())
    acct = ledger.pause_accounting()
    assert acct["handoff"]["count"] == n_requests
    assert acct["migration"]["count"] == 0, \
        "handoff pauses double-counted as plain migration"
    assert ledger.completed_by_role().get("decode") == n_requests

    emit("disagg_handoffs", n_requests,
         "first-token handoffs prefill tier -> decode tier")
    emit("disagg_pause_ms_max", round(max(pauses) * 1e3, 2),
         f"per-request handoff pause (budget {budget_s*1e3:.0f} ms, "
         "paper <50 ms)")
    emit("disagg_pause_ms_mean", round(float(np.mean(pauses)) * 1e3, 2))
    emit("disagg_budget_ms", round(budget_s * 1e3, 1),
         "HANDOFF_BUDGET_S env overrides")
    emit("disagg_streams_identical", int(identical),
         "token streams bitwise equal to the unified single-engine run")
    return {
        "handoffs": n_requests,
        "pause_s_max": max(pauses),
        "pause_s_mean": float(np.mean(pauses)),
        "budget_s": budget_s,
        "streams_identical": identical,
        "ledger_handoff_count": acct["handoff"]["count"],
        "ledger_migration_count": acct["migration"]["count"],
    }


class _PinnedScaler:
    """A no-op control loop: the replay exercises the handoff data path
    under arrival dynamics with the tier sizes held fixed."""

    planner = None

    def tick(self, dt):
        return None


def _replay_part(model, params, cfg, emit):
    """Part 3: the scale harness replays a seeded trace through the
    disaggregated cluster on the simulated clock."""
    from repro.obs import Recorder, SLOLedger, recording
    from repro.serving import (FakeClock, ServingCluster, ServingEngine,
                               install_clock)
    from repro.traffic import (LabelProfile, TrafficPattern, generate_trace,
                               replay_trace)

    clock = FakeClock(tick=1e-6)
    restore = install_clock(clock)
    try:
        with recording(Recorder()) as rec:
            cluster = ServingCluster()
            cluster.register("pf0", ServingEngine(model, params, n_slots=4,
                                                  s_max=64), role="prefill")
            cluster.register("pf1", ServingEngine(model, params, n_slots=4,
                                                  s_max=64), role="prefill")
            cluster.register("dc", ServingEngine(model, params, n_slots=8,
                                                 s_max=64), role="decode")
            pattern = TrafficPattern(
                duration_s=6.0, base_rate=60.0,
                labels={"phi": LabelProfile(weight=1.0)},
                diurnal_period_s=3.0, seed=5)
            trace = generate_trace(pattern)
            stats = replay_trace(
                trace, cluster, _PinnedScaler(), clock,
                vocab_size=cfg.vocab_size, step_time_s=STEP_TIME_S,
                tick_s=1.0, window_ticks=2,
                slo_targets={"phi": (50 * STEP_TIME_S, 2 * STEP_TIME_S)})
    finally:
        restore()
    # the replay drains completions incrementally (cluster metrics views
    # reset on drain), so per-role counts come from the obs stream
    ledger = SLOLedger().consume(rec.events())
    decode_done = ledger.completed_by_role().get("decode", 0)
    handoffs = sum(e.data["moved"] for e in rec.events("cluster.handoff"))

    assert stats.dropped == 0, f"replay dropped {stats.dropped} requests"
    assert stats.completed == stats.submitted == len(trace)
    assert handoffs > 0, "the replay never exercised the handoff path"
    assert decode_done > 0, "no completion ever landed on the decode tier"

    emit("disagg_replay_requests", len(trace),
         "seeded synthetic trace on the simulated clock")
    emit("disagg_replay_dropped", stats.dropped, "0 == fail-closed healthy")
    emit("disagg_replay_handoffs", handoffs, "first-token handoffs")
    emit("disagg_replay_decode_completions", decode_done,
         f"of {stats.completed} total (rest decoded in place when the "
         "decode tier was full)")
    return {
        "replay_requests": len(trace),
        "replay_dropped": stats.dropped,
        "replay_completed": stats.completed,
        "replay_handoffs": handoffs,
        "replay_decode_completions": decode_done,
        "replay_attainment": stats.attainment.get("phi"),
    }


def bench_disagg_serving(arch: str = "minitron_4b", emit=None) -> dict:
    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.planner import features_from_engine
    from repro.serving import ServingEngine

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    feats = features_from_engine(ServingEngine(model, params, n_slots=8,
                                               s_max=64))

    artifact = {}
    artifact.update(_plan_part(feats, emit))
    artifact.update(_exec_part(model, params, cfg, emit))
    artifact.update(_replay_part(model, params, cfg, emit))
    return artifact


if __name__ == "__main__":
    bench_disagg_serving()
