"""Paged-pool saturation benchmark: a mixed-length flash crowd against the
slot-granular engine vs the paged continuous-batching engine at the SAME
KV memory budget.

    PYTHONPATH=src:. python benchmarks/paged_batching.py

Trace shape: every request arrives at t=0 (flash crowd — the admission
path is never idle), prompt and generation lengths drawn from a bimodal
mix (~80% short interactive requests, a tail of long ones). Both engines
then get an identical fixed decode-step budget.

The comparison is memory-normalized, which is the whole point of paging:

  * **slot engine**: ``n_slots = kv_budget / s_max`` lanes, each lane
    pinning a full ``s_max`` KV extent for its request's lifetime — a
    ~30-token request on the ``s_max=256`` pool wastes ~90% of its lane;
  * **paged engine**: 6x the lanes over the SAME ``kv_budget`` tokens of
    KV — each request reserves only the pages its worst-case extent can
    touch, so the reclaimed padding admits more concurrent requests and
    every decode step advances more streams.

On the mixed trace the paged engine must sustain MORE decode tokens per
second AND admit more requests within the step budget (asserted here —
this is the ISSUE's acceptance gate), and its KV utilization
(used / allocated tokens) must sit above the slot engine's padding-
wasted ratio. Wall-clock rates are the median of ``PAGED_BENCH_REPEATS``
independent drives (fresh engine each) to damp CPU scheduling jitter.

Emitted ``name,value,derived`` CSV rows (also in BENCH_paged.json):

  paged_requests / paged_steps          trace + budget sizing
  paged_{slot,paged}_tok_s              sustained decode tokens/sec
  paged_{slot,paged}_admitted           requests prefilled in budget
  paged_{slot,paged}_completed          requests finished in budget
  paged_{slot,paged}_kv_util_mean       mean per-step KV utilization
  paged_throughput_gain                 paged tok/s over slot tok/s

Sizing knobs (CI default is moderate; the nominal saturation trace is
thousands of requests):

  PAGED_BENCH_REQUESTS   trace length          (default 600)
  PAGED_BENCH_STEPS      decode step budget    (default 120)
  PAGED_BENCH_REPEATS    timing repetitions    (default 3)
"""
from __future__ import annotations

import dataclasses
import os
import time


def _trace(rng, cfg, n, s_max):
    """(prompt, max_new) pairs: ~80% short interactive, ~20% long."""
    out = []
    for _ in range(n):
        if rng.random() < 0.8:
            p, m = int(rng.integers(3, 9)), int(rng.integers(14, 21))
        else:
            p, m = int(rng.integers(12, 25)), int(rng.integers(20, 29))
        p = min(p, s_max - 2)
        out.append((rng.integers(2, cfg.vocab_size, size=p)
                    .astype("int32"), m))
    return out


def _drive(engine, trace, steps, make_request):
    """Flash-crowd submit, then a fixed step budget; returns sustained
    tokens/sec, admitted/completed counts, and mean KV utilization."""
    reqs = [make_request(i, p, m) for i, (p, m) in enumerate(trace)]
    for r in reqs:
        engine.submit(r)
    utils = []
    t0 = time.perf_counter()
    for _ in range(steps):
        engine.step()
        utils.append(engine.kv_utilization)
        if not engine.load:
            break
    wall = time.perf_counter() - t0
    tokens = sum(len(r.tokens_out) for r in reqs)
    return {
        "tok_s": tokens / wall if wall > 0 else 0.0,
        "tokens": tokens,
        "wall_s": wall,
        "admitted": sum(1 for r in reqs if r.tokens_out),
        "completed": len(engine.done),
        "kv_util_mean": sum(utils) / len(utils) if utils else 0.0,
    }


def bench_paged_batching(arch: str = "minitron_4b", emit=None) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    n_requests = int(os.environ.get("PAGED_BENCH_REQUESTS", "600"))
    steps = int(os.environ.get("PAGED_BENCH_STEPS", "120"))
    repeats = int(os.environ.get("PAGED_BENCH_REPEATS", "3"))
    s_max = 256
    kv_budget = 4 * s_max                 # tokens of KV memory, both engines
    page_size = 8

    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    trace = _trace(rng, cfg, n_requests, s_max)

    def make_request(rid, prompt, max_new):
        return Request(rid, prompt.copy(), max_new_tokens=max_new)

    def slot_engine():
        return ServingEngine(model, params, n_slots=kv_budget // s_max,
                             s_max=s_max, paged=False)

    def paged_engine():
        return ServingEngine(model, params, n_slots=6 * (kv_budget // s_max),
                             s_max=s_max, page_size=page_size,
                             kv_tokens=kv_budget)

    # each timing repetition uses a FRESH engine, warmed on the trace's
    # prompt lengths outside the timed window (the serving loop itself
    # must never pay a compile); the median damps CPU scheduling jitter
    warm_lens = sorted({len(p) for p, _ in trace})
    results = {}
    for kind, factory in (("slot", slot_engine), ("paged", paged_engine)):
        runs = []
        for _ in range(max(repeats, 1)):
            eng = factory()
            for i, n in enumerate(warm_lens):
                eng.submit(make_request(-1 - i,
                                        trace[0][0][:1].repeat(n), 2))
            eng.run()
            eng.done.clear()
            runs.append(_drive(eng, trace, steps, make_request))
        results[kind] = sorted(runs, key=lambda r: r["tok_s"])[len(runs) // 2]
        results[kind]["tok_s_runs"] = [r["tok_s"] for r in runs]

    slot, paged = results["slot"], results["paged"]
    gain = paged["tok_s"] / slot["tok_s"] if slot["tok_s"] else float("inf")

    # ---- acceptance gates (the ISSUE's criteria, enforced here) ----
    assert paged["tok_s"] > slot["tok_s"], \
        f"paged engine slower: {paged['tok_s']:.1f} <= {slot['tok_s']:.1f} tok/s"
    assert paged["admitted"] > slot["admitted"], \
        f"paged admitted {paged['admitted']} <= slot {slot['admitted']}"
    assert paged["kv_util_mean"] > slot["kv_util_mean"], \
        "paged pool did not raise KV utilization over slot padding"

    emit("paged_requests", n_requests, "flash-crowd trace length")
    emit("paged_steps", steps, "decode step budget per engine")
    emit("paged_kv_budget_tokens", kv_budget, "same KV memory, both engines")
    for kind in ("slot", "paged"):
        r = results[kind]
        emit(f"paged_{kind}_tok_s", round(r["tok_s"], 1),
             f"sustained decode throughput, median of {repeats}")
        emit(f"paged_{kind}_admitted", r["admitted"],
             "requests prefilled within the step budget")
        emit(f"paged_{kind}_completed", r["completed"])
        emit(f"paged_{kind}_kv_util_mean", round(r["kv_util_mean"], 3),
             "used / allocated KV tokens, per-step mean")
    emit("paged_throughput_gain", round(gain, 2),
         "paged tok/s over slot tok/s at equal KV memory")

    return {
        "requests": n_requests,
        "steps": steps,
        "kv_budget_tokens": kv_budget,
        "page_size": page_size,
        "s_max": s_max,
        "slot": slot,
        "paged": paged,
        "throughput_gain": gain,
    }


if __name__ == "__main__":
    bench_paged_batching()
