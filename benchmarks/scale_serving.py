"""Million-request-scale serving benchmark on the simulated clock.

    PYTHONPATH=src:. python benchmarks/scale_serving.py

A >=10^5-request synthetic trace (diurnal modulation, a phi flash
crowd, an adversarial long-prompt flood on gen) is replayed through the
FULL stack — workload planner + autoscaler + migration machinery +
paged-KV-backed engines — with every timing quantity on the simulated
clock (`repro.serving.clock.FakeClock`): decode steps advance virtual
time by the modeled step duration, idle gaps are jumped, and no
wall-clock sleep gates the run. Wall time is therefore just the decode
math; simulated minutes of traffic replay in CI.

The planner runs with `ResidualCalibration` installed and engine
profiles attached from `calibrate_host_profile()`: every measurement
window the harness folds observed per-label TTFT/TPOT back into the
estimator as an EWMA residual correction, recording the analytical and
calibrated predictions FIRST (one-step-ahead, so the comparison is
honest). Asserted contract (the ISSUE's acceptance):

  * >= 10^5 requests replayed, zero dropped, every DowntimeReport
    finalized;
  * SLO attainment computed per label and overall;
  * calibrated predicted-vs-measured error strictly below the
    uncorrected analytical roofline's.

Emits ``name,value,derived`` CSV rows and returns the artifact dict
(`run.py` writes it to benchmarks/BENCH_scale.json). Env overrides:
SCALE_REQUESTS (approximate target, default 100000), SCALE_STEP_TIME_S
(modeled decode-step duration, default 4e-3).
"""
from __future__ import annotations

import os
import time as wall

SEED = 11
TICK_S = 1.0            # autoscaler control-loop period (simulated)
WINDOW_TICKS = 4        # ticks per calibration/measurement window


def bench_scale_serving(arch: str = "minitron_4b", emit=None) -> dict:
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.planner import (
        EngineSpec,
        ResidualCalibration,
        WorkloadPlanner,
        calibrate_host_profile,
    )
    from repro.serving import (
        Autoscaler,
        FakeClock,
        LoadTracker,
        ServingCluster,
        ServingEngine,
        install_clock,
    )
    from repro.sharding.plan import default_plan
    from repro.traffic import (
        FlashCrowd,
        LabelProfile,
        LongPromptFlood,
        TrafficPattern,
        generate_trace,
        replay_trace,
    )

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    n_target = int(os.environ.get("SCALE_REQUESTS", "100000"))
    # the modeled service rate: one 8-slot engine moves n_slots/step_time
    # = 2000 slot-tokens/s, so mean demand (~5600/s) forces the planner
    # to scale out toward the 4-engine ceiling (8000/s); diurnal peaks
    # run just under pooled capacity and the flash crowd pushes past it
    # transiently — spawn/retire under load, not a single static engine
    step_time_s = float(os.environ.get("SCALE_STEP_TIME_S", "4e-3"))

    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan(), n_slots=8, s_max=32)

    def engine_factory(sp, label):
        return ServingEngine(model, params, n_slots=sp.n_slots,
                             s_max=sp.s_max)

    # arrival intensity from the request target: base_rate * duration
    # ~= n_target (crowd/flood extras land on top, ~15-20% headroom)
    duration_s = 72.0
    base_rate = n_target / duration_s
    pattern = TrafficPattern(
        duration_s=duration_s, base_rate=base_rate,
        labels={"phi": LabelProfile(weight=2.0),
                "gen": LabelProfile(weight=1.0)},
        diurnal_period_s=duration_s / 2,
        flash_crowds=(FlashCrowd(t_start=duration_s / 3,
                                 duration_s=duration_s / 6,
                                 multiplier=3.0, label="phi"),),
        floods=(LongPromptFlood(t_start=2 * duration_s / 3,
                                duration_s=duration_s / 12,
                                rate=base_rate / 6, label="gen",
                                prompt_len=24, new_tokens=2),),
        seed=SEED)

    clock = FakeClock(tick=1e-6)
    restore = install_clock(clock)
    try:
        cluster = ServingCluster()
        calibration = ResidualCalibration(alpha=0.3)
        planner = WorkloadPlanner(cluster, engine_factory, specs=[spec],
                                  profiles=[host], dwell=0,
                                  calibration=calibration, clock=clock)
        for label in ("phi", "gen"):
            planner.bounds[label] = (1, 4)
            planner.set_slo_target(label, 50 * step_time_s,
                                   2 * step_time_s)
        scaler = Autoscaler(cluster,
                            lambda label: engine_factory(spec, label),
                            planner=planner,
                            tracker=LoadTracker(alpha=0.5),
                            async_spawn=False, clock=clock)
        planner.execute(planner.plan({}), async_spawn=False)  # floors
        planner.attach_calibrated_profiles()     # measured DeviceProfiles

        t_gen = wall.monotonic()
        trace = generate_trace(pattern)
        gen_s = wall.monotonic() - t_gen
        t_rep = wall.monotonic()
        stats = replay_trace(trace, cluster, scaler, clock,
                             vocab_size=cfg.vocab_size,
                             step_time_s=step_time_s, tick_s=TICK_S,
                             window_ticks=WINDOW_TICKS, seed=1)
        wall_s = wall.monotonic() - t_rep
    finally:
        restore()

    err = stats.prediction_error()
    contract = {
        "hundred_k_plus": len(trace) >= 100_000,
        "zero_dropped": stats.dropped == 0
        and stats.completed == stats.submitted == len(trace),
        "reports_finalized": stats.reports_finalized,
        "calibrated_beats_analytical":
            err["analytical_mare"] is not None
            and err["calibrated_mare"] < err["analytical_mare"],
    }
    if n_target >= 100_000:
        assert contract["hundred_k_plus"], len(trace)
    assert contract["zero_dropped"], (stats.dropped, stats.completed)
    assert contract["reports_finalized"]
    assert contract["calibrated_beats_analytical"], err

    emit("scale_requests", len(trace))
    emit("scale_sim_duration_s", round(stats.duration_s, 3))
    emit("scale_replay_wall_s", round(wall_s, 2),
         f"trace generation {gen_s:.2f}s; no wall sleeps — decode math "
         "only")
    emit("scale_sim_speedup",
         round(stats.duration_s / max(wall_s, 1e-9), 3),
         "simulated seconds per wall second")
    emit("scale_steps", stats.steps)
    emit("scale_dropped", stats.dropped, "contract: 0")
    emit("scale_engine_seconds", round(stats.engine_seconds, 3))
    emit("scale_peak_engines", stats.peak_engines)
    for label in sorted(stats.attainment):
        emit(f"scale_slo_attainment_{label}",
             round(stats.attainment[label], 4))
    emit("scale_slo_attainment_overall",
         round(stats.attainment_overall, 4)
         if stats.attainment_overall is not None else "n/a")
    emit("scale_pred_mare_analytical", round(err["analytical_mare"], 4),
         "mean |rel err|, one-step-ahead")
    emit("scale_pred_mare_calibrated", round(err["calibrated_mare"], 4),
         "contract: < analytical")
    emit("scale_calibration_windows", err["windows_scored"])
    emit("scale_downtime_max_s", round(stats.downtime_max_s, 6))

    return {
        "seed": SEED,
        "requests": len(trace),
        "step_time_s": step_time_s,
        "tick_s": TICK_S,
        "window_ticks": WINDOW_TICKS,
        "sim_duration_s": stats.duration_s,
        "replay_wall_s": wall_s,
        "steps": stats.steps,
        "submitted": stats.submitted,
        "completed": stats.completed,
        "dropped": stats.dropped,
        "engine_seconds": stats.engine_seconds,
        "peak_engines": stats.peak_engines,
        "final_engines": stats.final_engines,
        "per_label": stats.per_label,
        "slo_attainment": dict(stats.attainment,
                               overall=stats.attainment_overall),
        "prediction_error": err,
        "calibration": calibration.as_dict(),
        "downtime_max_s": stats.downtime_max_s,
        "reports": stats.reports,
        "contract": contract,
    }


if __name__ == "__main__":
    bench_scale_serving()
