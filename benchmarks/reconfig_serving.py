"""Online-reconfiguration benchmark (the paper's downtime / TTFT / TPOT
view of an intent change on a live serving fabric).

    PYTHONPATH=src:. python benchmarks/reconfig_serving.py

Drives the public `ServingCluster` runtime end-to-end:

  wave 1 (default plan)  ->  intent via Orchestrator(apply_to=cluster)
  [PREPARE: AOT compile | SWAP: drain+migrate | RESUME]  ->  wave 2

and emits ``name,value,derived`` CSV rows:

  reconfig_prepare_s       background compile (serving continues)
  reconfig_downtime_s      blocking swap window (paper target: < 50 ms)
  reconfig_aot_executables executables compiled ahead of the swap
  reconfig_migrated_MiB
  reconfig_ttft/tpot_{before,after}_s
  reconfig_overhead_pct    TTFT+TPOT overhead after the swap (< 10 % target)
"""
from __future__ import annotations

import dataclasses


def bench_reconfig_cluster(arch: str = "qwen2_moe_a2_7b",
                           n_requests: int = 8, emit=None) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_reduced_config
    from repro.core import Orchestrator
    from repro.models import build_model
    from repro.serving import Request, ServingCluster, ServingEngine

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    cluster = ServingCluster()
    cluster.register("edge0", ServingEngine(model, params,
                                            n_slots=4, s_max=48))
    rng = np.random.default_rng(0)

    def load(n, base, labels):
        for rid in range(n):
            cluster.submit(Request(
                base + rid,
                rng.integers(2, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=8, labels=labels))

    load(n_requests, 0, {"data-type": "phi"})
    cluster.run()

    orch = Orchestrator()
    res = orch.submit("Phi traffic must remain inside the pod.",
                      apply_to=cluster)
    assert res.success, res.report.summary()
    report = res.reports["edge0"]

    load(n_requests, 100, {"data-type": "phi"})
    cluster.run()                      # finalizes report.metrics_after

    before, after = report.metrics_before, report.metrics_after
    overhead = 100.0 * max(
        after["ttft_mean_s"] / before["ttft_mean_s"] - 1.0,
        after["tpot_mean_s"] / before["tpot_mean_s"] - 1.0)
    emit("reconfig_prepare_s", round(report.prepare_s, 4),
         "background compile (serving continues)")
    emit("reconfig_downtime_s", round(report.downtime_s, 4),
         "blocking swap window (paper target <0.05)")
    emit("reconfig_aot_executables", report.compiled_in_prepare,
         "compiled ahead of the swap window")
    emit("reconfig_migrated_MiB", round(report.migrate_bytes / 2**20, 2))
    emit("reconfig_ttft_before_s", round(before["ttft_mean_s"], 4))
    emit("reconfig_ttft_after_s", round(after["ttft_mean_s"], 4))
    emit("reconfig_tpot_before_s", round(before["tpot_mean_s"], 4))
    emit("reconfig_tpot_after_s", round(after["tpot_mean_s"], 4))
    emit("reconfig_overhead_pct", round(overhead, 1),
         "worst of TTFT/TPOT inflation (paper target <10, NB: first-wave "
         "JIT warmup usually makes this negative here)")
    return {"report": report, "before": before, "after": after}


if __name__ == "__main__":
    bench_reconfig_cluster()
