"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows. Each benchmark mirrors a paper
artifact (see DESIGN.md §7 for the index):

  table7_*            — GPT-4o overall performance table (paper Table 7)
  fig7_*              — interpreter-backend comparison (paper Fig. 7)
  fig9_<domain>_*     — per-domain metrics (paper Figs. 8/9)
  fig11_<cplx>_*      — per-complexity metrics (paper Figs. 10/11)
  failmode_*          — §6.3 failure-mode detection rates
  reconfig_*          — downtime / TTFT / TPOT around an online plan swap
                        (calibration-band metrics)
  migration_*         — live in-flight request migration (migrate-mode
                        retirement: per-request pause + stream identity)
  elastic_*           — autoscaled spawn/retire trajectory over a bursty
                        two-label trace
  roofline summary    — printed per (arch x shape) from the dry-run records

  overlap_*           — concurrent PREPARE: background compilation
                        overlapped with serving (wall-clock + throughput
                        + downtime contract)
  planner_*           — workload-aware configuration planner vs the
                        threshold ElasticPolicy (SLO attainment at
                        engine-seconds), plus the heterogeneous
                        A100-vs-L40s configuration choice
  paged_*             — paged KV pool + continuous batching vs the
                        slot-granular engine at equal KV memory on a
                        mixed-length flash-crowd saturation trace
  scale_*             — >=10^5-request synthetic-trace replay on the
                        SIMULATED clock through the full planner +
                        autoscaler + migration + paged-KV stack, with
                        online estimator calibration (EWMA residual
                        correction) beating the analytical roofline
  disagg_*            — prefill/decode disaggregated serving: the search
                        picks a split (L40S prefill tier + A100 decode
                        tier) over every unified config on a long-
                        prompt mix, and first-token handoffs keep
                        streams bitwise identical under a <50 ms pause
  watch_*             — Watchtower alerting: three injected degradations
                        (flash crowd past capacity, slowed engine,
                        poisoned calibration) each detected with finite
                        SIMULATED-second latency, zero false alarms on
                        the healthy baseline, critical-path attribution
                        conserving measured TTFT/TPOT, byte-
                        deterministic round-tripping debug bundles

Machine-readable artifacts: the serving benchmarks also write
``benchmarks/BENCH_reconfig.json`` (reconfigure + migration),
``benchmarks/BENCH_elastic.json`` (autoscaling trajectory),
``benchmarks/BENCH_overlap.json`` (concurrent-PREPARE contract),
``benchmarks/BENCH_planner.json`` (planner-vs-threshold contract),
``benchmarks/BENCH_paged.json`` (paged-pool saturation contract),
``benchmarks/BENCH_scale.json`` (scale-replay + calibration contract),
``benchmarks/BENCH_obs.json`` (flight-recorder overhead contract),
``benchmarks/BENCH_disagg.json`` (disaggregated-serving contract), and
``benchmarks/BENCH_watch.json`` (alerting + attribution contract) —
each mirrored to the repo root — so the perf trajectory is tracked
across PRs. CI produces them via

    PYTHONPATH=src:. python benchmarks/run.py --check --only reconfig migration elastic overlap planner paged scale obs disagg watch

(``--only`` substring-matches bench function names; no flag runs all.
``--check`` additionally gates the run against the COMMITTED
``benchmarks/BENCH_*.json`` baselines: each artifact's curated metrics
— see ``CHECK_TOLERANCES`` — must stay within per-metric tolerances of
the baseline, and the process exits nonzero on any regression.)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time
from pathlib import Path

ROWS = []
ARTIFACTS = {}          # bench key -> JSON-able dict (see _write_artifacts)
ART_DIR = Path(__file__).resolve().parent


def emit(name: str, value, derived: str = "") -> None:
    ROWS.append((name, value, derived))
    print(f"{name},{value},{derived}")


def _jsonable(x):
    """Recursively convert to strict-JSON values (NaN/inf -> None)."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, float) and not math.isfinite(x):
        return None
    if hasattr(x, "item"):               # numpy scalar
        return _jsonable(x.item())
    return x


#: artifact name -> the ARTIFACTS keys that fold into BENCH_<name>.json
ARTIFACT_FILES = {
    "reconfig": ("reconfigure", "migration"),
    "elastic": ("elastic",),
    "overlap": ("overlap",),
    "planner": ("planner",),
    "paged": ("paged",),
    "scale": ("scale",),
    "obs": ("obs",),
    "disagg": ("disagg",),
    "watch": ("watch",),
}


def _artifact_data(name: str):
    """The JSON-able payload BENCH_<name>.json would hold right now
    (None when the contributing benchmarks did not run)."""
    keys = ARTIFACT_FILES[name]
    if len(keys) == 1:
        return ARTIFACTS.get(keys[0])
    return {k: ARTIFACTS[k] for k in keys if k in ARTIFACTS} or None


#: ``--check`` regression gates: artifact -> {dotted metric path ->
#: tolerance}. Only SIMULATED/deterministic quantities and contract
#: booleans are gated — wall-clock numbers vary run to run on shared
#: boxes and would make the gate flaky. Tolerance kinds:
#:   "truthy"        the new value must be truthy
#:   "exact"         the new value must equal the committed baseline
#:   ("le_rel", f)   new <= baseline * (1 + f)   (bounded worsening)
#:   ("ge_rel", f)   new >= baseline * (1 - f)
#:   ("le_abs", cap) new <= cap                  (fixed ceiling)
#:   ("ge_abs", flo) new >= flo                  (fixed floor)
CHECK_TOLERANCES = {
    "obs": {
        "contract.overhead_under_budget": "truthy",
        "contract.trace_valid": "truthy",
        "contract.identical_sim_results": "truthy",
        "contract.no_event_drops": "truthy",
        "requests": "exact",
        "events_dropped": "exact",
        "spans_dropped": "exact",
    },
    "watch": {
        "contract.ok": "truthy",
        "scenarios.healthy.n_alerts": "exact",
        "scenarios.flash_crowd.detection_latency_s": ("le_rel", 0.5),
        "scenarios.slowed_engine.detection_latency_s": ("le_rel", 0.5),
        "scenarios.poisoned_calibration.detection_latency_s": ("le_rel", 0.5),
        "attribution.conservation.ttft_max_rel_err": ("le_abs", 0.01),
        "attribution.conservation.tpot_max_rel_err": ("le_abs", 0.01),
        "bundles.byte_deterministic": "truthy",
        "bundles.round_trip_ok": "truthy",
    },
    "scale": {
        "contract.hundred_k_plus": "truthy",
        "contract.zero_dropped": "truthy",
        "contract.reports_finalized": "truthy",
        "contract.calibrated_beats_analytical": "truthy",
        "completed": "exact",
        "dropped": "exact",
        "downtime_max_s": ("le_abs", 0.05),
    },
    "disagg": {
        "selected_disagg": "truthy",
        "streams_identical": "truthy",
        "replay_dropped": "exact",
        "replay_completed": "exact",
        "replay_attainment": ("ge_rel", 0.0),
    },
    "paged": {
        "throughput_gain": ("ge_abs", 1.0),
    },
    "elastic": {
        "downtime_s_max": ("le_abs", 0.05),
    },
    "overlap": {
        "downtime_s": ("le_abs", 0.05),
    },
    "reconfig": {
        "reconfigure.downtime_s": ("le_abs", 0.05),
    },
}


def _dig(d, path: str):
    """``_dig({"a": {"b": 1}}, "a.b") == 1``; None on any missing hop."""
    for part in path.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def _rule_ok(rule, new, old):
    """Apply one CHECK_TOLERANCES rule; returns ``(ok, detail)``."""
    if rule == "truthy":
        return bool(new), f"expected truthy, got {new!r}"
    if rule == "exact":
        return new == old, f"expected baseline {old!r}, got {new!r}"
    kind, bound = rule
    if not isinstance(new, (int, float)) or isinstance(new, bool):
        return False, f"non-numeric value {new!r}"
    if kind == "le_abs":
        return new <= bound, f"{new} exceeds ceiling {bound}"
    if kind == "ge_abs":
        return new >= bound, f"{new} below floor {bound}"
    if not isinstance(old, (int, float)) or isinstance(old, bool):
        return False, f"non-numeric baseline {old!r}"
    if kind == "le_rel":
        return (new <= old * (1.0 + bound) + 1e-12,
                f"{new} regressed past baseline {old} (+{bound:.0%})")
    if kind == "ge_rel":
        return (new >= old * (1.0 - bound) - 1e-12,
                f"{new} regressed below baseline {old} (-{bound:.0%})")
    raise ValueError(f"unknown tolerance rule {rule!r}")


def _check_regressions(baselines: dict) -> list:
    """Compare this run's artifacts against the committed baselines
    snapshotted at startup; returns the list of failure strings."""
    failures = []
    for name, rules in CHECK_TOLERANCES.items():
        produced = _artifact_data(name)
        if produced is None:
            continue                     # benchmark didn't run (--only)
        base = baselines.get(name)
        if base is None:
            emit(f"_check_{name}", "skipped", "no committed baseline")
            continue
        bad = 0
        for path, rule in rules.items():
            ok, detail = _rule_ok(rule, _dig(produced, path),
                                  _dig(base, path))
            if not ok:
                bad += 1
                failures.append(f"{name}:{path}: {detail}")
        emit(f"_check_{name}", "ok" if not bad else f"{bad} FAILED",
             f"{len(rules)} gated metrics")
    return failures


def _write_artifacts() -> None:
    """Write BENCH_<name>.json for whatever serving benchmarks ran
    (partial runs write partial artifacts). Each artifact is mirrored to
    the REPO ROOT as well as benchmarks/, so the perf trajectory is
    visible at the top level of every PR diff."""
    for name in ARTIFACT_FILES:
        data = _artifact_data(name)
        if data is None:
            continue
        text = json.dumps(_jsonable(data), indent=2) + "\n"
        for where in (ART_DIR, ART_DIR.parent):
            (where / f"BENCH_{name}.json").write_text(text)
        emit(f"_artifact_{name}_json", str(ART_DIR / f"BENCH_{name}.json"),
             "mirrored to repo root")


# ---------------------------------------------------------------------------


def bench_table7_overall() -> None:
    from benchmarks.intent_metrics import aggregate, run_corpus
    records = run_corpus()
    a = aggregate(records)["overall"]
    emit("table7_tasks", a["n"])
    emit("table7_accuracy_pct", round(a["success_rate"], 1),
         "paper GPT-4o: 95.6")
    emit("table7_avg_checks_per_task", round(a["avg_checks"], 2),
         "paper: 3.7")
    emit("table7_avg_time_s", round(a["avg_time_s"], 4),
         "paper: 20.97 (incl. real K8s/ONOS+LLM API latency)")
    emit("table7_avg_tokens", round(a["avg_tokens"], 0), "paper: 15133")


def bench_fig7_backend_comparison() -> None:
    from benchmarks.intent_metrics import aggregate, run_corpus
    from repro.core import DeterministicInterpreter, FaultyInterpreter
    backends = [
        ("det-parser", DeterministicInterpreter()),
        ("degraded-10pct", FaultyInterpreter(name="degraded-10", rate=0.10)),
        ("degraded-25pct", FaultyInterpreter(name="degraded-25", rate=0.25)),
    ]
    for name, be in backends:
        a = aggregate(run_corpus(interpreter=be))["overall"]
        emit(f"fig7_{name}_accuracy_pct", round(a["success_rate"], 1),
             "paper: gpt4o=95.6 claude=86.7 deepseek=77.8")
        emit(f"fig7_{name}_avg_time_s", round(a["avg_time_s"], 4))
        emit(f"fig7_{name}_avg_tokens", round(a["avg_tokens"], 0))


def bench_fig9_domains() -> None:
    from benchmarks.intent_metrics import aggregate, run_corpus
    records = run_corpus()
    for dom, a in aggregate(records, key="domain").items():
        emit(f"fig9_{dom}_accuracy_pct", round(a["success_rate"], 1),
             "paper: computing=100 networking=90.3 hybrid=96.7")
        emit(f"fig9_{dom}_avg_checks", round(a["avg_checks"], 2),
             "paper: computing=1.8 networking=3.7 hybrid=5.5")
        emit(f"fig9_{dom}_avg_time_s", round(a["avg_time_s"], 4))
        emit(f"fig9_{dom}_avg_tokens", round(a["avg_tokens"], 0))


def bench_fig11_complexity() -> None:
    from benchmarks.intent_metrics import aggregate, run_corpus
    records = run_corpus()
    for cplx, a in aggregate(records, key="complexity").items():
        emit(f"fig11_{cplx}_accuracy_pct", round(a["success_rate"], 1))
        emit(f"fig11_{cplx}_avg_checks", round(a["avg_checks"], 2),
             "paper: simple=1.1 complex=5.6")
        emit(f"fig11_{cplx}_avg_time_s", round(a["avg_time_s"], 4))


def bench_failure_modes() -> None:
    """Each §6.3 failure mode injected at rate 1.0: how often the pipeline
    detects it (fail-closed or gold-assertion catch)."""
    from benchmarks.intent_metrics import run_corpus
    from repro.core import FaultyInterpreter
    for mode in ("first_clause", "empty_path", "hallucinated_label",
                 "partial_topology"):
        be = FaultyInterpreter(name=f"fault-{mode}", rate=1.0, modes=(mode,))
        records = run_corpus(interpreter=be)
        emit(f"failmode_{mode}_success_pct",
             round(100.0 * sum(r["success"] for r in records) / len(records), 1),
             "success = fault harmless or caught fail-closed")


def bench_reconfig_serving() -> None:
    """Online reconfiguration through the ServingCluster runtime: downtime +
    TTFT/TPOT before vs after the swap (calibration-band metrics)."""
    try:
        from benchmarks.reconfig_serving import bench_reconfig_cluster
    except ImportError:   # invoked as `python benchmarks/run.py`
        from reconfig_serving import bench_reconfig_cluster
    out = bench_reconfig_cluster(emit=emit)
    rep, before, after = out["report"], out["before"], out["after"]
    ARTIFACTS["reconfigure"] = {
        "prepare_s": rep.prepare_s,
        "downtime_s": rep.downtime_s,
        "migrate_bytes": rep.migrate_bytes,
        "aot_executables": rep.compiled_in_prepare,
        "ttft_before_s": before["ttft_mean_s"],
        "ttft_after_s": after["ttft_mean_s"],
        "tpot_before_s": before["tpot_mean_s"],
        "tpot_after_s": after["tpot_mean_s"],
        "overhead_pct": 100.0 * max(
            after["ttft_mean_s"] / before["ttft_mean_s"] - 1.0,
            after["tpot_mean_s"] / before["tpot_mean_s"] - 1.0),
    }


def bench_live_migration() -> None:
    """Live in-flight request migration: migrate-mode retirement must keep
    token streams bitwise identical and every per-request pause under the
    (CPU-scaled) 50 ms budget."""
    try:
        from benchmarks.live_migration import bench_live_migration as bench
    except ImportError:
        from live_migration import bench_live_migration as bench
    ARTIFACTS["migration"] = bench(emit=emit)


def bench_elastic_scaling() -> None:
    """Autoscaled spawn/retire trajectory over a bursty two-label trace
    (downtime + TTFT/TPOT per label + engine-count trajectory)."""
    try:
        from benchmarks.elastic_scaling import bench_elastic_scaling as bench
    except ImportError:
        from elastic_scaling import bench_elastic_scaling as bench
    out = bench(emit=emit)
    scaler, cluster = out["scaler"], out["cluster"]
    events = [(d.kind, d.label, d.mode, r.downtime_s, r.prepare_s)
              for d, r in scaler.events]
    ARTIFACTS["elastic"] = {
        "spawns": sum(1 for e in events if e[0] == "spawn"),
        "retires": sum(1 for e in events if e[0] == "retire"),
        "rebalances": sum(1 for e in events if e[0] == "rebalance"),
        "peak_engines": max(out["trajectory"]),
        "final_engines": out["trajectory"][-1],
        "downtime_s_max": max((e[3] for e in events), default=0.0),
        "trajectory": out["trajectory"],
        "per_label": {
            label: {"completed": m["completed"],
                    "ttft_mean_s": m["ttft_mean_s"],
                    "tpot_mean_s": m["tpot_mean_s"]}
            for label, m in out["by_label"].items()},
        "events": [{"kind": k, "label": lb, "mode": md,
                    "downtime_s": d, "prepare_s": p}
                   for k, lb, md, d, p in events],
    }


def bench_overlap_prepare() -> None:
    """Concurrent PREPARE: the combined wall clock must beat the inline
    baseline, the committed swap must stay in the 50 ms budget, and
    serving throughput during PREPARE must stay within 10% of the host's
    CONCURRENT-SERVING CAPACITY — steady state on a machine with a real
    spare core; on starved CI boxes, the throughput an identical fully
    out-of-process compile permits (see benchmarks/overlap_prepare.py
    for the calibration rationale; both numbers are in the artifact)."""
    try:
        from benchmarks.overlap_prepare import bench_overlap_prepare as bench
    except ImportError:
        from overlap_prepare import bench_overlap_prepare as bench
    ARTIFACTS["overlap"] = bench(emit=emit)


def bench_planner_search() -> None:
    """Workload-aware configuration planner: SLO attainment >= the
    threshold ElasticPolicy at <= its engine-seconds on a shifting
    two-label trace; the same demand picks different configurations on
    A100-like vs L40s-like pools; the switch executes through the
    ticketed async machinery inside the 50 ms swap budget."""
    try:
        from benchmarks.plan_search import bench_plan_search as bench
    except ImportError:
        from plan_search import bench_plan_search as bench
    ARTIFACTS["planner"] = bench(emit=emit)


def bench_paged_batching() -> None:
    """Paged KV pool + continuous batching: at equal KV memory the paged
    engine must sustain more decode tokens/sec AND admit more requests
    than the slot-granular engine on a mixed-length flash-crowd trace,
    at higher KV utilization (used / allocated tokens)."""
    try:
        from benchmarks.paged_batching import bench_paged_batching as bench
    except ImportError:
        from paged_batching import bench_paged_batching as bench
    ARTIFACTS["paged"] = bench(emit=emit)


def bench_scale_serving() -> None:
    """Million-request-scale replay on the simulated clock: a >=10^5-
    request synthetic trace (diurnal + flash crowd + long-prompt flood)
    through the full planner+autoscaler+migration+paged-KV stack, zero
    drops, every DowntimeReport finalized, and the online-calibrated
    estimator's predicted-vs-measured error strictly below the
    uncorrected analytical roofline's."""
    try:
        from benchmarks.scale_serving import bench_scale_serving as bench
    except ImportError:
        from scale_serving import bench_scale_serving as bench
    ARTIFACTS["scale"] = bench(emit=emit)


def bench_obs_overhead() -> None:
    """Flight-recorder overhead + trace validity: the recorded replay's
    throughput must stay within 2% of the unrecorded one (zero-overhead-
    when-disabled is asserted separately by the no-op path), and the
    exported Chrome trace must validate as Perfetto-loadable."""
    try:
        from benchmarks.obs_overhead import bench_obs_overhead as bench
    except ImportError:
        from obs_overhead import bench_obs_overhead as bench
    ARTIFACTS["obs"] = bench(emit=emit)


def bench_disagg_serving() -> None:
    """Prefill/decode disaggregated serving: the role-aware search picks
    a disaggregated config (cheap prefill tier + A100 decode tier) that
    meets the joint TTFT/TPOT targets where every unified config —
    priced with the interference disaggregation removes — violates
    them; execution hands requests off at the first-token boundary with
    bitwise-identical streams and sub-budget pauses; the replay harness
    drives the handoff path at trace scale with zero drops."""
    try:
        from benchmarks.disagg_serving import bench_disagg_serving as bench
    except ImportError:
        from disagg_serving import bench_disagg_serving as bench
    ARTIFACTS["disagg"] = bench(emit=emit)


def bench_watchtower() -> None:
    """Watchtower alerting + critical-path attribution: three injected
    degradations (flash crowd past capacity, slowed engine, poisoned
    calibration) must each raise the right alert with finite
    SIMULATED-second detection latency, the healthy baseline must raise
    none, per-request attribution must conserve measured TTFT/TPOT
    within 1%, and captured debug bundles must be byte-deterministic
    and round-trip their SLO accounting."""
    try:
        from benchmarks.watchtower import bench_watchtower as bench
    except ImportError:
        from watchtower import bench_watchtower as bench
    ARTIFACTS["watch"] = bench(emit=emit)


def bench_roofline_table() -> None:
    """Summarize the dry-run records (single-pod mesh) — §Roofline."""
    d = Path("experiments/dryrun")
    if not d.exists():
        emit("roofline_records", 0, "run repro.launch.dryrun --all first")
        return
    n = 0
    for f in sorted(d.glob("*__16x16.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        n += 1
        rf = r["roofline"]
        emit(f"roofline_{r['arch']}_{r['shape']}_bottleneck",
             rf["bottleneck"].replace("_s", ""),
             f"rf={rf['roofline_fraction']:.3f} "
             f"useful={rf['useful_flops_ratio']:.2f}")
    emit("roofline_records", n)


def bench_kernel_latency() -> None:
    """Interpret-mode kernel sanity timings (not TPU perf — correctness
    plumbing only)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(3):
        jax.block_until_ready(ops.flash_attention(q, k, v, causal=True))
    emit("kernel_flash_interpret_us_per_call",
         round((time.time() - t0) / 3 * 1e6, 0), "interpret mode on CPU")


BENCHES = [
    bench_table7_overall,
    bench_fig7_backend_comparison,
    bench_fig9_domains,
    bench_fig11_complexity,
    bench_failure_modes,
    bench_reconfig_serving,
    bench_live_migration,
    bench_elastic_scaling,
    bench_overlap_prepare,
    bench_planner_search,
    bench_paged_batching,
    bench_scale_serving,
    bench_obs_overhead,
    bench_disagg_serving,
    bench_watchtower,
    bench_kernel_latency,
    bench_roofline_table,
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", nargs="*", default=None, metavar="SUBSTR",
                    help="run only benches whose function name contains "
                         "any of these substrings; current suites: "
                         "table7 fig7 fig9 fig11 failure reconfig "
                         "migration elastic overlap planner paged scale "
                         "obs disagg watch kernel roofline")
    ap.add_argument("--check", action="store_true",
                    help="after running, gate this run's artifacts "
                         "against the committed benchmarks/BENCH_*.json "
                         "baselines (per-metric tolerances, see "
                         "CHECK_TOLERANCES); exits 1 on any regression")
    args = ap.parse_args(argv)
    baselines = {}
    if args.check:
        # snapshot the committed baselines BEFORE _write_artifacts
        # overwrites them with this run's numbers
        for name in CHECK_TOLERANCES:
            p = ART_DIR / f"BENCH_{name}.json"
            if p.exists():
                baselines[name] = json.loads(p.read_text())
    benches = BENCHES if not args.only else [
        b for b in BENCHES
        if any(s in b.__name__ for s in args.only)]
    print("name,value,derived")
    for b in benches:
        t0 = time.time()
        b()
        emit(f"_bench_{b.__name__}_wall_s", round(time.time() - t0, 2))
    _write_artifacts()
    if args.check:
        failures = _check_regressions(baselines)
        for f in failures:
            print(f"CHECK FAIL: {f}")
        if failures:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
