"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows. Each benchmark mirrors a paper
artifact (see DESIGN.md §7 for the index):

  table7_*            — GPT-4o overall performance table (paper Table 7)
  fig7_*              — interpreter-backend comparison (paper Fig. 7)
  fig9_<domain>_*     — per-domain metrics (paper Figs. 8/9)
  fig11_<cplx>_*      — per-complexity metrics (paper Figs. 10/11)
  failmode_*          — §6.3 failure-mode detection rates
  reconfig_*          — downtime / TTFT / TPOT around an online plan swap
                        (calibration-band metrics)
  roofline summary    — printed per (arch x shape) from the dry-run records
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

ROWS = []


def emit(name: str, value, derived: str = "") -> None:
    ROWS.append((name, value, derived))
    print(f"{name},{value},{derived}")


# ---------------------------------------------------------------------------


def bench_table7_overall() -> None:
    from benchmarks.intent_metrics import aggregate, run_corpus
    records = run_corpus()
    a = aggregate(records)["overall"]
    emit("table7_tasks", a["n"])
    emit("table7_accuracy_pct", round(a["success_rate"], 1),
         "paper GPT-4o: 95.6")
    emit("table7_avg_checks_per_task", round(a["avg_checks"], 2),
         "paper: 3.7")
    emit("table7_avg_time_s", round(a["avg_time_s"], 4),
         "paper: 20.97 (incl. real K8s/ONOS+LLM API latency)")
    emit("table7_avg_tokens", round(a["avg_tokens"], 0), "paper: 15133")


def bench_fig7_backend_comparison() -> None:
    from benchmarks.intent_metrics import aggregate, run_corpus
    from repro.core import DeterministicInterpreter, FaultyInterpreter
    backends = [
        ("det-parser", DeterministicInterpreter()),
        ("degraded-10pct", FaultyInterpreter(name="degraded-10", rate=0.10)),
        ("degraded-25pct", FaultyInterpreter(name="degraded-25", rate=0.25)),
    ]
    for name, be in backends:
        a = aggregate(run_corpus(interpreter=be))["overall"]
        emit(f"fig7_{name}_accuracy_pct", round(a["success_rate"], 1),
             "paper: gpt4o=95.6 claude=86.7 deepseek=77.8")
        emit(f"fig7_{name}_avg_time_s", round(a["avg_time_s"], 4))
        emit(f"fig7_{name}_avg_tokens", round(a["avg_tokens"], 0))


def bench_fig9_domains() -> None:
    from benchmarks.intent_metrics import aggregate, run_corpus
    records = run_corpus()
    for dom, a in aggregate(records, key="domain").items():
        emit(f"fig9_{dom}_accuracy_pct", round(a["success_rate"], 1),
             "paper: computing=100 networking=90.3 hybrid=96.7")
        emit(f"fig9_{dom}_avg_checks", round(a["avg_checks"], 2),
             "paper: computing=1.8 networking=3.7 hybrid=5.5")
        emit(f"fig9_{dom}_avg_time_s", round(a["avg_time_s"], 4))
        emit(f"fig9_{dom}_avg_tokens", round(a["avg_tokens"], 0))


def bench_fig11_complexity() -> None:
    from benchmarks.intent_metrics import aggregate, run_corpus
    records = run_corpus()
    for cplx, a in aggregate(records, key="complexity").items():
        emit(f"fig11_{cplx}_accuracy_pct", round(a["success_rate"], 1))
        emit(f"fig11_{cplx}_avg_checks", round(a["avg_checks"], 2),
             "paper: simple=1.1 complex=5.6")
        emit(f"fig11_{cplx}_avg_time_s", round(a["avg_time_s"], 4))


def bench_failure_modes() -> None:
    """Each §6.3 failure mode injected at rate 1.0: how often the pipeline
    detects it (fail-closed or gold-assertion catch)."""
    from benchmarks.intent_metrics import run_corpus
    from repro.core import FaultyInterpreter
    for mode in ("first_clause", "empty_path", "hallucinated_label",
                 "partial_topology"):
        be = FaultyInterpreter(name=f"fault-{mode}", rate=1.0, modes=(mode,))
        records = run_corpus(interpreter=be)
        emit(f"failmode_{mode}_success_pct",
             round(100.0 * sum(r["success"] for r in records) / len(records), 1),
             "success = fault harmless or caught fail-closed")


def bench_reconfig_serving() -> None:
    """Online reconfiguration through the ServingCluster runtime: downtime +
    TTFT/TPOT before vs after the swap (calibration-band metrics)."""
    try:
        from benchmarks.reconfig_serving import bench_reconfig_cluster
    except ImportError:   # invoked as `python benchmarks/run.py`
        from reconfig_serving import bench_reconfig_cluster
    bench_reconfig_cluster(emit=emit)


def bench_roofline_table() -> None:
    """Summarize the dry-run records (single-pod mesh) — §Roofline."""
    d = Path("experiments/dryrun")
    if not d.exists():
        emit("roofline_records", 0, "run repro.launch.dryrun --all first")
        return
    n = 0
    for f in sorted(d.glob("*__16x16.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        n += 1
        rf = r["roofline"]
        emit(f"roofline_{r['arch']}_{r['shape']}_bottleneck",
             rf["bottleneck"].replace("_s", ""),
             f"rf={rf['roofline_fraction']:.3f} "
             f"useful={rf['useful_flops_ratio']:.2f}")
    emit("roofline_records", n)


def bench_kernel_latency() -> None:
    """Interpret-mode kernel sanity timings (not TPU perf — correctness
    plumbing only)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(3):
        jax.block_until_ready(ops.flash_attention(q, k, v, causal=True))
    emit("kernel_flash_interpret_us_per_call",
         round((time.time() - t0) / 3 * 1e6, 0), "interpret mode on CPU")


BENCHES = [
    bench_table7_overall,
    bench_fig7_backend_comparison,
    bench_fig9_domains,
    bench_fig11_complexity,
    bench_failure_modes,
    bench_reconfig_serving,
    bench_kernel_latency,
    bench_roofline_table,
]


def main() -> None:
    print("name,value,derived")
    for b in BENCHES:
        t0 = time.time()
        b()
        emit(f"_bench_{b.__name__}_wall_s", round(time.time() - t0, 2))


if __name__ == "__main__":
    main()
