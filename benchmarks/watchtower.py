"""Watchtower benchmark: alerting detects injected degradations fast,
never cries wolf, and attribution conserves measured latency.

    PYTHONPATH=src:. python benchmarks/watchtower.py

Four replays of the same compact stack (`recorded_replay` on a
`FakeClock`), three of them degraded on purpose, all watched by an
`repro.obs.AlertEvaluator`:

  1. **healthy** — the stock 2k-request replay. Contract: ZERO alerts
     (no false alarms), and per-request critical-path attribution
     (`RequestLineage`) conserves TTFT/TPOT within 1% of the engine's
     own measurements (exactly 0 under the FakeClock — the recorder
     stamps with non-advancing clock reads).
  2. **flash_crowd** — the phi flash crowd cranked 80x past baseline
     while the engine bounds are pinned to one engine per label and
     the simulated step is slowed to 20ms, so the burst (onset t=8
     sim-s) genuinely exceeds serving capacity and the queue blows
     through the TTFT target. Contract: a ``slo.burn_rate`` alert
     with finite detection latency, measured in SIMULATED seconds
     from onset.
  3. **slowed_engine** — decode steps take 6x longer from t=16 sim-s
     (``step_time_fn``). Contract: an ``estimator.drift`` alert (the
     planner's calibrated predictions stop matching reality).
  4. **poisoned_calibration** — the residual calibration is pre-seeded
     with bogus tiny ratios before the replay starts (onset t=0), so
     calibrated predictions are ~50x too optimistic. Contract: an
     ``estimator.drift`` alert on the first measurement window.

Plus three cross-cutting contracts:

  * **Bundles are deterministic and round-trip.** The poisoned
    scenario is run twice into separate bundle directories; the first
    captured bundle must be byte-identical across runs, and
    ``replay_ledger(load_bundle(p))`` — SLO attainment re-derived from
    the bundled event stream alone — must match the attainment frozen
    into the bundle by the live ledger.
  * **Alerting never perturbs the simulation.** A watched replay at
    the BENCH_obs workload scale is re-run without any evaluator;
    simulated stats must be bit-identical (the evaluator only reads
    the event stream with non-advancing clock stamps).
  * **Recording overhead stays inside the BENCH_obs 2% contract.** The
    same mechanistic attribution as `benchmarks.obs_overhead` — warm
    per-op costs x observed op counts x the cold-cache safety factor —
    at the same workload scale BENCH_obs calibrated the budget on
    (the contract is per-workload: a denser trace amortizes the replay
    loop's fixed per-step cost and would shrink the denominator).

Emits ``name,value,derived`` CSV rows and returns the artifact dict
(`run.py` writes it to BENCH_watch.json, mirrored at the repo root).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

try:
    from benchmarks.obs_overhead import (
        OVERHEAD_BUDGET,
        SAFETY_FACTOR,
        _per_op_costs,
    )
except ImportError:                                  # run as a script
    from obs_overhead import OVERHEAD_BUDGET, SAFETY_FACTOR, _per_op_costs

SEED = 11
#: FakeClock epoch inside `recorded_replay` — alert timestamps are
#: absolute simulated time, onsets below are trace-relative
EPOCH = 1_000.0
#: attribution conservation tolerance (fraction of the measurement)
CONSERVATION_EPS = 0.01
#: simulated onset of each injected degradation, trace-relative seconds
ONSETS = {"flash_crowd": 8.0, "slowed_engine": 16.0,
          "poisoned_calibration": 0.0}


def _watched_replay(n_requests, *, evaluator_kw=None, poison=None,
                    timings=None, **replay_kw):
    """One `recorded_replay` with an `AlertEvaluator` wired to the full
    stack; returns ``(stats, rec, planner, evaluator)``."""
    from repro.obs import AlertEvaluator
    from repro.traffic.replay import recorded_replay

    holder = {}

    def factory(rec, planner, scaler):
        if poison is not None:
            poison(planner.calibration)
        ev = AlertEvaluator(rec, policy=planner,
                            calibration=planner.calibration,
                            planner=planner, scaler=scaler,
                            **(evaluator_kw or {}))
        holder["evaluator"] = ev
        return ev

    stats, rec, planner = recorded_replay(
        n_requests, seed=SEED, alert_evaluator_factory=factory,
        timings=timings, **replay_kw)
    return stats, rec, planner, holder["evaluator"]


def _poison_calibration(calibration):
    """Pre-seed the residual EWMAs with a bogus 'everything is 50x
    faster than predicted' history (clipped at 1/ratio_cap), enough
    observations to clear the drift alarm's cold-start gate."""
    for _ in range(4):
        for label in ("phi", "gen"):
            calibration.observe(label, predicted_ttft_s=1.0,
                                predicted_tpot_s=1.0,
                                measured_ttft_s=0.02,
                                measured_tpot_s=0.02)


def _alert_counts(evaluator):
    counts = {}
    for a in evaluator.alerts:
        counts[a.name] = counts.get(a.name, 0) + 1
    return counts


def _detection_latency_s(evaluator, name, onset_rel_s):
    """Simulated seconds from degradation onset to the first ``name``
    alert; None when it never fired (a failed contract)."""
    ts = [a.t for a in evaluator.alerts if a.name == name]
    if not ts:
        return None
    return min(ts) - (EPOCH + onset_rel_s)


def bench_watchtower(emit=None) -> dict:
    from repro.obs import RequestLineage, load_bundle, replay_ledger
    from repro.traffic.replay import recorded_replay

    if emit is None:
        def emit(name, value, derived=""):
            print(f"{name},{value},{derived}")

    n_healthy = int(os.environ.get("WATCH_REQUESTS", "2000"))
    n_degraded = int(os.environ.get("WATCH_DEGRADED_REQUESTS", "400"))
    scenarios = {}

    # -- healthy baseline: zero alerts + conservation -----------------
    stats_h, rec_h, planner_h, ev_h = _watched_replay(n_healthy)
    lineage = RequestLineage.from_recorder(rec_h)
    cons = lineage.conservation(eps=CONSERVATION_EPS)
    critical = lineage.critical_path()
    scenarios["healthy"] = {
        "requests": stats_h.completed,
        "alerts": _alert_counts(ev_h),
        "n_alerts": len(ev_h.alerts),
    }

    # -- overhead + sim-identity at the BENCH_obs workload scale ------
    n_obs = int(os.environ.get("OBS_REQUESTS", "1000"))
    timings = {}
    stats_w, rec_w, _, ev_w = _watched_replay(n_obs, timings=timings)
    costs = _per_op_costs()
    wall_on = timings["replay_wall_s"]
    attributed_s = SAFETY_FACTOR * (rec_w.bus.emitted * costs["emit_s"]
                                    + rec_w.trace.added * costs["span_s"])
    overhead = attributed_s / wall_on

    # alerting never perturbs the simulation
    stats_plain, _, _ = recorded_replay(n_obs, seed=SEED)
    identical_sim = (dataclasses.asdict(stats_plain)
                     == dataclasses.asdict(stats_w))
    assert identical_sim, "evaluated replay diverged from plain replay"

    # -- flash crowd past capacity: SLO burn rate ---------------------
    # one engine per label + 20ms steps caps phi capacity well under
    # the 80x burst, so the queue blows through the TTFT target
    _, _, _, ev = _watched_replay(
        n_degraded, flash_multiplier=80.0, bounds=(1, 1),
        step_time_s=0.02,
        # the overload is real queueing, not estimator error: widen the
        # drift band so only the burn-rate signal speaks for this run
        evaluator_kw={"drift_band": 50.0})
    scenarios["flash_crowd"] = {
        "onset_s": ONSETS["flash_crowd"],
        "alerts": _alert_counts(ev),
        "detection_latency_s": _detection_latency_s(
            ev, "slo.burn_rate", ONSETS["flash_crowd"]),
    }

    # -- slowed engine: calibrated predictions drift ------------------
    def slow_after_16(t, _base=4e-3):
        return _base * 6.0 if t >= ONSETS["slowed_engine"] else _base

    _, _, _, ev = _watched_replay(
        n_degraded, step_time_fn=slow_after_16,
        evaluator_kw={"drift_band": 4.0})
    scenarios["slowed_engine"] = {
        "onset_s": ONSETS["slowed_engine"],
        "alerts": _alert_counts(ev),
        "detection_latency_s": _detection_latency_s(
            ev, "estimator.drift", ONSETS["slowed_engine"]),
    }

    # -- poisoned calibration: drift from the first window ------------
    # (also the bundle scenario: run twice, byte-compare the first
    # captured bundle, and round-trip its SLO accounting)
    bundle_first = {}
    round_trip_ok = None
    n_bundles = 0
    for attempt in ("a", "b"):
        with tempfile.TemporaryDirectory() as d:
            _, _, _, ev = _watched_replay(
                n_degraded, poison=_poison_calibration,
                evaluator_kw={"drift_band": 8.0, "bundle_dir": d})
            names = sorted(os.listdir(d))
            assert names, "poisoned run captured no bundles"
            n_bundles = len(names)
            path = os.path.join(d, names[0])
            bundle_first[attempt] = open(path, "rb").read()
            if round_trip_ok is None:
                bundle = load_bundle(path)
                live = bundle["slo"]["attainment"]
                rederived = replay_ledger(bundle).attainment()
                round_trip_ok = {
                    k: (None if v is None else round(v, 12))
                    for k, v in rederived.items()} == {
                    k: (None if v is None else round(v, 12))
                    for k, v in live.items()}
    byte_deterministic = bundle_first["a"] == bundle_first["b"]
    scenarios["poisoned_calibration"] = {
        "onset_s": ONSETS["poisoned_calibration"],
        "alerts": _alert_counts(ev),
        "detection_latency_s": _detection_latency_s(
            ev, "estimator.drift", ONSETS["poisoned_calibration"]),
    }

    detected_all = all(
        scenarios[s]["detection_latency_s"] is not None
        and scenarios[s]["detection_latency_s"] >= 0.0
        for s in ONSETS)
    contract = {
        "zero_false_alarms": len(ev_h.alerts) == 0
        and len(ev_w.alerts) == 0,
        "detected_all": detected_all,
        "conservation_ok": cons["ttft_max_rel_err"] <= CONSERVATION_EPS
        and cons["tpot_max_rel_err"] <= CONSERVATION_EPS
        and not cons["violations"],
        "bundle_byte_deterministic": byte_deterministic,
        "bundle_round_trip": bool(round_trip_ok),
        "identical_sim_results": identical_sim,
        "overhead_under_budget": overhead < OVERHEAD_BUDGET,
    }
    contract["ok"] = all(contract.values())
    assert contract["zero_false_alarms"], (ev_h.alerts, ev_w.alerts)
    assert contract["detected_all"], scenarios
    assert contract["conservation_ok"], cons
    assert contract["bundle_byte_deterministic"]
    assert contract["bundle_round_trip"]
    assert contract["overhead_under_budget"], (
        f"attributed recording overhead {overhead:.2%} >= "
        f"{OVERHEAD_BUDGET:.0%} on the watched replay")

    emit("watch_requests", stats_h.completed)
    emit("watch_healthy_alerts", len(ev_h.alerts), "contract: 0")
    for s in ("flash_crowd", "slowed_engine", "poisoned_calibration"):
        lat = scenarios[s]["detection_latency_s"]
        emit(f"watch_{s}_detection_s",
             "n/a" if lat is None else round(lat, 3),
             f"sim-seconds after onset t={ONSETS[s]:g}")
    emit("watch_attributed_requests", cons["n"])
    emit("watch_conservation_ttft_max_rel_err",
         round(cons["ttft_max_rel_err"], 6),
         f"contract: <= {CONSERVATION_EPS:g} (0 under FakeClock)")
    emit("watch_conservation_tpot_max_rel_err",
         round(cons["tpot_max_rel_err"], 6),
         f"contract: <= {CONSERVATION_EPS:g}")
    for label, cp in sorted(critical.items()):
        emit(f"watch_critical_{label}",
             f"{cp['ttft']['dominant_p99']}/{cp['tpot']['dominant_p99']}",
             "dominant p99 TTFT/TPOT component")
    emit("watch_bundles_per_poisoned_run", n_bundles)
    emit("watch_bundle_byte_deterministic", byte_deterministic)
    emit("watch_bundle_round_trip", bool(round_trip_ok),
         "re-derived SLO attainment == live ledger")
    emit("watch_identical_sim", identical_sim,
         "evaluated == unevaluated replay")
    emit("watch_attributed_overhead_pct", round(100 * overhead, 3),
         f"contract: < {100 * OVERHEAD_BUDGET:.0f} (BENCH_obs method)")

    return {
        "seed": SEED,
        "requests": n_healthy,
        "degraded_requests": n_degraded,
        "scenarios": scenarios,
        "attribution": {
            "conservation": cons,
            "critical_path": critical,
        },
        "bundles": {
            "per_poisoned_run": n_bundles,
            "byte_deterministic": byte_deterministic,
            "round_trip_ok": bool(round_trip_ok),
        },
        "identical_sim": identical_sim,
        "overhead": {
            "requests": stats_w.completed,
            "attributed_overhead_pct": 100 * overhead,
            "budget_pct": 100 * OVERHEAD_BUDGET,
            "safety_factor": SAFETY_FACTOR,
            "events_recorded": rec_w.bus.emitted,
            "spans_recorded": rec_w.trace.added,
            "replay_wall_s": wall_on,
        },
        "contract": contract,
    }


if __name__ == "__main__":
    bench_watchtower()
