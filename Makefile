PY ?= python

.PHONY: test test-stress ci example lint bench-reconfig bench-elastic \
        bench-migration bench-overlap bench-planner bench-paged \
        bench-scale bench-obs bench-disagg bench-watch bench-json docs

test:
	$(PY) -m pytest -x -q

# static checks: simulated-clock discipline (any serving/obs module that
# touches `time` must be swappable via CLOCKED_MODULE_NAMES)
lint:
	$(PY) scripts/check_clock_discipline.py

# the concurrency suite (threaded submitters vs async PREPARE commits),
# the paged-pool fragmentation stress, and the 10^5+-request simulated-
# clock replay (RUN_SLOW gates the `slow`-marked scale test), with
# faulthandler armed so a wedged run dumps every thread's stack
test-stress:
	PYTHONFAULTHANDLER=1 RUN_SLOW=1 $(PY) -m pytest -x -q \
		tests/test_concurrent_prepare.py tests/test_paged_stress.py \
		tests/test_scale.py

example:
	PYTHONPATH=src $(PY) examples/serve_intents.py

bench-reconfig:
	PYTHONPATH=src:. $(PY) benchmarks/reconfig_serving.py

bench-elastic:
	PYTHONPATH=src:. $(PY) benchmarks/elastic_scaling.py

bench-migration:
	PYTHONPATH=src:. $(PY) benchmarks/live_migration.py

bench-overlap:
	PYTHONPATH=src:. $(PY) benchmarks/overlap_prepare.py

bench-planner:
	PYTHONPATH=src:. $(PY) benchmarks/plan_search.py

bench-paged:
	PYTHONPATH=src:. $(PY) benchmarks/paged_batching.py

bench-scale:
	PYTHONPATH=src:. $(PY) benchmarks/scale_serving.py

bench-obs:
	PYTHONPATH=src:. $(PY) benchmarks/obs_overhead.py

bench-disagg:
	PYTHONPATH=src:. $(PY) benchmarks/disagg_serving.py

bench-watch:
	PYTHONPATH=src:. $(PY) benchmarks/watchtower.py

bench-json:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --check --only reconfig migration elastic overlap planner paged scale obs disagg watch

docs:
	$(PY) scripts/run_doc_examples.py

ci:
	bash scripts/ci.sh
