PY ?= python

.PHONY: test ci example bench-reconfig bench-elastic bench-migration \
        bench-json docs

test:
	$(PY) -m pytest -x -q

example:
	PYTHONPATH=src $(PY) examples/serve_intents.py

bench-reconfig:
	PYTHONPATH=src:. $(PY) benchmarks/reconfig_serving.py

bench-elastic:
	PYTHONPATH=src:. $(PY) benchmarks/elastic_scaling.py

bench-migration:
	PYTHONPATH=src:. $(PY) benchmarks/live_migration.py

bench-json:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only reconfig migration elastic

docs:
	$(PY) scripts/run_doc_examples.py

ci:
	bash scripts/ci.sh
