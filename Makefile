PY ?= python

.PHONY: test ci example bench-reconfig bench-elastic docs

test:
	$(PY) -m pytest -x -q

example:
	PYTHONPATH=src $(PY) examples/serve_intents.py

bench-reconfig:
	PYTHONPATH=src:. $(PY) benchmarks/reconfig_serving.py

bench-elastic:
	PYTHONPATH=src:. $(PY) benchmarks/elastic_scaling.py

docs:
	$(PY) scripts/run_doc_examples.py

ci:
	bash scripts/ci.sh
