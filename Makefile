PY ?= python

.PHONY: test ci example bench-reconfig

test:
	$(PY) -m pytest -x -q

example:
	PYTHONPATH=src $(PY) examples/serve_intents.py

bench-reconfig:
	PYTHONPATH=src:. $(PY) benchmarks/reconfig_serving.py

ci:
	bash scripts/ci.sh
