"""Property tests (real hypothesis when installed, else the deterministic
shim in tests/_hypothesis_compat.py) for the pure invariant kernels the
serving runtime leans on:

  * `sharding.merge_restrictions` — the single source of the constraint
    merge semantics: argument-order independence and fail-closed
    degradation of conflicting device pins;
  * the migration budget clamp (`serving/migration.needed_capacity`) —
    a migrated stream can NEVER extend beyond what the source pool could
    have produced, no matter how roomy the target is;
  * the paged KV pool (`serving/kvpool.PagedKVPool`) — arbitrary
    alloc/free interleavings never leak or double-hand-out a page, and
    OOM failures allocate nothing;
  * the continuous-batching compactor (`ServingEngine._compact`) —
    re-packing lanes preserves every request's (pos, pages, table row)
    association and their relative order;
  * the synthetic traffic generator (`repro.traffic.generator`) — same
    seed -> bitwise-identical trace, monotone arrival times, and a
    per-label mix that converges to the configured weights.
"""
import dataclasses

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.serving import Request
from repro.serving.kvpool import SCRATCH_PAGE, PagedKVPool, PoolOOM
from repro.serving.migration import needed_capacity, required_capacity
from repro.sharding import ShardingPlan, merge_restrictions, plan_satisfies
from repro.traffic import (FlashCrowd, LabelProfile, LongPromptFlood,
                           TrafficPattern, generate_trace)
from repro.traffic.generator import label_mix

settings.register_profile("repo", max_examples=50)
settings.load_profile("repo")

AXES = ("pod", "data", "model")


@st.composite
def plans(draw):
    """Restriction-only ShardingPlans over the production axis names."""
    pins = tuple((ax, draw(st.integers(0, 2)))
                 for ax in AXES if draw(st.booleans()))
    forbidden = tuple(ax for ax in AXES if draw(st.booleans()))
    return ShardingPlan(device_constraints=pins,
                        forbidden_collective_axes=forbidden)


# ---------------------------------------------------------------------------
# merge_restrictions
# ---------------------------------------------------------------------------


@given(base=plans(), r1=plans(), r2=plans())
def test_merge_restrictions_commutes_over_required_plans(base, r1, r2):
    """The merged outcome must not depend on the order constraints were
    presented (apply_policy merges ALL unsatisfied constraints at once;
    a different dict ordering must not change the resulting plan)."""
    assert merge_restrictions(base, r1, r2) == merge_restrictions(base, r2, r1)


@given(base=plans(), r1=plans(), r2=plans())
def test_merge_restrictions_conflicts_fail_closed(base, r1, r2):
    """Pins that disagree on an axis (with the base or between required
    plans) must degrade to forbidding that axis with NO pin: an engine
    asked to be in two places at once satisfies neither pinned
    constraint and the label rejects at routing time — never a silently
    chosen winner."""
    merged = merge_restrictions(base, r1, r2)
    merged_pins = dict(merged.device_constraints)
    sources = [dict(base.device_constraints), dict(r1.device_constraints),
               dict(r2.device_constraints)]
    for ax in AXES:
        coords = {src[ax] for src in sources if ax in src}
        if len(coords) > 1:               # conflicting pins
            assert ax not in merged_pins
            assert ax in merged.forbidden_collective_axes
        elif len(coords) == 1:            # agreeing pins survive verbatim
            assert merged_pins.get(ax) == coords.pop()
    # forbidden axes only ever accumulate
    for src in (base, r1, r2):
        assert set(src.forbidden_collective_axes) \
            <= set(merged.forbidden_collective_axes)
    # fail-closed end to end: a required plan whose pin was degraded is
    # NOT satisfied by the merge result
    for req in (r1, r2):
        degraded = [ax for ax, c in req.device_constraints
                    if dict(merged.device_constraints).get(ax) != c]
        if degraded:
            assert not plan_satisfies(merged, req)


@given(base=plans(), req=plans())
def test_merge_restrictions_satisfies_when_no_conflict(base, req):
    """Absent pin conflicts, merging a required plan into a base must
    produce a plan that actually satisfies it (this is what makes
    apply_policy's single-swap-per-engine strategy sound)."""
    base_pins = dict(base.device_constraints)
    conflict = any(base_pins.get(ax) not in (None, c)
                   for ax, c in req.device_constraints)
    merged = merge_restrictions(base, req)
    if not conflict:
        assert plan_satisfies(merged, req)


# ---------------------------------------------------------------------------
# migration budget clamp (serving/migration.py)
# ---------------------------------------------------------------------------


def _decoding_state(prompt_len, extra, max_new):
    """A consistent mid-decode request: prefill emitted one token at
    pos=prompt_len; ``extra`` decode steps followed."""
    req = Request(0, np.zeros(prompt_len, np.int32), max_new_tokens=max_new)
    req.tokens_out = [1] * (extra + 1)
    return req, prompt_len + extra


@given(prompt_len=st.integers(1, 40), extra=st.integers(0, 40),
       max_new=st.integers(1, 80), src_s_max=st.integers(8, 64))
def test_budget_clamp_decoding_never_extends_stream(prompt_len, extra,
                                                    max_new, src_s_max):
    """For any mid-decode state valid on the source pool, the capacity
    requirement never exceeds the source's own ``s_max`` — so a roomier
    target can never emit a token the unmigrated run would not have."""
    prompt_len = min(prompt_len, src_s_max - 2)
    extra = min(extra, src_s_max - 2 - prompt_len, max(max_new - 1, 0))
    req, pos = _decoding_state(prompt_len, extra, max_new)

    need = needed_capacity(req, "decoding", pos, src_s_max)
    assert need <= src_s_max              # the source itself always fits
    assert need >= pos + 1                # state already written fits too
    # the clamped remaining budget obeys BOTH the request's own budget
    # and the source pool's stop rule (slot_pos >= s_max - 1)
    rem = need - pos - 1
    assert 0 <= rem <= max(max_new - len(req.tokens_out), 0)
    assert pos + rem <= src_s_max - 1
    # total stream length never exceeds the unmigrated run's
    assert len(req.tokens_out) + rem <= max(max_new, len(req.tokens_out))


@given(prompt_len=st.integers(1, 40), max_new=st.integers(1, 80),
       src_s_max=st.integers(8, 64))
def test_budget_clamp_queued_never_extends_stream(prompt_len, max_new,
                                                  src_s_max):
    """Queued (not yet prefilled) requests carry the same guarantee: the
    requirement covers prompt + clamped generation, within the source."""
    prompt_len = min(prompt_len, src_s_max - 1)
    req = Request(0, np.zeros(prompt_len, np.int32), max_new_tokens=max_new)

    need = needed_capacity(req, "queued", prompt_len, src_s_max)
    assert prompt_len + 1 <= need <= src_s_max
    rem = need - prompt_len
    assert rem <= max(max_new, 1)


@given(prompt_len=st.integers(1, 30), extra=st.integers(0, 30),
       max_new=st.integers(1, 60),
       src_s_max=st.integers(8, 64), dst_s_max=st.integers(8, 64))
def test_budget_clamp_import_decision_is_monotone(prompt_len, extra,
                                                  max_new, src_s_max,
                                                  dst_s_max):
    """`required_capacity` (what import_slot fails closed on) equals the
    pre-flight `needed_capacity`, and a target at least as roomy as the
    source is ALWAYS admissible — migration onto equal-or-bigger pools
    cannot fail the capacity check."""
    from repro.serving.migration import SlotSnapshot

    prompt_len = min(prompt_len, src_s_max - 2)
    extra = min(extra, src_s_max - 2 - prompt_len, max(max_new - 1, 0))
    req, pos = _decoding_state(prompt_len, extra, max_new)
    need = needed_capacity(req, "decoding", pos, src_s_max)

    snap = SlotSnapshot(rid=0, request=req, phase="decoding", pos=pos,
                        kv=None, src_s_max=src_s_max)
    assert required_capacity(snap) == need
    if dst_s_max >= src_s_max:
        assert need <= dst_s_max          # equal-or-bigger always admits


# ---------------------------------------------------------------------------
# paged KV pool (serving/kvpool.py)
# ---------------------------------------------------------------------------


@st.composite
def pool_traces(draw):
    """(n_pages, watermark, ops): a random interleaving of allocations
    (tokens to admit, reserve flag) and frees (which live allocation)."""
    n_pages = draw(st.integers(2, 12))
    watermark = draw(st.integers(0, n_pages - 1))
    ops = []
    for _ in range(draw(st.integers(1, 30))):
        if draw(st.booleans()):
            ops.append(("alloc", draw(st.integers(1, 40)),
                        draw(st.booleans())))
        else:
            ops.append(("free", draw(st.integers(0, 1 << 30)), False))
    return n_pages, watermark, ops


@given(trace=pool_traces())
def test_pool_alloc_free_never_leaks(trace):
    """Whatever the alloc/free interleaving: every page is either free
    or owned by exactly one live allocation, OOM allocates nothing, and
    returning every live allocation restores the pool to pristine."""
    n_pages, watermark, ops = trace
    pool = PagedKVPool(page_size=8, n_pages=n_pages, watermark=watermark)
    live = []                             # list of page-id lists
    for kind, arg, reserve in ops:
        if kind == "alloc":
            n = pool.pages_for(arg)
            before = pool.free_pages
            try:
                got = pool.alloc(n, reserve=reserve)
            except PoolOOM:
                assert pool.free_pages == before    # took nothing
                budget = before - (0 if reserve else watermark)
                assert n > max(budget, 0)           # refusal was justified
            else:
                assert len(got) == n
                assert pool.free_pages == before - n
                if not reserve:           # admission respected the mark
                    assert pool.free_pages >= watermark or n == 0
                live.append(got)
        elif live:
            pool.free(live.pop(arg % len(live)))
        # conservation: free + live partitions the data pages exactly
        held = [p for alloc in live for p in alloc]
        assert len(held) == len(set(held))          # no double hand-out
        assert SCRATCH_PAGE not in held
        assert pool.free_pages + len(held) == n_pages
        assert pool.allocated_tokens == len(held) * pool.page_size
    for alloc in live:
        pool.free(alloc)
    assert pool.free_pages == n_pages
    assert pool.allocated_tokens == 0


# ---------------------------------------------------------------------------
# continuous-batching compactor (ServingEngine._compact)
# ---------------------------------------------------------------------------


@st.composite
def occupancies(draw):
    """(n_slots, pages_per_seq, lanes): a random lane occupancy, each
    active lane holding (rid, pos, pages)."""
    n_slots = draw(st.integers(1, 8))
    npp = draw(st.integers(1, 4))
    next_page = 1
    lanes = []
    for _ in range(n_slots):
        if draw(st.booleans()):
            n_pg = draw(st.integers(1, npp))
            pages = list(range(next_page, next_page + n_pg))
            next_page += n_pg
            lanes.append((draw(st.integers(0, 99)),
                          draw(st.integers(0, npp * 8 - 1)), pages))
        else:
            lanes.append(None)
    return n_slots, npp, lanes


@given(occ=occupancies())
def test_compaction_preserves_per_request_state(occ):
    """`_compact` must move each request's pos, page list and page-table
    row TOGETHER into the lane prefix, preserving relative order —
    packing reorders lanes, never a request's token stream."""
    from repro.serving.engine import ServingEngine

    n_slots, npp, lanes = occ

    class Eng:                            # just the state _compact touches
        pass

    eng = Eng()
    eng.n_slots = n_slots
    eng.slot_req = [(None if l is None else ("req", l[0])) for l in lanes]
    eng.slot_pos = np.zeros(n_slots, np.int32)
    eng.slot_pages = [[] if l is None else list(l[2]) for l in lanes]
    eng.page_tables = np.full((n_slots, npp), SCRATCH_PAGE, np.int32)
    for i, l in enumerate(lanes):
        if l is not None:
            eng.slot_pos[i] = l[1]
            eng.page_tables[i, :len(l[2])] = l[2]

    ServingEngine._compact(eng)

    active = [l for l in lanes if l is not None]
    n = len(active)
    # the prefix holds the active requests in their original order...
    for lane, (rid, pos, pages) in enumerate(active):
        assert eng.slot_req[lane] == ("req", rid)
        assert int(eng.slot_pos[lane]) == pos
        assert eng.slot_pages[lane] == pages
        row = list(eng.page_tables[lane])
        assert row[:len(pages)] == pages          # table row traveled too
        assert all(p == SCRATCH_PAGE for p in row[len(pages):])
    # ...and everything past it is cleared to the inactive state
    for lane in range(n, n_slots):
        assert eng.slot_req[lane] is None
        assert int(eng.slot_pos[lane]) == 0
        assert eng.slot_pages[lane] == []
        assert all(p == SCRATCH_PAGE for p in eng.page_tables[lane])


# ---------------------------------------------------------------------------
# synthetic traffic generator (repro/traffic/generator.py)
# ---------------------------------------------------------------------------


@st.composite
def traffic_patterns(draw, adversarial=True):
    """Random `TrafficPattern`s: 1-3 weighted labels, diurnal swing,
    optionally a (label-pinned) flash crowd and a long-prompt flood."""
    n_labels = draw(st.integers(1, 3))
    labels = {f"l{i}": LabelProfile(weight=float(draw(st.integers(1, 5))),
                                    new_tokens_mean=1.0
                                    + draw(st.integers(0, 4)))
              for i in range(n_labels)}
    crowds, floods = (), ()
    if adversarial and draw(st.booleans()):
        crowds = (FlashCrowd(
            t_start=float(draw(st.integers(0, 20))),
            duration_s=float(draw(st.integers(1, 10))),
            multiplier=float(draw(st.integers(2, 5))),
            label=draw(st.sampled_from([None] + sorted(labels)))),)
    if adversarial and draw(st.booleans()):
        floods = (LongPromptFlood(
            t_start=float(draw(st.integers(0, 20))),
            duration_s=float(draw(st.integers(1, 10))),
            rate=float(draw(st.integers(1, 10))),
            label=draw(st.sampled_from(sorted(labels)))),)
    return TrafficPattern(
        duration_s=30.0, base_rate=float(draw(st.integers(5, 40))),
        labels=labels,
        diurnal_amplitude=draw(st.integers(0, 8)) / 10.0,
        flash_crowds=crowds, floods=floods,
        seed=draw(st.integers(0, 2**31 - 1)))


@given(pattern=traffic_patterns())
def test_trace_same_seed_bitwise_identical(pattern):
    """ACCEPTANCE: a pattern is a pure function of its seed — two
    independent generations agree on every field of every request."""
    a, b = generate_trace(pattern), generate_trace(pattern)
    assert a == b                         # frozen dataclasses: exact
    # ...and a different seed actually moves the trace (not a constant)
    other = dataclasses.replace(pattern, seed=pattern.seed ^ 1)
    assert generate_trace(other) != a


@given(pattern=traffic_patterns())
def test_trace_arrivals_monotone_and_well_formed(pattern):
    """Arrival times are monotone non-decreasing within [0, duration),
    rids are dense in arrival order, and every shape respects its
    label's profile (bucketed prompts, capped decode budgets)."""
    trace = generate_trace(pattern)
    flood_shapes = {(f.label, f.prompt_len, f.new_tokens)
                    for f in pattern.floods}
    prev = 0.0
    for i, r in enumerate(trace):
        assert r.rid == i
        assert r.t >= prev
        assert 0.0 <= r.t < pattern.duration_s
        prev = r.t
        prof = pattern.labels[r.label]
        if (r.label, r.prompt_len, r.new_tokens) not in flood_shapes:
            assert r.prompt_len in prof.prompt_buckets
            assert 1 <= r.new_tokens <= prof.new_tokens_cap


@given(pattern=traffic_patterns(adversarial=False),
       _seed_bump=st.integers(0, 1000))
def test_trace_label_mix_matches_weights(pattern, _seed_bump):
    """Without label-skewing events (crowds/floods), the empirical
    per-label mix converges to the normalized profile weights (diurnal
    modulation scales all labels equally, so it cannot skew the mix)."""
    pattern = dataclasses.replace(pattern, base_rate=60.0,
                                  seed=pattern.seed + _seed_bump)
    trace = generate_trace(pattern)
    assert len(trace) > 1000              # enough mass for the tolerance
    total = sum(p.weight for p in pattern.labels.values())
    mix = label_mix(trace)
    for name, prof in pattern.labels.items():
        assert abs(mix.get(name, 0.0) - prof.weight / total) < 0.05
