"""Fallback property-testing shim: use real `hypothesis` when installed,
otherwise a tiny deterministic-random stand-in so the tier-1 suite still
*collects and runs* in minimal containers (install requirements-dev.txt to
get full shrinking/coverage).

Only the surface this repo's tests use is implemented: `given` (kwargs),
`settings.register_profile/load_profile(max_examples=, deadline=)`,
`st.sampled_from`, `st.booleans`, `st.integers(lo, hi)`, `st.data()` and
`@st.composite`. Draws come from a per-test seeded `random.Random`, so runs
are reproducible; each test executes `max_examples` sampled cases.
"""
from __future__ import annotations

try:                                     # pragma: no cover - passthrough
    from hypothesis import given, settings, strategies  # noqa: F401
    st = strategies
except ImportError:
    import functools
    import inspect
    import random as _random
    from types import SimpleNamespace

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class _DataObject:
        """The `st.data()` interactive-draw handle."""

        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy):
            return strategy.draw(self._rng)

    def _sampled_from(seq):
        options = list(seq)
        return _Strategy(lambda rng: options[rng.randrange(len(options))])

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _integers(min_value=0, max_value=(1 << 31) - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _data():
        return _Strategy(lambda rng: _DataObject(rng))

    def _composite(fn):
        @functools.wraps(fn)
        def make(*args, **kw):
            return _Strategy(
                lambda rng: fn(lambda s: s.draw(rng), *args, **kw))
        return make

    st = SimpleNamespace(sampled_from=_sampled_from, booleans=_booleans,
                         integers=_integers, data=_data,
                         composite=_composite)
    strategies = st

    class settings:  # noqa: N801 - mirrors the hypothesis API name
        _profiles = {"default": {"max_examples": 20}}
        _active = "default"

        def __init__(self, **kw):
            self._kw = kw

        def __call__(self, fn):          # used as a decorator
            fn._hc_settings = self._kw
            return fn

        @classmethod
        def register_profile(cls, name, **kw):
            cls._profiles[name] = kw

        @classmethod
        def load_profile(cls, name):
            cls._active = name

        @classmethod
        def current(cls):
            return cls._profiles.get(cls._active, {})

    def given(**strategies_kw):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kw):
                conf = dict(settings.current())
                conf.update(getattr(fn, "_hc_settings", {}))
                n = int(conf.get("max_examples", 20))
                rng = _random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = {k: s.draw(rng)
                             for k, s in strategies_kw.items()}
                    fn(*args, **drawn, **kw)

            # hide the drawn parameters from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies_kw])
            del wrapper.__wrapped__
            return wrapper
        return deco
