"""Fault tolerance: checkpoint/restart, failure recovery, stragglers,
deterministic data restart, optimizer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.checkpoint.store import latest_step
from repro.configs import get_reduced_config
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamW, warmup_cosine
from repro.runtime import TrainRunner


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_reduced_config("minitron_4b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = AdamW(lr=warmup_cosine(1e-3, 5, 100))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    ds = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)
    return model, params, opt_state, step, ds


def test_checkpoint_roundtrip(tmp_path, tiny_setup):
    _, params, opt_state, _, _ = tiny_setup
    save_checkpoint(tmp_path, 3, {"params": params, "opt": opt_state})
    step, restored = load_checkpoint(tmp_path, {"params": params,
                                                "opt": opt_state})
    assert step == 3
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path, tiny_setup):
    _, params, _, _, _ = tiny_setup
    for s in range(6):
        save_checkpoint(tmp_path, s, {"p": params}, keep=2)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [4, 5]
    assert latest_step(tmp_path) == 5


def test_loss_decreases(tmp_path, tiny_setup):
    _, params, opt_state, step_fn, ds = tiny_setup
    runner = TrainRunner(step_fn=step_fn, params=params, opt_state=opt_state,
                         dataset=ds, ckpt_dir=tmp_path, ckpt_every=50)
    out = runner.run(30)
    first = np.mean(runner.losses[:5])
    last = np.mean(runner.losses[-5:])
    assert last < first, f"loss did not decrease: {first} -> {last}"


def test_failure_recovery_resumes_from_checkpoint(tmp_path, tiny_setup):
    _, params, opt_state, step_fn, ds = tiny_setup
    runner = TrainRunner(step_fn=step_fn, params=params, opt_state=opt_state,
                         dataset=ds, ckpt_dir=tmp_path, ckpt_every=5)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        runner.run(20, fail_at=13)
    assert latest_step(tmp_path) == 10          # last periodic checkpoint
    out = runner.recover_and_run(20)
    assert out["steps"] == 20
    assert out["restarts"] == 1


def test_straggler_detection(tmp_path, tiny_setup):
    _, params, opt_state, step_fn, ds = tiny_setup
    flagged = []
    runner = TrainRunner(step_fn=step_fn, params=params, opt_state=opt_state,
                         dataset=ds, ckpt_dir=tmp_path, ckpt_every=100,
                         mitigation_hook=lambda rep: flagged.append(rep))
    runner.run(12, slow_steps={8: 1.5})
    assert any(r.step == 8 for r in runner.monitor.flagged)
    assert flagged and flagged[0].slowdown > 2.0


def test_data_pipeline_deterministic_restart():
    ds = SyntheticLM(vocab_size=256, seq_len=16, global_batch=4, seed=1)
    b1 = ds.batch_at(7)
    b2 = ds.batch_at(7)   # "restarted" stream
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # mask excludes BOS targets
    assert float(b1["loss_mask"].min()) in (0.0, 1.0)
    assert b1["tokens"].shape == (4, 17)


def test_adamw_optimizes_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 5.0}
    state = opt.init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_grad_compression_error_feedback():
    from repro.optim.compress import (compress_grads_int8,
                                      decompress_grads_int8, init_residual)
    g = {"w": jnp.linspace(-1, 1, 1000)}
    res = init_residual(g)
    acc = jnp.zeros_like(g["w"])
    true = jnp.zeros_like(g["w"])
    for _ in range(20):
        q, scales, res = compress_grads_int8(g, res)
        acc = acc + decompress_grads_int8(q, scales)["w"]
        true = true + g["w"]
    # error feedback keeps the long-run mean unbiased
    err = float(jnp.max(jnp.abs(acc - true))) / 20
    assert err < 1e-2


def test_elastic_restore_onto_new_sharding(tmp_path, tiny_setup):
    """Checkpoint saved under one layout restores under explicit shardings
    (single-device here, but exercising the device_put path)."""
    _, params, opt_state, _, _ = tiny_setup
    save_checkpoint(tmp_path, 1, {"params": params})
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        {"params": params})
    step, restored = load_checkpoint(tmp_path, {"params": params}, shardings=sh)
    leaf = jax.tree.leaves(restored["params"])[0]
    assert leaf.sharding.mesh.axis_names == ("data",)
