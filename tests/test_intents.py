"""Intent pipeline tests: corpus accuracy, failure modes, and property-based
invariants of the satisfaction relation."""
import dataclasses

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    CORPUS,
    Component,
    Configuration,
    DEFAULT_WORKLOAD,
    DeterministicInterpreter,
    FaultyInterpreter,
    Flow,
    Intent,
    Orchestrator,
    PlacementConstraint,
    RoutingConstraint,
    build_fabric,
    compile_intent,
    satisfies,
    validate,
)

settings.register_profile("intents", max_examples=25, deadline=None)
settings.load_profile("intents")


def test_corpus_distribution():
    assert len(CORPUS) == 90
    by_domain = {d: sum(1 for e in CORPUS if e.domain == d)
                 for d in ("computing", "networking", "hybrid")}
    assert by_domain == {"computing": 30, "networking": 30, "hybrid": 30}
    assert sum(1 for e in CORPUS if e.complexity == "simple") == 38
    assert sum(1 for e in CORPUS if e.complexity == "complex") == 52


def test_corpus_full_accuracy_deterministic_backend():
    orch = Orchestrator()
    correct = 0
    for e in CORPUS:
        r = orch.submit(e.text)
        outcome = "enforce" if r.success else "fail-closed"
        correct += (outcome == e.expect)
    assert correct == 90, f"deterministic backend accuracy {correct}/90"


def test_faulty_backend_degrades_and_is_detected():
    """Injected failure modes (paper §6.3) must (a) be partly rejected at
    runtime by the fail-closed validator (hallucinated labels, empty paths)
    and (b) be fully visible to the benchmark validator, which — like the
    paper's — checks the corpus's GOLD assertions, catching the
    partial-topology class that a runtime self-check cannot see."""
    orch = Orchestrator(interpreter=FaultyInterpreter(rate=1.0))
    det = DeterministicInterpreter()
    rejected = 0
    gold_violations = 0
    benchmark_success = 0
    for e in CORPUS:
        r = orch.submit(e.text)
        if not r.report.passed:
            rejected += 1
            continue
        gold = det.interpret(e.text, orch.fabric, orch.components).intent
        ok, _ = satisfies(gold, r.policy.config, orch.fabric, orch.components)
        gold_violations += (not ok)
        benchmark_success += ok
    assert rejected > 0, "no injected fault caught at runtime (fail-closed)"
    # benchmark accuracy must be strictly below the deterministic backend's
    faulty_acc = benchmark_success / 90
    assert faulty_acc < 1.0
    # and every applied-but-wrong config is DETECTED by gold validation
    assert rejected + gold_violations + benchmark_success == 90


def test_unenforceable_intent_fails_closed():
    orch = Orchestrator()
    r = orch.submit("Prohibit financial database service deployment in the "
                    "cloud zone.")
    assert not r.success
    assert any("unenforceable" in c.detail or "no component" in c.detail
               for c in r.report.checks if not c.passed)


def test_hallucinated_label_fails_closed():
    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    intent = Intent(
        text="keep PHI in the EU", domain="computing", complexity="simple",
        placement=(PlacementConstraint(
            selector=(("data-type", "phi"),),
            require=(("region", "eu_region"),)),))
    policy = compile_intent(intent, fabric, DEFAULT_WORKLOAD,
                            base_placement={c.name: 0 for c in DEFAULT_WORKLOAD})
    report = validate(policy, fabric, DEFAULT_WORKLOAD)
    assert not report.passed
    assert any("eu_region" in c.detail for c in report.checks if not c.passed)


def test_empty_path_triple_fails_closed():
    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    intent = Intent(
        text="traffic must traverse the backup switch", domain="networking",
        complexity="simple",
        routing=(RoutingConstraint(
            flow=Flow("nonexistent-src", "nonexistent-dst"),
            waypoints=("backup",)),))
    policy = compile_intent(intent, fabric, DEFAULT_WORKLOAD, base_placement={})
    report = validate(policy, fabric, DEFAULT_WORKLOAD)
    assert not report.passed


def test_pod_confinement_colocates_and_validates():
    orch = Orchestrator()
    r = orch.submit("Phi traffic must remain inside the pod and avoid huawei "
                    "switches.")
    assert r.success, [c.detail for c in r.report.checks if not c.passed]
    phi = [c.name for c in DEFAULT_WORKLOAD if c.labels["data-type"] == "phi"]
    pods = {orch.state.placement[n] for n in phi}
    assert len(pods) == 1, "phi components not co-located"


# ---------------------------------------------------------------------------
# property-based invariants
# ---------------------------------------------------------------------------

LABEL_KEYS = ["zone", "security", "provider", "region"]
LABEL_VALS = {
    "zone": ["cloud", "edge"], "security": ["high", "medium", "low"],
    "provider": ["aws", "azure"], "region": ["eu", "us"],
}


@st.composite
def placement_constraints(draw):
    key = draw(st.sampled_from(LABEL_KEYS))
    val = draw(st.sampled_from(LABEL_VALS[key]))
    dtype = draw(st.sampled_from(["phi", "general"]))
    as_forbid = draw(st.booleans())
    return PlacementConstraint(
        selector=(("data-type", dtype),),
        require=() if as_forbid else ((key, val),),
        forbid=((key, val),) if as_forbid else ())


@given(pc=placement_constraints())
def test_compile_then_satisfy_or_fail_closed(pc):
    """INVARIANT: whatever the compiler APPLIES satisfies the intent; when
    it cannot, it must record an error (never silently mis-place)."""
    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    intent = Intent("prop", "computing", "simple", placement=(pc,))
    policy = compile_intent(intent, fabric, DEFAULT_WORKLOAD,
                            base_placement={c.name: 0 for c in DEFAULT_WORKLOAD})
    ok, msgs = satisfies(intent, policy.config, fabric, DEFAULT_WORKLOAD)
    assert ok or policy.errors, f"silent violation: {msgs}"


@given(pc=placement_constraints(), pod=st.sampled_from([0, 1]))
def test_satisfaction_is_label_monotone(pc, pod):
    """INVARIANT: a constraint holds for a site iff require ⊆ λ and
    forbid ∩ λ = ∅ — cross-checked against a direct evaluation."""
    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    labels = fabric.pod_labels(pod)
    from repro.core.labels import match_labels
    expected = (all(match_labels(labels, {k: v}) for k, v in pc.require)
                and not any(match_labels(labels, {k: v}) for k, v in pc.forbid))
    assert pc.holds_for_site(labels) == expected


@given(data=st.data())
def test_pathfinder_respects_forbid_and_waypoints(data):
    """INVARIANT: any path returned by the constrained search contains every
    waypoint and no forbidden transit vertex."""
    from repro.core import pathfinder
    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    rows = 16
    src = f"pod0/host{data.draw(st.integers(0, rows - 1))}"
    dst = f"pod1/host{data.draw(st.integers(0, rows - 1))}"
    vendor = data.draw(st.sampled_from(["huawei", "cisco", "juniper"]))
    wp = f"pod0/sw_r{data.draw(st.integers(0, rows - 1))}"
    path = pathfinder.find_path(fabric, src, dst,
                                forbid=(("mfr", vendor),), waypoints=(wp,))
    if path is None:
        return  # infeasible is acceptable; silently-bad paths are not
    assert wp in path
    exempt = pathfinder.exempt_set(fabric, src, dst, wp)
    for vid in path:
        if vid in exempt:
            continue
        assert fabric.vertex_labels(vid).get("mfr") != vendor


# ---------------------------------------------------------------------------
# service-level constraints (Φ_L): parsing, compilation, fail-closed checks
# ---------------------------------------------------------------------------


def test_service_level_clause_parses_to_slo_target():
    orch = Orchestrator()
    res = orch.submit("Keep TTFT under 200 ms for phi traffic.")
    assert res.success, res.report.summary()
    intent = res.policy.intent
    assert len(intent.service) == 1
    sc = intent.service[0]
    assert dict(sc.selector) == {"data-type": "phi"}
    assert sc.max_ttft_s == pytest.approx(0.2)
    assert sc.max_tpot_s is None
    assert res.policy.slo_targets == {"phi": (pytest.approx(0.2), None)}


def test_service_level_tpot_seconds_and_intersection():
    orch = Orchestrator()
    res = orch.submit("Per-token latency below 0.05 seconds for the "
                      "patient service, and keep TTFT under 150 ms for "
                      "patient records.")
    assert res.success, res.report.summary()
    # both clauses resolve to the patient component's phi routing label
    ttft, tpot = res.policy.slo_targets["phi"]
    assert ttft == pytest.approx(0.15)
    assert tpot == pytest.approx(0.05)


def test_service_level_unknown_workload_fails_closed():
    orch = Orchestrator()
    res = orch.submit("Keep TTFT under 100 ms for the billing service.")
    assert not res.applied
    assert any(not c.passed for c in res.report.checks)


def test_latency_clause_without_metric_or_subject_emits_nothing():
    from repro.core import DeterministicInterpreter
    from repro.core.labels import build_fabric
    from repro.core.intents import DEFAULT_WORKLOAD

    be = DeterministicInterpreter()
    fabric = build_fabric((2, 4, 4), ("pod", "data", "model"))
    # a time bound with no recognized latency metric is not an SLO
    r1 = be.interpret("Answer within 200 ms.", fabric, DEFAULT_WORKLOAD)
    assert r1.intent.service == ()
    # a metric with no workload subject cannot attach to a label
    r2 = be.interpret("Keep TTFT under 200 ms.", fabric, DEFAULT_WORKLOAD)
    assert r2.intent.service == ()


def test_two_metrics_two_bounds_bind_independently():
    """"TTFT under 200 ms and TPOT under 20 ms" in ONE clause must not
    relax the TPOT promise to the TTFT number."""
    orch = Orchestrator()
    res = orch.submit("Keep TTFT under 200 ms and TPOT under 20 ms "
                      "for phi traffic.")
    assert res.success, res.report.summary()
    ttft, tpot = res.policy.slo_targets["phi"]
    assert ttft == pytest.approx(0.2)
    assert tpot == pytest.approx(0.02)


def test_first_token_latency_is_ttft_not_tpot():
    """"first token latency" is a TTFT phrasing; it must not also
    install a spurious per-token target."""
    orch = Orchestrator()
    res = orch.submit("Keep first token latency under 200 ms for phi "
                      "traffic.")
    assert res.success, res.report.summary()
    assert res.policy.slo_targets["phi"] == (pytest.approx(0.2), None)
