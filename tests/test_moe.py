"""MoE layer invariants (hypothesis property tests)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_reduced_config
from repro.configs.base import MoEConfig
from repro.models import mlp
from repro.models.common import act_fn

settings.register_profile("moe", max_examples=10, deadline=None)
settings.load_profile("moe")


def _moe_cfg(E=8, k=2, d=32, ff=48, shared=0):
    base = get_reduced_config("qwen2_moe_a2_7b")
    return dataclasses.replace(
        base, d_model=d, param_dtype="float32", activ_dtype="float32",
        moe=MoEConfig(num_experts=E, top_k=k, d_expert=ff,
                      num_shared_experts=shared, d_shared=ff,
                      norm_topk_prob=True))


def _dense_reference(cfg, p, x):
    """Per-token dense evaluation of the same experts — the dropless oracle."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / w.sum(-1, keepdims=True)
    act = act_fn(cfg.mlp_act)
    out = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        acc = jnp.zeros((d,))
        for j in range(m.top_k):
            e = int(idx[t, j])
            h = act(xt[t] @ p["w_gate"][e]) * (xt[t] @ p["w_up"][e])
            acc = acc + w[t, j] * (h @ p["w_down"][e])
        out = out.at[t].set(acc)
    if m.num_shared_experts:
        out = out + mlp.mlp(cfg, p["shared"], xt)
    return out.reshape(B, S, d)


@given(E=st.sampled_from([4, 8]), k=st.sampled_from([1, 2]),
       shared=st.sampled_from([0, 1]))
def test_small_group_moe_is_exact(E, k, shared):
    cfg = _moe_cfg(E=E, k=k, shared=shared)
    p = mlp.init_moe(cfg, jax.random.PRNGKey(E + k))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    out, aux = mlp.moe_ffn(cfg, p, x)
    ref = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    assert jnp.isfinite(aux)


def test_expert_padding_never_routed():
    """Padded experts (idx >= num_experts) must receive zero weight."""
    cfg = _moe_cfg(E=5, k=2)   # padded to 16
    p = mlp.init_moe(cfg, jax.random.PRNGKey(0))
    assert p["w_up"].shape[0] == mlp.padded_experts(5) == 16
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, _ = mlp.moe_ffn(cfg, p, x)
    # zero the pad experts' weights -> output must be identical
    p2 = dict(p)
    for key in ("w_up", "w_gate", "w_down"):
        p2[key] = p[key].at[5:].set(0.0)
    out2, _ = mlp.moe_ffn(cfg, p2, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_group_padding_tokens_dropped():
    """T not a multiple of the group size: padded tokens must not affect
    real outputs."""
    cfg = _moe_cfg(E=4, k=2)
    p = mlp.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 7, cfg.d_model))
    out, _ = mlp.moe_ffn(cfg, p, x)
    ref = _dense_reference(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
