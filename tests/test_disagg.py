"""Disaggregated prefill/decode serving: role-tagged engines, the
router's decode-exclusion, the first-token handoff through the batched
migration path (bitwise stream identity), per-role accounting, the
backlog-aware demand forecast, the capacity-view contracts the
autoscaler's rebalance decision depends on, and the planner-level
choice between disaggregated and unified configurations.
"""
import math

import numpy as np
import pytest
from conftest import baseline_streams as _baseline_streams
from conftest import make_engine as _mk
from conftest import make_request

from repro.obs import Recorder, SLOLedger, recording
from repro.planner import (
    EngineSpec,
    LabelDemand,
    TrafficMix,
    WorkloadPlanner,
    best_candidate,
    calibrate_host_profile,
    estimate,
    estimate_disagg,
    features_from_engine,
    prefill_interference,
    score_current,
)
from repro.planner.search import demand_from_tracker
from repro.serving import Request, RoutingError, ServingCluster
from repro.serving.kvpool import PagedKVPool
from repro.sharding import default_plan


# ---------------------------------------------------------------------------
# roles + routing
# ---------------------------------------------------------------------------


def test_engine_role_validation(fp32_model):
    _, model, params = fp32_model
    eng = _mk(model, params, role="prefill")
    assert eng.role == "prefill"
    with pytest.raises(ValueError):
        eng.role = "verifier"
    with pytest.raises(ValueError):
        _mk(model, params, role="Prefill")
    with pytest.raises(ValueError):
        EngineSpec(plan=default_plan(), role="draft")


def test_decode_engines_never_take_new_requests(fp32_model):
    """The router excludes decode-role engines from NEW admissions; a
    label served only by decode engines fails closed."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(0)
    cluster = ServingCluster()
    cluster.register("dc", _mk(model, params), role="decode")
    with pytest.raises(RoutingError):
        cluster.submit(make_request(rng, cfg, 0))
    assert [r.rid for r in cluster.rejected] == [0]
    cluster.register("pf", _mk(model, params), role="prefill")
    assert cluster.submit(make_request(rng, cfg, 1)) == "pf"


def test_handoff_streams_bitwise_identical_with_accounting(fp32_model):
    """THE TENTPOLE PROPERTY: requests admitted to a prefill engine are
    handed off at first token to the decode engine and their streams are
    bitwise identical to the unified oracle — with the handoff showing
    up as first-class obs spans/events, a dedicated SLO-ledger pause
    cause (never double-counted as plain migration), per-role completion
    counts, and per-role metrics_by_label entries."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 7, 6, 8)]
    expect = _baseline_streams(model, params, prompts, new=8)

    with recording(Recorder()) as rec:
        cluster = ServingCluster()
        cluster.register("pf", _mk(model, params, n_slots=4),
                         role="prefill")
        cluster.register("dc", _mk(model, params, n_slots=4),
                         role="decode")
        reqs = [Request(i, p, max_new_tokens=8)
                for i, p in enumerate(prompts)]
        for r in reqs:
            assert cluster.submit(r) == "pf"
        cluster.step()                        # prefill + first token
        # the handoff ran inside step(): every request now decodes on dc
        assert cluster.engine("pf").load == 0
        assert cluster.engine("dc").load == 4
        cluster.run()

    assert {r.rid: list(r.tokens_out) for r in reqs} == expect

    # events: migration.pause carries reason="handoff"; the cluster
    # emits one cohort-level cluster.handoff summary
    pauses = rec.events("migration.pause")
    assert pauses and all(e.data["reason"] == "handoff" for e in pauses)
    (cohort,) = rec.events("cluster.handoff")
    assert cohort.data["moved"] == 4
    assert any(s.name == "migration.pause" for s in rec.trace.spans())

    # ledger: pauses land under "handoff", not "migration"
    ledger = SLOLedger().consume(rec.events())
    acct = ledger.pause_accounting()
    assert acct["handoff"]["count"] == len(pauses)
    assert acct["migration"]["count"] == 0
    assert acct["handoff"]["total_s"] == pytest.approx(
        sum(e.data["pause_s"] for e in pauses))
    # completions happened on the decode tier
    assert ledger.completed_by_role() == {"decode": 4}
    # per-role metrics surface in the cluster's label folds
    m = cluster.metrics_by_label()
    assert m["role:decode"]["completed"] == 4
    assert "role:prefill" not in m        # prefill tier completed nothing


def test_handoff_respects_decode_capacity(fp32_model):
    """With a decode tier too small for the whole cohort, only what fits
    moves; the rest keep decoding on the prefill engine (never dropped,
    never truncated)."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(4)]
    expect = _baseline_streams(model, params, prompts, new=8)
    cluster = ServingCluster()
    cluster.register("pf", _mk(model, params, n_slots=4), role="prefill")
    cluster.register("dc", _mk(model, params, n_slots=2), role="decode")
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        cluster.submit(r)
    cluster.step()
    assert cluster.engine("dc").load == 2     # only the free slots moved
    assert cluster.engine("pf").load == 2
    cluster.run()
    assert {r.rid: list(r.tokens_out) for r in reqs} == expect


# ---------------------------------------------------------------------------
# capacity-view contracts (autoscaler rebalance-over-spawn inputs)
# ---------------------------------------------------------------------------


def test_free_tokens_never_negative_after_watermark_dip(fp32_model):
    """A migration import may spend the watermark headroom; the engine's
    admission-capacity views must clamp at zero instead of going
    negative and hiding peer capacity from the rebalance sum."""
    _, model, params = fp32_model
    eng = _mk(model, params, n_slots=4, s_max=32, page_size=8)
    eng.pool.watermark = 2
    pages = eng.pool.alloc(eng.pool.free_pages - 1, reserve=True)
    assert eng.pool.free_pages < eng.pool.watermark
    assert eng.free_tokens == 0
    assert eng.kv_token_capacity >= 0
    eng.pool.free(pages)
    assert eng.free_tokens > 0


def test_kv_token_capacity_clamps_degenerate_watermark():
    """The pool itself rejects watermark >= n_pages, but the engine-side
    contract is pinned independently: capacity is never negative."""
    pool = PagedKVPool(page_size=8, n_pages=4, watermark=3)
    assert (pool.n_pages - pool.watermark) * pool.page_size >= 0
    assert pool.admittable_pages >= 0
    with pytest.raises(ValueError):
        PagedKVPool(page_size=8, n_pages=4, watermark=4)


def test_cluster_kv_utilization_excludes_draining(fp32_model):
    """Retired-but-unreaped (draining) engines are not routable capacity
    — their residual allocations must not poison the cluster aggregate
    the autoscaler's rebalance-over-spawn decision reads."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(3)
    cluster = ServingCluster()
    cluster.register("a", _mk(model, params, n_slots=2, page_size=8))
    cluster.register("b", _mk(model, params, n_slots=2, page_size=8))
    cluster.submit(make_request(rng, cfg, 0, new=8))
    cluster.step()                            # resident on one engine
    cluster.retire_engine("a", mode="drain")
    util = cluster.kv_utilization()
    assert "a" not in util
    assert set(util) == {"b", "*"}
    cluster.run()


# ---------------------------------------------------------------------------
# backlog-aware forecast (flash-crowd regression)
# ---------------------------------------------------------------------------


class _StubTracker:
    def __init__(self, rates, depths):
        self._rates, self._depths = rates, depths

    def labels(self):
        return sorted(set(self._rates) | set(self._depths))

    def rate(self, label):
        return self._rates.get(label, 0.0)

    def depth(self, label):
        return self._depths.get(label, 0.0)


def test_demand_folds_queue_backlog(fp32_model):
    """SATELLITE (flash crowd): the forecast is rate AND backlog — a
    deep queue raises the effective sizing rate even when the arrival
    EWMA alone looks steady."""
    cluster = ServingCluster()
    steady = demand_from_tracker(
        _StubTracker({"phi": 2.0}, {"phi": 0.0}), cluster)
    crowd = demand_from_tracker(
        _StubTracker({"phi": 2.0}, {"phi": 40.0}), cluster, drain_s=10.0)
    assert steady["phi"].queued == 0.0
    assert steady["phi"].effective_rate == pytest.approx(2.0)
    assert crowd["phi"].queued == 40.0
    assert crowd["phi"].effective_rate == pytest.approx(2.0 + 4.0)
    # the mix the estimator scores uses the effective rate
    assert crowd["phi"].mix().rate == pytest.approx(6.0)
    # sub-floor depth EWMA tails forecast as zero backlog
    tail = demand_from_tracker(
        _StubTracker({"phi": 2.0}, {"phi": 0.3}), cluster)
    assert tail["phi"].queued == 0.0


def test_flash_crowd_scales_capacity(fp32_model):
    """The regression: a flash crowd (steady arrivals, deep backlog)
    must size MORE capacity than the same arrival rate with an empty
    queue — before the fix the planner sized for the steady rate while
    the backlog drained at whatever latency old capacity produced."""
    _, model, params = fp32_model
    feats = features_from_engine(_mk(model, params))
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan())
    idle = estimate(feats, host)
    rate = 0.5 * idle.throughput_tok_s / 16.0      # one engine at rho=.5
    queued = 10.0 * rate                           # backlog worth 10 s
    calm = best_candidate(
        {"phi": LabelDemand(rate=rate)}, {}, specs=[spec],
        profiles=[host], features_fn=lambda s: feats)
    crowd = best_candidate(
        {"phi": LabelDemand(rate=rate, queued=queued, drain_s=10.0)}, {},
        specs=[spec], profiles=[host], features_fn=lambda s: feats)
    assert calm.config["phi"].count == 1
    assert crowd.config["phi"].count > calm.config["phi"].count


# ---------------------------------------------------------------------------
# disaggregated configuration search
# ---------------------------------------------------------------------------


def _role_specs():
    return [EngineSpec(plan=default_plan(), n_slots=2, s_max=32),
            EngineSpec(plan=default_plan(), n_slots=2, s_max=32,
                       role="prefill"),
            EngineSpec(plan=default_plan(), n_slots=2, s_max=32,
                       role="decode")]


def _prefill_bound_profile(feats):
    """A compute-poor / bandwidth-rich device: the decode step is a
    compute-roofline 100 us while memory streaming is negligible, so a
    512-token prefill costs ~256 decode steps — the regime (long prompts
    on compute-bound hardware) where prefill/decode interference
    dominates a unified deployment and disaggregation pays."""
    from repro.planner import DeviceProfile
    return DeviceProfile(name="pfbound", peak_flops=feats.flops / 1e-4,
                         hbm_bw=feats.bytes / 1e-6, mem_bytes=1e15,
                         link_bw=1e15)


def _long_mix_demand(feats, profile):
    """A long-prompt + long-decode mix on ``profile`` whose prefill duty
    is 1.2 engine-seconds/second: at 6 unified engines the interference
    still inflates TPOT by 1/(1-0.2) = 1.25x (violating a 1.15x target),
    while a 2-prefill + 1-decode split runs both tiers below 0.85."""
    mix = TrafficMix(prompt_len=512, new_tokens=256, rate=0.0)
    p = estimate(feats, profile, mix).prefill_s
    rate = 1.2 / p
    return LabelDemand(rate=rate, prompt_len=512, new_tokens=256), p


def test_search_chooses_disagg_for_long_mix(fp32_model):
    """ACCEPTANCE: on a long-prompt/long-decode mix with a tight TPOT
    target, the search picks a disaggregated (prefill + decode tier)
    configuration and meets the joint targets where every affordable
    unified configuration violates them."""
    _, model, params = fp32_model
    feats = features_from_engine(_mk(model, params))
    prof = _prefill_bound_profile(feats)
    d, p = _long_mix_demand(feats, prof)
    targets = {"phi": (8.0 * p, 1.15 * estimate(feats, prof).tpot_s)}
    best = best_candidate(
        {"phi": d}, targets, specs=_role_specs(), profiles=[prof],
        features_fn=lambda s: feats, max_engines_per_label=6)
    assert best.config["phi"].disaggregated
    assert best.violations == 0
    roles = best.config["phi"].by_role()
    assert set(roles) == {"prefill", "decode"}
    assert roles["prefill"].count >= 1 and roles["decode"].count >= 1
    # priced WITH the interference disaggregation removes, even the
    # biggest affordable unified deployment violates the TPOT target —
    # the win is structural, not a count the enumeration missed
    for count in range(1, 7):
        uni = score_current(
            {"phi": (_role_specs()[0], prof, count)}, {"phi": d},
            targets, features_fn=lambda s: feats, interference=True)
        assert uni.violations > 0, f"unified x{count} should violate"


def test_search_falls_back_to_unified_for_easy_mix(fp32_model):
    """Disaggregation costs >= 2 engines; an easy mix one unified engine
    serves stays unified (cost term of the lexicographic objective)."""
    _, model, params = fp32_model
    feats = features_from_engine(_mk(model, params))
    host = calibrate_host_profile()
    idle = estimate(feats, host)
    d = LabelDemand(rate=0.05 * idle.throughput_tok_s / 16.0)
    best = best_candidate(
        {"phi": d}, {}, specs=_role_specs(), profiles=[host],
        features_fn=lambda s: feats, max_engines_per_label=6)
    assert not best.config["phi"].disaggregated
    assert best.config["phi"].count == 1
    assert best.violations == 0


def test_legacy_search_numbers_unchanged_without_role_specs(fp32_model):
    """With no role-tagged spec in the catalog, interference pricing is
    never applied: scores are bitwise what the pre-disaggregation search
    produced."""
    _, model, params = fp32_model
    feats = features_from_engine(_mk(model, params))
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan())
    d = LabelDemand(rate=0.5 * estimate(feats, host).throughput_tok_s
                    / 16.0, prompt_len=64.0)
    best = best_candidate({"phi": d}, {}, specs=[spec], profiles=[host],
                          features_fn=lambda s: feats)
    raw = estimate(feats, host, d.mix(),
                   engines=best.config["phi"].count)
    assert best.per_label["phi"].tpot_s == raw.tpot_s
    assert best.per_label["phi"].ttft_s == raw.ttft_s


def test_estimate_disagg_tiers_are_independent(fp32_model):
    """The disaggregated estimate's TTFT moves with the prefill tier
    only and its TPOT with the decode tier only."""
    _, model, params = fp32_model
    feats = features_from_engine(_mk(model, params))
    host = calibrate_host_profile()
    mix = TrafficMix(prompt_len=256, new_tokens=64,
                     rate=0.4 / estimate(feats, host,
                                         TrafficMix(prompt_len=256)
                                         ).prefill_s)
    one = estimate_disagg(feats, feats, mix, prefill_profile=host,
                          decode_profile=host)
    more_pf = estimate_disagg(feats, feats, mix, prefill_profile=host,
                              decode_profile=host, prefill_engines=2)
    more_de = estimate_disagg(feats, feats, mix, prefill_profile=host,
                              decode_profile=host, decode_engines=2)
    assert more_pf.ttft_s < one.ttft_s
    assert more_pf.tpot_s == one.tpot_s
    assert more_de.tpot_s == one.tpot_s         # tpot is the roofline step
    assert more_de.throughput_tok_s == pytest.approx(
        2.0 * one.throughput_tok_s)
    with pytest.raises(ValueError):
        estimate_disagg(feats, feats, mix, prefill_profile=host,
                        decode_profile=host, prefill_engines=0)
    # the handoff surcharge lands on TTFT only
    surcharged = estimate_disagg(feats, feats, mix, prefill_profile=host,
                                 decode_profile=host, handoff_s=0.05)
    assert surcharged.ttft_s == pytest.approx(one.ttft_s + 0.05)
    assert surcharged.tpot_s == one.tpot_s


def test_prefill_interference_saturates(fp32_model):
    _, model, params = fp32_model
    feats = features_from_engine(_mk(model, params))
    host = calibrate_host_profile()
    mix = TrafficMix(prompt_len=256, new_tokens=64, rate=0.0)
    est = estimate(feats, host, mix)
    assert prefill_interference(est, mix) == est     # zero duty: untouched
    loaded = TrafficMix(prompt_len=256, new_tokens=64,
                        rate=0.5 / est.prefill_s)
    inflated = prefill_interference(est, loaded)
    assert inflated.tpot_s == pytest.approx(est.tpot_s * 2.0)
    swamped = TrafficMix(prompt_len=256, new_tokens=64,
                         rate=2.0 / est.prefill_s)
    assert math.isinf(prefill_interference(est, swamped).tpot_s)


def test_score_current_role_dict_and_lone_tier(fp32_model):
    """`score_current` prices a deployed disaggregated config with the
    disagg estimator; a lone tier (prefill with no decode) is graded as
    missing capacity — it cannot serve alone."""
    _, model, params = fp32_model
    feats = features_from_engine(_mk(model, params))
    prof = _prefill_bound_profile(feats)
    specs = _role_specs()
    d, p = _long_mix_demand(feats, prof)
    targets = {"phi": (8.0 * p, 1.15 * estimate(feats, prof).tpot_s)}
    full = score_current(
        {"phi": {"prefill": (specs[1], prof, 2),
                 "decode": (specs[2], prof, 2)}},
        {"phi": d}, targets, features_fn=lambda s: feats)
    assert full.violations == 0
    assert full.cost == pytest.approx(4 * prof.cost_rate * prof.n_devices)
    assert full.config["phi"].disaggregated
    lone = score_current(
        {"phi": {"prefill": (specs[1], prof, 2)}},
        {"phi": d}, targets, features_fn=lambda s: feats)
    assert lone.violations >= 11.0
    # the interference flag prices a unified deployment's duty in
    plain = score_current({"phi": (specs[0], prof, 1)}, {"phi": d},
                          targets, features_fn=lambda s: feats)
    priced = score_current({"phi": (specs[0], prof, 1)}, {"phi": d},
                           targets, features_fn=lambda s: feats,
                           interference=True)
    assert priced.per_label["phi"].tpot_s > plain.per_label["phi"].tpot_s


# ---------------------------------------------------------------------------
# planner end to end: choose, spawn with roles, serve through handoff
# ---------------------------------------------------------------------------


def test_planner_deploys_disagg_and_serves_through_handoff(fp32_model):
    """ACCEPTANCE (planner end-to-end): the planner proposes a
    disaggregated config for the long mix, its spawn actions carry role
    assignments, execution registers role-tagged engines, and the
    resulting cluster serves requests through the first-token handoff to
    completion."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()

    def factory(spec, label):
        return _mk(model, params, n_slots=spec.n_slots, s_max=spec.s_max)

    feats = features_from_engine(_mk(model, params))
    prof = _prefill_bound_profile(feats)
    planner = WorkloadPlanner(cluster, factory, specs=_role_specs(),
                              profiles=[prof], dwell=0,
                              max_engines_per_label=6)
    d, p = _long_mix_demand(feats, prof)
    planner.set_slo_target("phi", 8.0 * p,
                           1.15 * estimate(feats, prof).tpot_s)
    actions = planner.plan({"phi": d})
    spawn_roles = sorted(a.role for a in actions if a.kind == "spawn")
    assert "prefill" in spawn_roles and "decode" in spawn_roles
    planner.execute(actions, async_spawn=False)
    roles = {n: cluster.engine(n).role for n in cluster.engines()}
    assert "prefill" in roles.values() and "decode" in roles.values()

    # a second planning round against the same demand holds still — the
    # deployed role config is recognized as current capacity
    assert planner.plan({"phi": d}) == []

    rng = np.random.default_rng(7)
    reqs = [make_request(rng, cfg, rid, "phi", new=6) for rid in range(4)]
    placed = [cluster.submit(r) for r in reqs]
    assert all(roles[name] == "prefill" for name in placed)
    cluster.run()
    assert all(len(r.tokens_out) == 6 for r in reqs)
    # what fit the decode tier handed off; the overflow decoded in place
    # on its prefill engine (capacity-constrained handoff never blocks)
    m = cluster.metrics_by_label()
    by_role = {r: m.get(f"role:{r}", {}).get("completed", 0)
               for r in ("prefill", "decode")}
    assert by_role["decode"] >= 2
    assert by_role["prefill"] + by_role["decode"] == 4
