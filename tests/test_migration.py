"""Live in-flight request migration: export/import state transfer,
fail-closed edge cases (capacity, labels, route constraints), the
migrate-mode retirement fast path, padded-bucket AOT prefill, and the
registration-time compiled-HLO validator hook.

Uses the shared serving harness from conftest (``fp32_model`` session
fixture, `make_request`/`make_engine`/`baseline_streams`); this file's
traces default to ``max_new_tokens=5``."""
import dataclasses

import jax
import numpy as np
import pytest
from conftest import baseline_streams as _baseline_streams
from conftest import make_engine as _mk
from conftest import make_request

from repro.configs import get_reduced_config
from repro.models import build_model
from repro.serving import (
    Autoscaler,
    ElasticPolicy,
    LoadTracker,
    MigrationError,
    Request,
    RoutingError,
    ServingCluster,
    ServingEngine,
)
from repro.sharding import ShardingPlan, default_plan


def _req(rng, cfg, rid, labels=None, n=6, new=5):
    return make_request(rng, cfg, rid, labels, n=n, new=new)


PINNED = ShardingPlan(device_constraints=(("pod", 0),),
                      forbidden_collective_axes=("pod",))


# ---------------------------------------------------------------------------
# state transfer
# ---------------------------------------------------------------------------


def test_migrate_mid_decode_streams_bitwise_identical(fp32_model):
    """The headline property: a request moved between engines mid-decode
    keeps its KV prefix and its token stream is bitwise identical to an
    unmigrated run."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 7, 6, 8)]
    expect = _baseline_streams(model, params, prompts, new=8)

    cluster = ServingCluster()
    cluster.register("src", _mk(model, params, n_slots=4))
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        cluster.submit(r)
    for _ in range(3):
        cluster.step()                       # everyone is mid-decode
    cluster.register("dst", _mk(model, params, n_slots=4))
    records = cluster.migrate_requests("src", "dst")
    assert len(records) == 4
    assert all(m.phase == "decoding" and m.bytes_moved > 0 for m in records)
    assert cluster.engine("src").load == 0
    cluster.run()
    assert {r.rid: list(r.tokens_out) for r in reqs} == expect


def test_migrate_mid_prefill_vs_mid_decode(fp32_model):
    """A queued (not yet prefilled) request migrates as a lightweight
    queued snapshot — no KV bytes, submission stamp preserved — while a
    resident one carries its slot state."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(1)
    cluster = ServingCluster()
    cluster.register("src", _mk(model, params, n_slots=2))
    reqs = [_req(rng, cfg, rid) for rid in range(3)]
    for r in reqs:
        cluster.submit(r)
    cluster.step()                           # 2 resident, 1 still queued
    t_submit = reqs[2].t_submit
    cluster.register("dst", _mk(model, params, n_slots=2))
    records = {m.rid: m for m in cluster.migrate_requests("src", "dst")}
    assert records[0].phase == "decoding" and records[0].bytes_moved > 0
    assert records[2].phase == "queued" and records[2].bytes_moved == 0
    assert reqs[2].t_submit == t_submit      # TTFT still from original submit
    cluster.run()
    assert all(len(r.tokens_out) == r.max_new_tokens for r in reqs)


def test_migrate_into_smaller_pool_fails_closed(fp32_model):
    """A pool whose s_max cannot finish the generation refuses the import;
    the request is restored to the source and completes there."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("src", _mk(model, params, s_max=48))
    cluster.register("small", _mk(model, params, s_max=16))
    rng = np.random.default_rng(2)
    req = _req(rng, cfg, 0, n=8, new=20)     # needs 8 + 20 positions
    cluster.engine("src").submit(req)
    cluster.step()
    with pytest.raises(MigrationError):
        cluster.migrate_requests("src", "small", rids=[0])
    assert cluster.engine("src").load == 1   # restored, not dropped
    cluster.run()
    assert len(req.tokens_out) == 20         # finished on the source


def test_migrate_larger_pool_never_extends_stream(fp32_model):
    """Export clamps the budget to what the SOURCE pool could produce, so
    a roomier target can't emit tokens the unmigrated run wouldn't."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(2, cfg.vocab_size, size=6).astype(np.int32)
    # source caps generation at s_max-1: 15 positions -> 9 decode tokens
    base = ServingEngine(model, params, n_slots=2, s_max=16)
    r0 = Request(0, prompt.copy(), max_new_tokens=30)
    base.submit(r0)
    base.run()

    cluster = ServingCluster()
    cluster.register("src", _mk(model, params, s_max=16))
    cluster.register("big", _mk(model, params, s_max=64))
    r1 = Request(0, prompt.copy(), max_new_tokens=30)
    cluster.engine("src").submit(r1)
    cluster.step()
    cluster.migrate_requests("src", "big", rids=[0])
    cluster.run()
    assert r1.tokens_out == r0.tokens_out


def test_migrate_unserved_label_fails_closed(fp32_model):
    """Tenancy labels and route constraints gate migration exactly like
    routing: an engine the router would refuse can't receive the request
    by migration either."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("src", _mk(model, params))
    cluster.register("general-only", _mk(
        model, params, labels={"data-type": "general"}))
    rng = np.random.default_rng(4)
    req = _req(rng, cfg, 0, {"data-type": "phi"})
    cluster.engine("src").submit(req)
    cluster.step()
    with pytest.raises(RoutingError):
        cluster.migrate_requests("src", "general-only", rids=[0])
    assert cluster.engine("src").load == 1   # nothing moved

    # route constraint: destination plan must satisfy it
    cluster.set_route_constraint("phi", PINNED)
    cluster.register("unpinned", _mk(model, params), plan=default_plan())
    with pytest.raises(RoutingError):
        cluster.migrate_requests("src", "unpinned", rids=[0])
    cluster.register("pinned", _mk(model, params), plan=PINNED)
    records = cluster.migrate_requests("src", "pinned", rids=[0])
    assert records[0].dst == "pinned"


# ---------------------------------------------------------------------------
# migrate-mode retirement
# ---------------------------------------------------------------------------


def test_retire_migrate_reaps_immediately_with_measured_downtime(fp32_model):
    cfg, model, params = fp32_model
    rng = np.random.default_rng(5)
    cluster = ServingCluster()
    cluster.register("a", _mk(model, params, n_slots=4))
    cluster.register("b", _mk(model, params, n_slots=4))
    reqs = [_req(rng, cfg, rid) for rid in range(3)]
    for r in reqs:
        cluster.engine("a").submit(r)
    cluster.step()
    report = cluster.retire_engine("a", mode="migrate")
    # relocated and reaped in the same call — no drain latency
    assert "a" not in cluster.engines()
    assert report.event == "retire"
    assert report.downtime_s > 0.0           # the honest blocking window
    assert len(report.migrations) == 3
    assert report.migrate_bytes > 0
    assert all(m.pause_s >= 0 for m in report.migrations)
    cluster.run()
    assert all(len(r.tokens_out) == r.max_new_tokens for r in reqs)
    assert cluster.metrics()["completed"] == 3


def test_retire_migrate_falls_back_to_drain_without_peer(fp32_model):
    """Requests no peer may legally hold stay behind and drain in place —
    fail-closed beats mis-placement."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(6)
    cluster = ServingCluster()
    cluster.register("phi-0", _mk(model, params,
                                  labels={"data-type": "phi"}))
    cluster.register("general-0", _mk(model, params,
                                      labels={"data-type": "general"}))
    req = _req(rng, cfg, 0, {"data-type": "phi"})
    cluster.engine("phi-0").submit(req)
    cluster.step()
    report = cluster.retire_engine("phi-0", mode="migrate")
    assert report.migrations == ()           # nowhere legal to go
    assert "phi-0" in cluster.engines()      # still draining it out
    assert cluster.draining() == ["phi-0"]
    cluster.run()
    assert "phi-0" not in cluster.engines()  # drained, then reaped
    assert len(req.tokens_out) == req.max_new_tokens


def test_retire_migrate_zero_peers_falls_back_to_drain(fp32_model,
                                                       fake_clock):
    """Regression: migrate-mode retirement on a cluster with NO other
    engine at all must fall back to draining instead of erroring, and
    the report's downtime must be honestly 0 — discovering there was
    nowhere to go blocks nobody. The fake clock makes the window
    deterministic: any nonzero accounting would be exact, not jitter."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(20)
    cluster = ServingCluster()
    cluster.register("only", _mk(model, params))
    req = _req(rng, cfg, 0)
    cluster.engine("only").submit(req)
    cluster.step()                           # resident mid-decode

    report = cluster.retire_engine("only", mode="migrate")
    assert report.event == "retire"
    assert report.migrations == ()           # zero eligible peers
    assert report.downtime_s == 0.0          # honest: the drain path
    assert report.migrate_bytes == 0
    assert cluster.draining() == ["only"]    # drains in place instead
    cluster.run()
    assert "only" not in cluster.engines()   # reaped once empty
    assert len(req.tokens_out) == req.max_new_tokens
    assert cluster.metrics()["completed"] == 1
    # deterministic stamps under the fake clock: the request's TTFT/TPOT
    # are exact multiples of the clock tick, never wall-clock jitter
    assert req.t_done > req.t_first > req.t_submit
    ticks = (req.t_done - req.t_submit) / fake_clock.tick
    assert abs(ticks - round(ticks)) < 1e-6


def test_drain_mode_retirement_unchanged(fp32_model):
    """The default mode keeps the PR-2 semantics: no blocking, no
    migrations, drain then reap."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(7)
    cluster = ServingCluster()
    cluster.register("a", _mk(model, params))
    cluster.register("b", _mk(model, params))
    cluster.engine("a").submit(_req(rng, cfg, 0))
    report = cluster.retire_engine("a")
    assert report.downtime_s == 0.0 and report.migrations == ()
    assert cluster.draining() == ["a"]
    cluster.run()
    assert "a" not in cluster.engines()
    with pytest.raises(ValueError):
        cluster.retire_engine("b", mode="teleport")


def test_autoscaler_prefers_migrate_retire_when_peers_have_slots(fp32_model):
    """With prefer_migrate, a cold label's busy dedicated engine is
    retired by live migration (relocate + immediate reap) instead of
    waiting out its longest decode."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(8)
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params, n_slots=4))
    cluster.spawn_engine("phi-0", _mk(model, params, n_slots=4),
                         labels={"data-type": "phi"})
    scaler = Autoscaler(
        cluster, lambda label: _mk(model, params),
        # retire_depth above the residual in-flight depth: the label is
        # cold (no arrivals) even though two long decodes are resident
        policy=ElasticPolicy(retire_rate=0.25, retire_depth=3.0, sustain=2,
                             cooldown=0, prefer_migrate=True),
        tracker=LoadTracker(alpha=1.0))
    for rid in range(2):
        cluster.engine("phi-0").submit(
            _req(rng, cfg, rid, {"data-type": "phi"}, new=64))
    cluster.step()                           # long decodes now resident
    decisions = []
    for _ in range(3):
        decisions += scaler.tick()
    retire = next(d for d in decisions if d.kind == "retire")
    assert retire.mode == "migrate"
    assert "phi-0" not in cluster.engines()  # reaped immediately
    _, report = next(e for e in scaler.events if e[0].kind == "retire")
    assert len(report.migrations) == 2
    cluster.run()
    assert cluster.metrics()["completed"] == 2


def test_autoscaler_drain_strict_without_prefer_migrate(fp32_model):
    """Default policy still never retires a busy engine (PR-2 contract)."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(9)
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params, n_slots=4))
    cluster.spawn_engine("phi-0", _mk(model, params, n_slots=4),
                         labels={"data-type": "phi"})
    scaler = Autoscaler(
        cluster, lambda label: _mk(model, params),
        policy=ElasticPolicy(retire_rate=0.25, sustain=2, cooldown=0),
        tracker=LoadTracker(alpha=1.0))
    cluster.submit(_req(rng, cfg, 0, {"data-type": "phi"}, new=64))
    cluster.step()
    for _ in range(3):
        assert all(d.kind != "retire" for d in scaler.tick())
    assert "phi-0" in cluster.engines()


# ---------------------------------------------------------------------------
# padded-bucket AOT prefill
# ---------------------------------------------------------------------------


def test_bucket_prefill_unseen_length_never_jits(fp32_model):
    """With the bucket ladder compiled, a never-seen prompt length admits
    through the padded executable — the JIT fallback is unreachable —
    and the tokens match the exact-length path bit for bit."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(10)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in (11, 5)]             # 11 and 5 were never compiled
    expect = _baseline_streams(model, params, prompts, new=5, n_slots=2)

    eng = _mk(model, params)
    assert eng.supports_padded_prefill()
    assert eng.bucket_lengths() == [8, 16, 32]
    cluster = ServingCluster()
    cluster.register("e0", eng)
    report = cluster.reconfigure("e0", default_plan(), prefill_lengths=(6,),
                                 prefill_buckets=True)
    # decode + prefill(6) + buckets 8/16/32
    assert report.compiled_in_prepare == 5
    eng._prefill = _forbidden_jit            # prove the fallback is unused
    reqs = [Request(i, p, max_new_tokens=5) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert {r.rid: r.tokens_out for r in reqs} == expect


def _forbidden_jit(*a, **k):
    raise AssertionError("JIT prefill fallback used on the serving path")


def test_bucket_prefill_excluded_for_ssm_models():
    """SSM mixers fold padding into their recurrent state — bucket
    padding must be refused, not silently wrong."""
    cfg = dataclasses.replace(get_reduced_config("mamba2_370m"),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, n_slots=2, s_max=32)
    assert not eng.supports_padded_prefill()
    assert eng.bucket_lengths() == []


def test_migrated_queued_request_reuses_target_buckets(fp32_model):
    """A queued request migrated onto a bucket-equipped target admits via
    the padded executable — migration never reintroduces serving-path
    JIT."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(11)
    cluster = ServingCluster()
    cluster.register("src", _mk(model, params, n_slots=2))
    reqs = [_req(rng, cfg, rid, n=9) for rid in range(3)]
    for r in reqs:
        cluster.submit(r)
    cluster.step()                           # rid 2 still queued
    dst = _mk(model, params, n_slots=2)
    cluster.register("dst", dst)
    cluster.reconfigure("dst", default_plan(), prefill_lengths=(),
                        prefill_buckets=True)
    dst._prefill = _forbidden_jit
    records = cluster.migrate_requests("src", "dst", rids=[2])
    assert records[0].phase == "queued"
    cluster.run()
    assert len(reqs[2].tokens_out) == reqs[2].max_new_tokens


# ---------------------------------------------------------------------------
# registration-time compiled-HLO validation
# ---------------------------------------------------------------------------

BAD_HLO = """
HloModule synth

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  ROOT %ar = f32[64,64]{1,0} all-reduce(%p0), channel_id=1, replica_groups={{0,4},{1,5},{2,6},{3,7}}, use_global_device_ids=true, to_apply=%add
}
"""


def test_verify_engine_hlo_fail_closed_on_forbidden_axis(fp32_model):
    """A compiled module whose collectives cross a forbidden axis is
    rejected, no matter what the declared plan claims."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.set_route_constraint("phi", PINNED)
    cluster.register("e0", _mk(model, params), plan=PINNED)
    # on the (2,2,2) production topology, groups {0,4}... cross axis 0
    with pytest.raises(ValueError, match="fail-closed"):
        cluster.verify_engine_hlo("e0", hlo_text=BAD_HLO,
                                  mesh_shape=(2, 2, 2),
                                  axis_names=("pod", "data", "model"))
    # the engine's real compiled decode (single device, no collectives)
    # passes the same check
    assert "collectives checked" in cluster.verify_engine_hlo("e0")


def test_register_checks_compiled_hlo_not_just_plan(fp32_model, monkeypatch):
    """register() fails closed — and does NOT register — when the
    compiled artifact contradicts the declared plan."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.set_route_constraint("phi", PINNED)
    monkeypatch.setattr(ServingEngine, "decode_hlo_text",
                        lambda self: BAD_HLO)
    # attribute the synthetic module's collectives on the production
    # topology, where its replica groups cross the forbidden pod axis
    import repro.core.validator as validator
    real = validator.check_hlo_axes
    monkeypatch.setattr(
        validator, "check_hlo_axes",
        lambda text, axes, shape, names: real(text, axes, (2, 2, 2),
                                              ("pod", "data", "model")))
    with pytest.raises(ValueError, match="compiled-HLO"):
        cluster.register("bad", _mk(model, params), plan=PINNED)
    assert "bad" not in cluster.engines()
    # opting out (or no applicable constraint) registers fine
    cluster.register("ok", _mk(model, params), plan=PINNED,
                     verify_hlo=False)
    assert "ok" in cluster.engines()


def test_constraint_installed_after_register_quarantines_bad_engine(
        fp32_model, monkeypatch):
    """The register-then-constrain order is fail-closed too:
    set_route_constraint re-validates claim-satisfying engines and
    quarantines (derouts) any whose compiled artifact disproves the
    declared plan."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(12)
    cluster = ServingCluster()
    cluster.register("bad", _mk(model, params), plan=PINNED)   # no routes yet
    cluster.register("open", _mk(model, params), plan=default_plan())
    monkeypatch.setattr(ServingEngine, "decode_hlo_text",
                        lambda self: BAD_HLO)
    import repro.core.validator as validator
    real = validator.check_hlo_axes
    monkeypatch.setattr(
        validator, "check_hlo_axes",
        lambda text, axes, shape, names: real(text, axes, (2, 2, 2),
                                              ("pod", "data", "model")))
    with pytest.raises(ValueError, match="fail-closed"):
        cluster.set_route_constraint("phi", PINNED)
    # constraint installed, engine registered but unroutable: phi traffic
    # fails closed instead of landing on the disproven claim
    assert "phi" in cluster.route_constraints()
    assert "bad" in cluster.engines()
    with pytest.raises(RoutingError):
        cluster.submit(_req(rng, cfg, 0, {"data-type": "phi"}))
    # unconstrained traffic still routes (to the open engine)
    assert cluster.submit(_req(rng, cfg, 1)) == "open"


def test_spawn_engine_verifies_aot_compiled_hlo(fp32_model):
    """spawn_engine re-uses the PREPARE-phase executable for the check:
    a compliant spawn passes and joins the pool."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.set_route_constraint("phi", PINNED)
    report = cluster.spawn_engine("phi-0", _mk(model, params), plan=PINNED,
                                  labels={"data-type": "phi"})
    assert report.event == "spawn"
    assert "phi-0" in cluster.engines_for_label("phi")


# ---------------------------------------------------------------------------
# empty cohorts + role-phase preflight + cross-s_max handoff
# ---------------------------------------------------------------------------


def test_empty_cohort_is_a_true_noop(fp32_model):
    """SATELLITE: migrating an empty cohort — an idle source, an
    explicit empty rid list, or a migrate-mode retirement with nothing
    to move — reports no records and zero downtime, and emits NO pause
    span or migration event (a degenerate batch record would poison the
    per-migration pause statistics)."""
    from repro.obs import Recorder, recording

    cfg, model, params = fp32_model
    rng = np.random.default_rng(30)
    with recording(Recorder()) as rec:
        cluster = ServingCluster()
        cluster.register("a", _mk(model, params))
        cluster.register("b", _mk(model, params))
        assert cluster.migrate_requests("a", "b") == []      # idle source
        assert cluster.migrate_requests("a", "b", rids=[]) == []
        req = _req(rng, cfg, 0)
        cluster.engine("a").submit(req)
        cluster.step()
        # busy source, explicit empty cohort: still a no-op
        assert cluster.migrate_requests("a", "b", rids=[]) == []
        report = cluster.retire_engine("b", mode="migrate")  # idle engine
        assert report.downtime_s == 0.0
        assert report.migrations == ()
        cluster.run()
        assert len(req.tokens_out) == req.max_new_tokens
    assert rec.events("migration.pause") == []
    assert [s for s in rec.trace.spans()
            if s.name == "migration.pause"] == []


def test_queued_request_to_decode_engine_fails_closed(fp32_model):
    """A still-queued request needs a prefill; moving it onto a
    decode-role engine (which never prefills) must refuse up front,
    moving nothing."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(31)
    cluster = ServingCluster()
    cluster.register("src", _mk(model, params, n_slots=1))
    cluster.register("dc", _mk(model, params), role="decode")
    reqs = [_req(rng, cfg, rid) for rid in range(2)]
    for r in reqs:
        cluster.engine("src").submit(r)
    cluster.engine("src").step()             # rid 0 resident, rid 1 queued
    with pytest.raises(RoutingError, match="decode"):
        cluster.migrate_requests("src", "dc", rids=[1])
    assert len(cluster.engine("src").queue) == 1    # nothing moved
    cluster.run()
    assert all(len(r.tokens_out) == r.max_new_tokens for r in reqs)


def test_decoding_request_to_prefill_engine_fails_closed(fp32_model):
    """A decoding request parked on a prefill-role engine would just be
    handed off again — the migration preflight refuses the move."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(32)
    cluster = ServingCluster()
    cluster.register("src", _mk(model, params))
    cluster.register("pf", _mk(model, params), role="prefill")
    req = _req(rng, cfg, 0)
    cluster.engine("src").submit(req)
    cluster.engine("src").step()             # mid-decode
    with pytest.raises(RoutingError, match="prefill"):
        cluster.migrate_requests("src", "pf", rids=[0])
    assert cluster.engine("src").load == 1          # nothing moved
    cluster.run()
    assert len(req.tokens_out) == req.max_new_tokens


def test_cross_smax_handoff_never_truncates(fp32_model):
    """SATELLITE: a prompt admitted to a prefill engine whose s_max
    exceeds the decode tier's either refits (fits the target budget) or
    decodes in place on the prefill engine — the stream is NEVER
    truncated, and an explicit oversized cross-s_max migration fails
    closed with the request restored."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(33)
    prompt = rng.integers(2, cfg.vocab_size, size=8).astype(np.int32)
    big = Request(0, prompt.copy(), max_new_tokens=20)    # needs 8+20+1
    small = Request(1, prompt.copy(), max_new_tokens=4)   # refits into 16
    cluster = ServingCluster()
    cluster.register("pf", _mk(model, params, n_slots=4, s_max=48),
                     role="prefill")
    cluster.register("dc", _mk(model, params, n_slots=4, s_max=16),
                     role="decode")
    assert cluster.submit(big) == "pf"
    assert cluster.submit(small) == "pf"
    cluster.step()
    assert cluster.engine("dc").load == 1    # small handed off
    assert cluster.engine("pf").load == 1    # big stayed (would truncate)
    with pytest.raises(MigrationError):
        cluster.migrate_requests("pf", "dc", rids=[0])
    assert cluster.engine("pf").load == 1    # restored, not dropped
    cluster.run()
    assert len(big.tokens_out) == 20         # full budget, on the source
    assert len(small.tokens_out) == 4
