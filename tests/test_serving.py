"""Serving engine + online reconfiguration tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core import ReconfigEngine
from repro.models import build_model
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def fp32_model():
    cfg = dataclasses.replace(get_reduced_config("minitron_4b"),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new, s_max=48):
    """Single-sequence prefill + decode loop — the engine's oracle."""
    toks = list(map(int, prompt))
    logits, cache = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
    pool = model.init_cache(1, s_max, dtype=jnp.float32)

    def merge(z, c):
        if c.shape == z.shape:
            return c.astype(z.dtype)
        ax = [i for i, (a, b) in enumerate(zip(z.shape, c.shape)) if a != b][0]
        sl = [slice(None)] * z.ndim
        sl[ax] = slice(0, c.shape[ax])
        return z.at[tuple(sl)].set(c.astype(z.dtype))

    cache = jax.tree.map(merge, pool, cache)
    out = [int(jnp.argmax(logits[0, : model.cfg.vocab_size]))]
    pos = len(toks)
    decode = jax.jit(model.decode_step)
    for _ in range(n_new - 1):
        logits, cache = decode(params, jnp.asarray([[out[-1]]], jnp.int32),
                               cache, jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits[0, : model.cfg.vocab_size])))
        pos += 1
    return out


def test_engine_outputs_match_reference(fp32_model):
    """Batched slot decoding must be token-exact vs the single-sequence
    reference, including slots at different positions."""
    cfg, model, params = fp32_model
    eng = ServingEngine(model, params, n_slots=2, s_max=48)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]   # deliberately different lengths
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid, p, max_new_tokens=5))
    eng.run()
    assert len(eng.done) == 3
    for req in eng.done:
        ref = _greedy_reference(model, params, req.prompt, 5)
        assert req.tokens_out == ref, (req.rid, req.tokens_out, ref)


def test_engine_metrics(fp32_model):
    cfg, model, params = fp32_model
    eng = ServingEngine(model, params, n_slots=2, s_max=32)
    rng = np.random.default_rng(1)
    for rid in range(4):
        eng.submit(Request(rid, rng.integers(2, cfg.vocab_size, size=6).astype(np.int32),
                           max_new_tokens=4))
    eng.run()
    m = eng.metrics()
    assert m["completed"] == 4
    assert m["ttft_mean_s"] > 0 and m["tpot_mean_s"] > 0


def test_reconfigure_preserves_outputs(fp32_model):
    """A plan swap mid-stream must not change tokens (same weights), and
    downtime must be measured."""
    cfg, model, params = fp32_model
    eng = ServingEngine(model, params, n_slots=2, s_max=48)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(4)]
    for rid, p in enumerate(prompts[:2]):
        eng.submit(Request(rid, p, max_new_tokens=4))
    for _ in range(2):
        eng.step()

    rc = ReconfigEngine(eng)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    report = rc.reconfigure(new_shardings={
        "params": jax.tree.map(lambda _: repl, eng.params),
        "cache": jax.tree.map(lambda _: repl, eng.cache)})
    for rid, p in enumerate(prompts[2:], start=2):
        eng.submit(Request(rid, p, max_new_tokens=4))
    eng.run()
    rc.finalize_metrics(report)

    assert report.downtime_s >= 0
    assert report.migrate_bytes > 0
    assert len(eng.done) == 4
    for req in eng.done:
        ref = _greedy_reference(model, params, req.prompt, 4)
        assert req.tokens_out == ref
