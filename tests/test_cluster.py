"""ServingCluster runtime tests: label-based fail-closed routing, the
pause/drain/swap/resume lifecycle, and the end-to-end intent ->
validate -> reconfigure -> serve round-trip.

Uses the shared serving harness from conftest (``fp32_model`` session
fixture, `make_request`)."""
import numpy as np
import pytest
from conftest import make_request as _req

from repro.core import Orchestrator
from repro.serving import (
    METRIC_KEYS,
    EngineStateError,
    Request,
    RoutingError,
    ServingCluster,
    ServingEngine,
)
from repro.sharding import ShardingPlan, default_plan, plan_satisfies


PINNED = ShardingPlan(device_constraints=(("pod", 0),),
                      forbidden_collective_axes=("pod",))
PHI_CONSTRAINT = ShardingPlan(device_constraints=(("pod", 0),),
                              forbidden_collective_axes=("pod",))


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def test_plan_satisfaction_relation():
    assert plan_satisfies(PINNED, PHI_CONSTRAINT)
    assert not plan_satisfies(default_plan(), PHI_CONSTRAINT)
    # a pinned axis counts as non-crossable even if not explicitly forbidden
    assert plan_satisfies(
        ShardingPlan(device_constraints=(("pod", 0),)),
        ShardingPlan(forbidden_collective_axes=("pod",)))
    # wrong pod pin does not satisfy a pod-0 requirement
    assert not plan_satisfies(
        ShardingPlan(device_constraints=(("pod", 1),),
                     forbidden_collective_axes=("pod",)), PHI_CONSTRAINT)


def test_labeled_routing_lands_only_on_compliant_engines(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("pinned", ServingEngine(model, params, n_slots=2,
                                             s_max=32), plan=PINNED)
    cluster.register("open", ServingEngine(model, params, n_slots=2,
                                           s_max=32), plan=default_plan())
    cluster.set_route_constraint("phi", PHI_CONSTRAINT)
    rng = np.random.default_rng(0)

    for rid in range(4):
        cluster.submit(_req(rng, cfg, rid, {"data-type": "phi"}))
    # phi never lands on the non-compliant engine
    assert cluster.engine("open").load == 0
    assert cluster.engine("pinned").load == 4
    # unconstrained traffic balances onto the idle engine
    name = cluster.submit(_req(rng, cfg, 10, {"data-type": "general"}))
    assert name == "open"


def test_trace_driver_interleaves_routing_and_fail_closed(fp32_model):
    """The shared request-trace driver (conftest.drive_trace) interleaves
    submits with decode steps, records per-request placements, and maps
    fail-closed rejections to None without aborting the trace."""
    from conftest import drive_trace

    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("pinned", ServingEngine(model, params, n_slots=2,
                                             s_max=32), plan=PINNED)
    cluster.set_route_constraint("phi", PHI_CONSTRAINT)
    cluster.set_route_constraint("audio", ShardingPlan(
        device_constraints=(("pod", 1),)))      # nothing satisfies this
    rng = np.random.default_rng(20)
    trace = [_req(rng, cfg, 0, {"data-type": "phi"}, new=3),
             _req(rng, cfg, 1, {"data-type": "audio"}, new=3),
             _req(rng, cfg, 2, {"data-type": "phi"}, new=3)]

    placed = drive_trace(cluster, trace, steps_between=1)
    assert placed == ["pinned", None, "pinned"]
    assert [r.rid for r in cluster.rejected] == [1]
    # the trace drained: every routable request completed in full
    assert cluster.metrics()["completed"] == 2
    assert all(len(trace[i].tokens_out) == 3 for i in (0, 2))


def test_unroutable_request_fails_closed(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("open", ServingEngine(model, params, n_slots=2,
                                           s_max=32), plan=default_plan())
    cluster.set_route_constraint("phi", PHI_CONSTRAINT)
    rng = np.random.default_rng(1)
    with pytest.raises(RoutingError):
        cluster.submit(_req(rng, cfg, 0, {"data-type": "phi"}))
    assert len(cluster.rejected) == 1
    # engine labels that contradict the request also disqualify
    cluster2 = ServingCluster()
    cluster2.register("general-only", ServingEngine(
        model, params, n_slots=2, s_max=32,
        labels={"data-type": "general"}), plan=PINNED)
    with pytest.raises(RoutingError):
        cluster2.submit(_req(rng, cfg, 1, {"data-type": "phi"}))


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------


def test_lifecycle_state_machine(fp32_model):
    cfg, model, params = fp32_model
    eng = ServingEngine(model, params, n_slots=2, s_max=32)
    with pytest.raises(EngineStateError):
        eng.swap_plan(PINNED)            # swap requires pause
    eng.pause()
    with pytest.raises(EngineStateError):
        eng.step()                       # paused engines don't serve
    assert eng.drain() == 0
    eng.swap_plan(PINNED)
    assert eng.plan is PINNED
    eng.resume()
    assert eng.step() == 0               # empty but serving again


def test_metrics_always_full_key_set(fp32_model):
    cfg, model, params = fp32_model
    eng = ServingEngine(model, params, n_slots=2, s_max=32)
    m = eng.metrics()
    assert set(m) == set(METRIC_KEYS)
    assert m["completed"] == 0 and np.isnan(m["ttft_mean_s"])
    cluster = ServingCluster()
    cluster.register("e", eng)
    assert set(cluster.metrics()) == set(METRIC_KEYS)


def test_swap_preserves_tokens_and_swap_window_has_no_compile(fp32_model):
    """Mid-stream reconfigure onto AOT executables must be token-exact and
    must not compile inside the pause..resume window."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(2, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(4)]

    # oracle: uninterrupted engine
    ref = ServingEngine(model, params, n_slots=2, s_max=32)
    for rid, p in enumerate(prompts):
        ref.submit(Request(rid, p, max_new_tokens=4))
    ref.run()
    expect = {r.rid: r.tokens_out for r in ref.done}

    cluster = ServingCluster()
    eng = ServingEngine(model, params, n_slots=2, s_max=32)
    cluster.register("e", eng)
    for rid, p in enumerate(prompts[:2]):
        cluster.submit(Request(rid, p, max_new_tokens=4))
    cluster.step()
    report = cluster.reconfigure("e", PINNED, prefill_lengths=(6,))
    for rid, p in enumerate(prompts[2:], start=2):
        cluster.submit(Request(rid, p, max_new_tokens=4))
    cluster.run()

    assert report.compiled_in_prepare == 2          # decode + prefill(6)
    assert report.prepare_s > 0 and report.downtime_s >= 0
    # AOT happened ahead: the blocking window is far below the compile cost
    assert report.downtime_s < report.prepare_s
    assert report.migrate_bytes > 0
    assert eng.plan is PINNED
    assert {r.rid: r.tokens_out for r in eng.done} == expect


def test_apply_policy_conflicting_pins_stay_fail_closed(fp32_model):
    """Placement updates that pin phi components to *different* pods must
    degrade to axis confinement, never to a vacuous always-true constraint;
    fully empty plan updates must install no constraint at all."""
    from repro.core import Component

    cfg, model, params = fp32_model
    comps = (Component("phi-a", {"data-type": "phi"}),
             Component("phi-b", {"data-type": "phi"}))

    class FakePolicy:
        plan_updates = {
            "phi-a": ShardingPlan(device_constraints=(("pod", 0),)),
            "phi-b": ShardingPlan(device_constraints=(("pod", 1),)),
        }

    cluster = ServingCluster()
    cluster.register("open", ServingEngine(model, params, n_slots=2,
                                           s_max=32), plan=default_plan())
    reports = cluster.apply_policy(FakePolicy(), components=comps)
    required = cluster.route_constraints()["phi"]
    assert required.forbidden_collective_axes == ("pod",)
    assert not plan_satisfies(default_plan(), required)   # not vacuous
    assert "open" in reports                              # engine was swapped
    assert plan_satisfies(cluster.engine("open").plan, required)

    class EmptyPolicy:
        plan_updates = {"phi-a": ShardingPlan()}

    cluster2 = ServingCluster()
    cluster2.register("e", ServingEngine(model, params, n_slots=2, s_max=32))
    cluster2.apply_policy(EmptyPolicy(), components=comps)
    assert cluster2.route_constraints() == {}


# ---------------------------------------------------------------------------
# end-to-end intent round-trip
# ---------------------------------------------------------------------------


def test_e2e_intent_reconfigure_serve_roundtrip(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("edge0", ServingEngine(model, params, n_slots=2,
                                            s_max=32))
    rng = np.random.default_rng(3)
    for rid in range(2):
        cluster.submit(_req(rng, cfg, rid, {"data-type": "phi"}, new=3))
    cluster.run()

    orch = Orchestrator()
    res = orch.submit("Phi traffic must remain inside the pod.",
                      apply_to=cluster)
    assert res.success
    assert "reconfigure" in res.timings
    assert "edge0" in res.reports
    report = res.reports["edge0"]
    assert report.downtime_s >= 0 and report.prepare_s > 0
    assert report.compiled_in_prepare > 0
    assert set(report.metrics_before) == set(METRIC_KEYS)
    assert report.metrics_before["completed"] == 2

    # the cluster now enforces the phi route constraint
    phi_req = _req(rng, cfg, 100, {"data-type": "phi"}, new=3)
    assert cluster.eligible(phi_req) == ["edge0"]
    assert "phi" in cluster.route_constraints()
    assert "pod" in cluster.engine("edge0").plan.forbidden_collective_axes

    # keep serving; the report's after-window finalizes automatically
    cluster.submit(phi_req)
    cluster.run()
    assert set(report.metrics_after) == set(METRIC_KEYS)
    assert report.metrics_after["completed"] == 1
    assert report.metrics_after["ttft_mean_s"] > 0

# ---------------------------------------------------------------------------
# route constraints beyond the data-type label (selector / predicate routes)
# ---------------------------------------------------------------------------


def test_multi_key_selector_route_fail_closed(fp32_model):
    """A multi-key selector constraint binds only requests carrying ALL
    its keys; matching requests route fail-closed exactly like data-type
    constraints."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("pinned", ServingEngine(model, params, n_slots=2,
                                             s_max=32), plan=PINNED)
    cluster.register("open", ServingEngine(model, params, n_slots=2,
                                           s_max=32), plan=default_plan())
    cluster.set_route_predicate({"data-type": "phi", "app": "patient"},
                                PHI_CONSTRAINT)
    rng = np.random.default_rng(0)

    # both keys present -> only the compliant engine qualifies
    name = cluster.submit(_req(rng, cfg, 0, {"data-type": "phi",
                                             "app": "patient"}))
    assert name == "pinned"
    # one key missing -> the selector does not bind; any engine serves
    cluster.submit(_req(rng, cfg, 1, {"data-type": "phi"}))
    assert cluster.engine("open").load + cluster.engine("pinned").load == 2

    # no compliant engine at all -> rejected, never silently served
    cluster.retire_engine("pinned")
    cluster.run()
    with pytest.raises(RoutingError):
        cluster.submit(_req(rng, cfg, 2, {"data-type": "phi",
                                          "app": "patient"}))
    assert cluster.rejected[-1].rid == 2


def test_predicate_route_and_merge_with_data_type(fp32_model):
    """An arbitrary label predicate routes fail-closed, and a request
    matching BOTH a data-type constraint and a predicate constraint must
    satisfy their MERGE (conflicting pins degrade to unroutable)."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("pinned", ServingEngine(model, params, n_slots=2,
                                             s_max=32), plan=PINNED)
    cluster.set_route_predicate(
        lambda labels: labels.get("tier") == "gold",
        ShardingPlan(device_constraints=(("pod", 0),)))
    rng = np.random.default_rng(0)
    assert cluster.submit(_req(rng, cfg, 0, {"tier": "gold"})) == "pinned"

    # merged requirement: data-type wants pod 1, predicate wants pod 0 —
    # the conflict degrades to pod-axis CONFINEMENT (documented
    # merge_restrictions semantics): an engine pinned somewhere on the
    # pod axis still qualifies, an unpinned one does not
    cluster.set_route_constraint(
        "phi", ShardingPlan(device_constraints=(("pod", 1),)))
    req = cluster.required_for({"data-type": "phi", "tier": "gold"})
    assert "pod" in req.forbidden_collective_axes
    assert not dict(req.device_constraints)       # pins degraded away
    assert plan_satisfies(PINNED, req)
    assert not plan_satisfies(default_plan(), req)
    assert cluster.submit(_req(rng, cfg, 1, {"data-type": "phi",
                                             "tier": "gold"})) == "pinned"


def test_selector_route_constrains_migration(fp32_model):
    """Migration eligibility honors selector constraints: a destination
    that fails the merged requirement is rejected fail-closed."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("src", ServingEngine(model, params, n_slots=2,
                                          s_max=32), plan=PINNED)
    cluster.register("dst", ServingEngine(model, params, n_slots=2,
                                          s_max=32), plan=default_plan())
    rng = np.random.default_rng(0)
    cluster.submit(_req(rng, cfg, 0, {"data-type": "phi",
                                      "app": "patient"}))
    cluster.set_route_predicate({"data-type": "phi", "app": "patient"},
                                PHI_CONSTRAINT)
    with pytest.raises(RoutingError):
        cluster.migrate_requests("src", "dst")
