"""Shared fixtures. NB: no XLA_FLAGS here — tests run on the single real CPU
device; only launch/dryrun.py forces 512 placeholder devices."""
import os

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
