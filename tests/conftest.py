"""Shared fixtures + the serving test harness.

NB: no XLA_FLAGS here — tests run on the single real CPU device; only
launch/dryrun.py forces 512 placeholder devices.

The serving harness deduplicates the setup that was copy-pasted across
test_cluster/test_autoscaler/test_migration:

  * ``fp32_model``      session-scoped tiny fp32 model (one build + init
                        for the whole suite);
  * ``make_request``    labeled request factory (accepts a bare label
                        string or a full labels dict);
  * ``make_engine``     tiny `ServingEngine` builder;
  * ``baseline_streams``  oracle token streams of an uninterrupted run;
  * ``drive_trace``     request-trace driver (submit/step interleaving);
  * ``FakeClock`` / ``fake_clock``  deterministic clock installed into
                        the serving modules, so timing-derived assertions
                        (TTFT/TPOT stamps, downtime windows) are exact.
"""
import dataclasses

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# tiny-model cluster builders
# ---------------------------------------------------------------------------


def build_tiny_model(arch="minitron_4b"):
    """(cfg, model, params) for a reduced fp32 config — the serving
    tests' standard substrate."""
    from repro.configs import get_reduced_config
    from repro.models import build_model

    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="session")
def fp32_model():
    """The shared tiny serving model, built once per test session."""
    return build_tiny_model()


def make_request(rng, cfg, rid, labels=None, *, n=6, new=4):
    """One labeled `Request` with a random prompt of length ``n``.

    ``labels`` may be a full dict or a bare ``data-type`` value string.
    """
    from repro.serving import Request

    if isinstance(labels, str):
        labels = {"data-type": labels}
    return Request(rid, rng.integers(2, cfg.vocab_size, size=n)
                   .astype(np.int32), max_new_tokens=new,
                   labels=labels or {})


def make_engine(model, params, *, n_slots=2, s_max=32, **kw):
    """A tiny `ServingEngine` with the suite's standard pool sizing."""
    from repro.serving import ServingEngine

    return ServingEngine(model, params, n_slots=n_slots, s_max=s_max, **kw)


def baseline_streams(model, params, prompts, new, *, n_slots=4, s_max=32):
    """Token streams of an unmigrated/uninterrupted run over ``prompts``
    (the oracle the reconfiguration/migration tests compare against)."""
    from repro.serving import Request

    eng = make_engine(model, params, n_slots=n_slots, s_max=s_max)
    reqs = [Request(i, p, max_new_tokens=new) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    return {r.rid: list(r.tokens_out) for r in reqs}


def drive_trace(cluster, requests, *, steps_between=1, drain=True):
    """Submit ``requests`` one by one, interleaving ``steps_between``
    decode steps after each (a deterministic open-loop trace driver).

    Returns the engine name the router chose per request (None where
    routing failed closed — the request is in ``cluster.rejected``).
    """
    from repro.serving import RoutingError

    placed = []
    for r in requests:
        try:
            placed.append(cluster.submit(r))
        except RoutingError:
            placed.append(None)
        for _ in range(steps_between):
            cluster.step()
    if drain:
        cluster.run()
    return placed


# ---------------------------------------------------------------------------
# deterministic fake clock (now first-class: repro.serving.clock)
# ---------------------------------------------------------------------------

# Re-exported so older test imports (`from conftest import FakeClock`)
# keep working; the implementation lives in the serving layer now.
from repro.serving.clock import FakeClock, install_clock  # noqa: E402


@pytest.fixture
def fake_clock():
    """Install a `FakeClock` as the ``time`` source of the serving layer
    (engine/cluster/migration/prepare stamp requests and windows through
    it — see `repro.serving.clock.install_clock`)."""
    clock = FakeClock()
    restore = install_clock(clock)
    yield clock
    restore()
