"""HLO cost model + collective attribution tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hlo_analysis import axes_crossed, parse_collectives
from repro.core.hlo_cost import HloCostModel
from repro.core.validator import check_hlo_axes


def _scan_module(n_layers, width=256):
    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()
    ws = jax.ShapeDtypeStruct((n_layers, width, width), jnp.float32)
    x = jax.ShapeDtypeStruct((32, width), jnp.float32)
    return jax.jit(f).lower(ws, x).compile().as_text()


def test_cost_model_multiplies_while_trip_count():
    f1 = HloCostModel(_scan_module(1)).cost().flops
    f8 = HloCostModel(_scan_module(8)).cost().flops
    assert 7.5 < f8 / f1 < 8.5, (f1, f8)


def test_cost_model_dot_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    txt = jax.jit(f).lower(a, b).compile().as_text()
    flops = HloCostModel(txt).cost().flops
    assert abs(flops - 2 * 64 * 128 * 32) / (2 * 64 * 128 * 32) < 0.05


SYNTH_HLO = """
HloModule synth

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %ar = f32[64,64]{1,0} all-reduce(%p0), channel_id=1, replica_groups={{0,1},{2,3}}, use_global_device_ids=true, to_apply=%add
  ROOT %ag = f32[64,64]{1,0} all-gather(%ar), channel_id=2, replica_groups={{0,2},{1,3}}, dimensions={0}, use_global_device_ids=true
}
"""


def test_parse_collectives_and_axes():
    colls = parse_collectives(SYNTH_HLO)
    assert {c.kind for c in colls} == {"all-reduce", "all-gather"}
    ar = next(c for c in colls if c.kind == "all-reduce")
    ag = next(c for c in colls if c.kind == "all-gather")
    # mesh (2, 2) with axes (pod, model): device = pod*2 + model
    assert axes_crossed(ar.groups, None, (2, 2), ("pod", "model")) == ("model",)
    assert axes_crossed(ag.groups, None, (2, 2), ("pod", "model")) == ("pod",)


def test_check_hlo_axes_fail_closed():
    ok, msg = check_hlo_axes(SYNTH_HLO, ["pod"], (2, 2), ("pod", "model"))
    assert not ok and "pod" in msg
    ok, msg = check_hlo_axes(SYNTH_HLO, ["data"], (2, 2), ("pod", "data"))
    # second mesh interpretation: axis named data == old model -> both cross?
    # groups {0,2}/{1,3} cross dim0 ("pod"); {0,1}/{2,3} cross dim1 ("data")
    assert not ok


def test_iota_replica_groups():
    txt = ("%ar = f32[8]{0} all-reduce(%x), channel_id=1, "
           "replica_groups=[2,2]<=[4], use_global_device_ids=true\n")
    colls = parse_collectives(txt)
    assert len(colls) == 1
    np.testing.assert_array_equal(colls[0].groups, [[0, 1], [2, 3]])


def test_wire_bytes_model():
    colls = parse_collectives(SYNTH_HLO)
    ar = next(c for c in colls if c.kind == "all-reduce")
    # ring all-reduce: 2 * bytes * (n-1)/n
    expect = 2 * 64 * 64 * 4 * 0.5
    assert abs(ar.wire_bytes_per_device() - expect) < 1
