"""Edge-case tests for the flight recorder's metrics sketches and the
SLO ledger's window accounting:

  * `Histogram.quantile` — the documented ``sqrt(growth) - 1`` relative
    error bound holds for arbitrary positive samples (property test via
    the hypothesis shim), and the rank semantics match a sorted-list
    oracle;
  * zero / negative / sub-``min_value`` observations clamp into the
    underflow bucket (reported as 0.0) without corrupting min/max/sum;
  * NaN and inf contamination surface as NaN / inf quantiles instead of
    silently vanishing;
  * `SLOLedger` window boundaries — completions landing exactly on a
    window edge score in the NEXT window, windows with no scored
    completions never materialize (empty window == absent, attainment
    NaN only via an explicit empty `WindowAttainment`), and
    handoff-reason migration pauses are accounted under "handoff" and
    NEVER double-counted under "migration".
"""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.obs import SLOLedger
from repro.obs.events import Event
from repro.obs.metrics import Histogram, MetricsRegistry, RequestAggregate
from repro.obs.slo import WindowAttainment


# ---------------------------------------------------------------------------
# Histogram quantile error bound (property)


@st.composite
def _samples(draw):
    """1..60 positive floats spanning ~9 decades (integers mapped —
    the shim has no st.floats)."""
    n = draw(st.integers(1, 60))
    return [draw(st.integers(1, 10 ** 9)) * 1e-6 for _ in range(n)]


@settings(max_examples=60)
@given(values=_samples(), q_pct=st.integers(0, 100))
def test_quantile_relative_error_bound(values, q_pct):
    h = Histogram(growth=1.1)
    for v in values:
        h.observe(v)
    q = q_pct / 100.0
    est = h.quantile(q)
    # the sketch's rank semantics: first bucket whose cumulative count
    # reaches rank q*(n-1)+1 — the sorted-list element at that rank
    rank = q * (len(values) - 1) + 1
    truth = sorted(values)[math.ceil(rank) - 1]
    bound = math.sqrt(h.growth) - 1.0
    assert abs(est - truth) <= truth * (bound + 1e-9), (
        f"q={q}: estimate {est} vs truth {truth} breaks the "
        f"sqrt(growth)-1 = {bound:.4f} relative-error contract")


@settings(max_examples=30)
@given(values=_samples())
def test_quantile_is_monotone_in_q(values):
    h = Histogram()
    for v in values:
        h.observe(v)
    qs = [h.quantile(i / 10.0) for i in range(11)]
    assert qs == sorted(qs)
    bound = math.sqrt(h.growth) - 1.0
    assert h.quantile(1.0) == pytest.approx(h.max, rel=bound + 1e-9)


# ---------------------------------------------------------------------------
# Underflow clamping and contamination


def test_zero_and_negative_clamp_to_underflow_bucket():
    h = Histogram(min_value=1e-9)
    for v in (0.0, -5.0, 5e-10, -0.0):
        h.observe(v)
    # everything below min_value reports as 0.0 at every quantile
    assert h.quantile(0.0) == 0.0
    assert h.quantile(1.0) == 0.0
    # ... but the exact extremes and the running sum are preserved
    assert h.min == -5.0
    assert h.max == 5e-10
    assert h.count == 4
    assert h.sum == pytest.approx(-5.0 + 5e-10)


def test_underflow_mixes_with_regular_observations():
    h = Histogram(min_value=1e-9)
    for v in (0.0, -1.0, 0.5, 2.0):
        h.observe(v)
    assert h.quantile(0.0) == 0.0          # underflow owns the low ranks
    assert h.quantile(1.0) == pytest.approx(2.0, rel=0.05)
    snap = h.snapshot()
    assert snap["count"] == 4 and snap["min"] == -1.0


def test_empty_and_contaminated_sketches():
    h = Histogram()
    assert math.isnan(h.quantile(0.5))
    assert math.isnan(h.mean)
    h.observe(1.0)
    h.observe(math.nan)
    assert math.isnan(h.quantile(0.5))     # NaN propagates, like np
    g = Histogram()
    g.observe(1.0)
    g.observe(math.inf)
    assert g.quantile(1.0) == math.inf
    with pytest.raises(ValueError):
        g.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram(growth=1.0)


def test_request_aggregate_empty_matches_nan_shape():
    agg = RequestAggregate()
    m = agg.metrics()
    assert m["completed"] == 0
    assert all(math.isnan(m[k]) for k in
               ("ttft_mean_s", "ttft_p99_s", "tpot_mean_s", "tpot_p99_s"))
    agg.observe(0.1, 0.01)
    m = agg.metrics()
    assert m["completed"] == 1 and m["ttft_mean_s"] == pytest.approx(0.1)


def test_registry_families_are_stable_and_sorted():
    reg = MetricsRegistry()
    c = reg.counter("done", label="phi")
    assert reg.counter("done", label="phi") is c
    reg.counter("done", label="gen").inc(2)
    c.inc()
    snap = reg.snapshot()
    assert snap["counters"] == {"done{label=gen}": 2.0,
                                "done{label=phi}": 1.0}


# ---------------------------------------------------------------------------
# SLOLedger window boundaries + pause attribution


def _complete(seq, ts, label, ttft_s, tpot_s=0.001):
    return Event(seq, ts, "request.complete", "e0", seq, label,
                 {"ttft_s": ttft_s, "tpot_s": tpot_s})


def test_window_edge_scores_in_next_window():
    led = SLOLedger({"phi": (0.1, None)}, window_s=1.0, t0=100.0)
    led.observe(_complete(0, 100.0, "phi", 0.05))    # window 0 start
    led.observe(_complete(1, 100.999, "phi", 0.05))  # still window 0
    led.observe(_complete(2, 101.0, "phi", 0.5))     # EXACTLY the edge
    ws = led.windows("phi")
    assert [w.window for w in ws] == [0, 1]
    assert ws[0].scored == 2 and ws[0].ok == 2
    assert ws[1].scored == 1 and ws[1].ok == 0
    assert ws[1].t_end == pytest.approx(102.0)


def test_empty_windows_never_materialize():
    led = SLOLedger({"phi": (0.1, None)}, window_s=1.0, t0=100.0)
    led.observe(_complete(0, 100.5, "phi", 0.05))
    led.observe(_complete(1, 105.5, "phi", 0.05))    # 4 silent windows
    assert [w.window for w in led.windows("phi")] == [0, 5]
    # unscored labels contribute completions but no windows at all
    led.observe(_complete(2, 100.6, "unscored", 9.9))
    assert led.windows("unscored") == []
    assert led.completed()["unscored"] == 1
    assert "unscored" not in led.attainment()
    # an explicitly empty window reports NaN attainment, not a crash
    assert math.isnan(WindowAttainment(0, 101.0, "phi", 0, 0).attainment)


def test_handoff_and_migration_pauses_never_double_count():
    led = SLOLedger(window_s=1.0, t0=0.0)
    mk = lambda seq, reason: Event(
        seq, 0.5, "migration.pause", "e0", seq, "phi",
        {"pause_s": 0.01, "reason": reason})
    led.observe(mk(0, "handoff"))
    led.observe(mk(1, "retire"))
    led.observe(mk(2, "handoff"))
    led.observe(mk(3, ""))
    acc = led.pause_accounting()
    assert acc["handoff"]["count"] == 2
    assert acc["migration"]["count"] == 2
    assert acc["handoff"]["total_s"] == pytest.approx(0.02)
    assert acc["migration"]["total_s"] == pytest.approx(0.02)
    # every pause lands in exactly one cause: totals add up
    total = sum(acc[c]["total_s"] for c in ("handoff", "migration"))
    assert total == pytest.approx(0.04)
