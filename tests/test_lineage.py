"""Unit tests for `repro.obs.lineage` — per-request critical-path
attribution assembled from synthetic event streams (no serving stack:
the full-stack path is exercised by benchmarks/watchtower.py and the
docs example).

Covers: exact conservation on consistent streams, residual semantics
(queue wait / decode compute), pre- vs post-admission handoff split,
PREPARE-window overlap, partial-request exclusion, violation flagging,
per-label critical-path aggregation, and Chrome flow stitching through
`repro.obs.trace.export_chrome`.
"""
import json

import pytest

from repro.obs import (
    Recorder,
    RequestLineage,
    TPOT_COMPONENTS,
    TTFT_COMPONENTS,
    validate_chrome,
)
from repro.obs.events import Event


def _ev(seq, ts, kind, engine="e0", rid=0, label="phi", **data):
    return Event(seq, ts, kind, engine, rid, label, data)


def _basic_request(rid=7, t0=10.0, engine="e0"):
    """A consistent submit/admit/complete triple: TTFT 0.5 (0.08
    prefill + 0.02 admission + 0.4 queue), decode span 0.4 over 4
    steps."""
    return [
        _ev(0, t0, "request.submit", engine, rid),
        _ev(1, t0 + 0.5, "request.admit", engine, rid,
            admit_s=0.02, prefill_s=0.08),
        _ev(2, t0 + 0.9, "request.complete", engine, rid,
            ttft_s=0.5, tpot_s=0.1, tokens_out=5),
    ]


def test_consistent_stream_conserves_exactly():
    lin = RequestLineage.from_events(_basic_request())
    assert len(lin) == 1 and not lin.partial_rids
    tl = lin.get(7)
    assert tl.ttft_parts["queue_wait"] == pytest.approx(0.4)
    assert tl.ttft_parts["admission"] == pytest.approx(0.02)
    assert tl.ttft_parts["prefill"] == pytest.approx(0.08)
    assert sum(tl.ttft_parts.values()) == pytest.approx(tl.ttft_s)
    assert tl.decode_steps == 4
    assert tl.decode_span_s == pytest.approx(0.4)
    assert tl.tpot_parts["decode"] == pytest.approx(0.4)
    assert tl.ttft_error() < 1e-12 and tl.tpot_error() < 1e-12
    assert tl.critical("ttft") == "queue_wait"
    assert tl.critical("tpot") == "decode"
    cons = lin.conservation()
    assert cons["violations"] == []
    assert cons["ttft_max_rel_err"] < 1e-12
    assert set(tl.ttft_parts) == set(TTFT_COMPONENTS)
    assert set(tl.tpot_parts) == set(TPOT_COMPONENTS)


def test_post_admit_migration_pause_comes_out_of_decode():
    events = _basic_request()
    # a 0.05s migration pause mid-decode, landing the request on e1
    events.insert(2, _ev(9, 10.7, "migration.pause", "e0", 7,
                         pause_s=0.05, dst="e1", reason="retire"))
    events[-1] = _ev(2, 10.9, "request.complete", "e1", 7,
                     ttft_s=0.5, tpot_s=0.1, tokens_out=5)
    tl = RequestLineage.from_events(events).get(7)
    assert tl.tpot_parts["migration_pause"] == pytest.approx(0.05)
    assert tl.tpot_parts["decode"] == pytest.approx(0.35)
    assert tl.tpot_parts["handoff_pause"] == 0.0
    assert tl.engines == ("e0", "e1")
    assert tl.hops == ((pytest.approx(10.65), 10.7, "e0", "e1",
                        "retire"),)
    assert tl.tpot_error() < 1e-12


def test_pre_admit_handoff_lands_in_ttft_not_decode():
    rid, t0 = 3, 20.0
    events = [
        _ev(0, t0, "request.submit", "prefill0", rid),
        # disaggregated first-token handoff BEFORE the decode admit
        _ev(1, t0 + 0.3, "migration.pause", "prefill0", rid,
            pause_s=0.04, dst="decode0", reason="handoff"),
        _ev(2, t0 + 0.5, "request.admit", "decode0", rid),
        _ev(3, t0 + 0.7, "request.complete", "decode0", rid,
            ttft_s=0.5, tpot_s=0.1, tokens_out=3),
    ]
    tl = RequestLineage.from_events(events).get(rid)
    assert tl.ttft_parts["handoff_pause"] == pytest.approx(0.04)
    assert tl.ttft_parts["queue_wait"] == pytest.approx(0.46)
    assert tl.tpot_parts["handoff_pause"] == 0.0      # never double
    assert tl.engines == ("prefill0", "decode0")
    assert tl.ttft_error() < 1e-12 and tl.tpot_error() < 1e-12


def test_prepare_window_overlap_is_attributed():
    events = _basic_request()
    # a committed swap on the admitting engine, 0.1s of downtime fully
    # inside the request's [submit, admit] interval
    events.insert(1, _ev(9, 10.4, "cluster.swap", "e0", -1, "",
                         downtime_s=0.1))
    tl = RequestLineage.from_events(events).get(7)
    assert tl.ttft_parts["prepare_wait"] == pytest.approx(0.1)
    assert tl.ttft_parts["queue_wait"] == pytest.approx(0.3)
    assert tl.ttft_error() < 1e-12
    # a swap on a DIFFERENT engine attributes nothing
    events[1] = _ev(9, 10.4, "cluster.swap", "other", -1, "",
                    downtime_s=0.1)
    tl = RequestLineage.from_events(events).get(7)
    assert tl.ttft_parts["prepare_wait"] == 0.0


def test_partial_requests_are_excluded_not_guessed():
    events = _basic_request()
    events.append(_ev(5, 11.0, "request.complete", "e0", 99,
                      ttft_s=0.1, tpot_s=0.01, tokens_out=2))
    lin = RequestLineage.from_events(events)
    assert len(lin) == 1
    assert lin.partial_rids == [99]
    assert lin.get(99) is None
    assert lin.conservation()["n_partial"] == 1


def test_inconsistent_measurement_is_flagged():
    events = _basic_request()
    # engine claims a TTFT twice what the event stream supports
    events[-1] = _ev(2, 10.9, "request.complete", "e0", 7,
                     ttft_s=1.0, tpot_s=0.1, tokens_out=5)
    cons = RequestLineage.from_events(events).conservation(eps=0.01)
    assert cons["violations"] == [7]
    assert cons["ttft_max_rel_err"] == pytest.approx(0.5)


def test_critical_path_aggregates_per_label():
    events = []
    seq = 0
    for i, (label, queue) in enumerate([("phi", 0.4), ("phi", 0.6),
                                        ("gen", 0.01)]):
        rid, t0 = 100 + i, 50.0 + i
        events += [
            _ev(seq, t0, "request.submit", "e0", rid, label),
            _ev(seq + 1, t0 + queue + 0.08, "request.admit", "e0", rid,
                label, prefill_s=0.08),
            _ev(seq + 2, t0 + queue + 0.28, "request.complete", "e0",
                rid, label, ttft_s=queue + 0.08, tpot_s=0.1,
                tokens_out=3),
        ]
        seq += 3
    cp = RequestLineage.from_events(events).critical_path()
    assert cp["phi"]["n"] == 2 and cp["gen"]["n"] == 1
    assert cp["phi"]["ttft"]["dominant_p99"] == "queue_wait"
    assert cp["phi"]["ttft"]["p99"]["queue_wait"] == pytest.approx(0.6)
    assert cp["gen"]["ttft"]["dominant_p99"] == "prefill"
    assert cp["phi"]["tpot"]["dominant_p50"] == "decode"


def test_chrome_flows_round_trip_through_export(tmp_path):
    rec = Recorder()
    with rec.span("decode", track="e0", rid=7) as _:
        pass
    with rec.span("decode", track="e1", rid=7) as _:
        pass
    events = _basic_request()
    events.insert(2, _ev(9, 10.7, "migration.pause", "e0", 7,
                         pause_s=0.05, dst="e1", reason="retire"))
    events[-1] = _ev(2, 10.9, "request.complete", "e1", 7,
                     ttft_s=0.5, tpot_s=0.1, tokens_out=5)
    lin = RequestLineage.from_events(events)
    flows = lin.chrome_flows()
    assert [f["ph"] for f in flows] == ["s", "f"]
    assert flows[0]["track"] == "e0" and flows[1]["track"] == "e1"
    assert flows[0]["id"] == flows[1]["id"] == 7 * 16
    path = tmp_path / "trace.json"
    rec.export_chrome(str(path), flows=flows)
    doc = json.loads(path.read_text())
    assert validate_chrome(doc) > 0
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"s", "f"} <= phases


def test_from_recorder_matches_from_events():
    rec = Recorder()
    for ev in _basic_request():
        rec.bus.emit(ev.kind, engine=ev.engine, rid=ev.rid,
                     label=ev.label, ts=ev.ts, **ev.data)
    a = RequestLineage.from_recorder(rec)
    b = RequestLineage.from_events(rec.events())
    assert len(a) == len(b) == 1
    assert a.get(7).ttft_parts == b.get(7).ttft_parts
