"""End-to-end behaviour tests for the paper's system: intent in natural
language -> interpretation -> compilation -> fail-closed validation ->
applied state, coordinated with the serving/training substrate."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.configs.base import get_shape_cell
from repro.core import DEFAULT_WORKLOAD, Orchestrator, satisfies
from repro.core.reconfig import ReconfigEngine
from repro.models import build_model
from repro.serving import Request, ServingEngine
from repro.sharding import ShardingPlan, batch_specs, cache_specs, param_specs


def test_e2e_hybrid_intent_applies_coordinated_state():
    orch = Orchestrator()
    r = orch.submit(
        "Place phi workloads on eu nodes and ensure their traffic avoids "
        "untrusted switches.")
    assert r.success, [c.detail for c in r.report.checks if not c.passed]
    # compute layer: all phi components on the EU pod (pod0)
    phi = [c.name for c in DEFAULT_WORKLOAD if c.labels["data-type"] == "phi"]
    assert all(orch.state.placement[n] == 0 for n in phi)
    # network layer: flow rules installed
    assert orch.state.flow_rules
    # satisfaction relation agrees with the validator
    ok, msgs = satisfies(r.policy.intent, r.policy.config, orch.fabric,
                         orch.components)
    assert ok, msgs


def test_e2e_metrics_shape_matches_paper_table7():
    """The orchestrator exposes exactly the paper's per-intent metrics."""
    orch = Orchestrator()
    r = orch.submit("Keep the phi database on high-security infrastructure.")
    assert r.report.n_checks >= 1
    assert r.prompt_tokens > 0 and r.completion_tokens > 0
    assert set(r.timings) == {"state_query", "interpret", "compile",
                              "validate", "apply"}


def test_e2e_intent_driven_serving_reconfiguration():
    """Intent change mid-serving: plans recompiled and swapped, tokens
    unchanged, downtime recorded (the band's downtime/TTFT/TPOT view)."""
    cfg = dataclasses.replace(get_reduced_config("qwen2_moe_a2_7b"),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, n_slots=2, s_max=32)
    rng = np.random.default_rng(0)
    for rid in range(2):
        eng.submit(Request(
            rid, rng.integers(2, cfg.vocab_size, size=5).astype(np.int32),
            max_new_tokens=3, labels={"data-type": "phi"}))
    eng.step()

    orch = Orchestrator()
    res = orch.submit("Phi traffic must remain inside the pod.")
    assert res.success
    assert any("phi" in k for k in orch.state.plans), orch.state.plans

    rc = ReconfigEngine(eng)
    report = rc.reconfigure()     # swap executables per the new plan
    eng.run()
    rc.finalize_metrics(report)
    assert report.downtime_s >= 0.0
    assert eng.metrics()["completed"] == 2


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_tree_matches_params(arch):
    """Every param leaf has a spec leaf of rank <= array rank (structure
    drift between models and sharding plans breaks the dry-run)."""
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    shapes = model.param_shapes(max_seq=64)
    specs = param_specs(cfg, ShardingPlan())
    jax.tree.map(lambda s, p: None, shapes, specs)  # same structure or raises
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(
        x, jax.sharding.PartitionSpec))
    for s, p in zip(flat_s, flat_p):
        assert len(p) <= len(s.shape), (arch, s.shape, p)


@pytest.mark.parametrize("arch", ["minitron_4b", "qwen2_moe_a2_7b",
                                  "mamba2_370m", "jamba_v0_1_52b",
                                  "whisper_large_v3"])
def test_cache_specs_tree_matches_cache(arch):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    cache = model.cache_shapes(4, 32)
    specs = cache_specs(cfg, ShardingPlan(seq_axis="model"), batch=4)
    jax.tree.map(lambda s, p: None, cache, specs)


def test_batch_specs_cover_all_inputs():
    for arch in ("whisper_large_v3", "qwen2_vl_2b", "minitron_4b"):
        cfg = get_reduced_config(arch)
        cell = get_shape_cell("train_4k")
        specs = batch_specs(cfg, ShardingPlan(), cell)
        assert "tokens" in specs and "loss_mask" in specs
        if cfg.encdec is not None:
            assert "frames" in specs
        if cfg.pos_type == "mrope":
            assert "positions" in specs
