"""Concurrent PREPARE: background compilation overlapped with live serving.

Covers the pending-swap state machine (PREPARING -> READY -> SWAPPED with
cancellation/supersession — a superseded ticket's executables are provably
never installed), the non-blocking `reconfigure_async`/`spawn_engine_async`
paths committing at step boundaries, the autoscaler's async spawns, the
orchestrator riding the async path, and a multi-threaded stress run (N
submitter threads against in-flight reconfigures/spawns: no routing to an
engine mid-swap, no dropped requests, every DowntimeReport finalized).

Run the stress tests standalone with faulthandler armed:

    make test-stress      # PYTHONFAULTHANDLER=1 pytest tests/test_concurrent_prepare.py
"""
import threading
import time

import numpy as np
import pytest
from conftest import make_engine, make_request

from repro.core import Orchestrator
from repro.serving import (
    METRIC_KEYS,
    Autoscaler,
    LoadTracker,
    PrepareCancelled,
    ServingCluster,
)
from repro.sharding import ShardingPlan, default_plan

PINNED = ShardingPlan(device_constraints=(("pod", 0),),
                      forbidden_collective_axes=("pod",))

# generous wall-clock cap for "serve until the background compile lands"
# loops — they normally finish in a few seconds
DEADLINE_S = 300.0


def _serve_until_done(cluster, ticket, deadline_s=DEADLINE_S):
    """Keep stepping (serving continues) until the ticket is terminal;
    the swap commits inside `step()` at a safe boundary. Returns decode
    steps served while the ticket was still pending."""
    served = 0
    t0 = time.monotonic()
    while not ticket.done():
        assert time.monotonic() - t0 < deadline_s, \
            f"ticket stuck: {ticket!r}"
        n = cluster.step()
        served += n
        if n == 0:
            time.sleep(0.002)      # idle but the worker is still compiling
    return served


# ---------------------------------------------------------------------------
# the async lifecycle
# ---------------------------------------------------------------------------


def test_reconfigure_async_overlaps_serving_and_is_token_exact(fp32_model):
    """The headline property: reconfigure_async returns immediately,
    serving continues through PREPARE, the swap commits at a step
    boundary, and the token streams match an uninterrupted run."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=6).astype(np.int32)
               for _ in range(6)]
    from conftest import baseline_streams
    expect = baseline_streams(model, params, prompts, new=6, n_slots=2)

    cluster = ServingCluster()
    cluster.register("e0", make_engine(model, params))
    from repro.serving import Request
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs[:4]:
        cluster.submit(r)
    cluster.step()

    ticket = cluster.reconfigure_async("e0", PINNED, prefill_lengths=(6,))
    assert not ticket.done()                      # returned immediately
    assert cluster.prepare_pending() == [ticket]
    _serve_until_done(cluster, ticket)
    assert ticket.state == "swapped"

    report = ticket.result()
    assert report.engine == "e0" and report.event == "reconfigure"
    assert report.compiled_in_prepare >= 1
    assert report.downtime_s < report.prepare_s   # window never compiles
    assert cluster.engine("e0").plan is PINNED

    for r in reqs[4:]:                            # post-swap traffic
        cluster.submit(r)
    cluster.run()
    assert cluster.pending_reports() == []        # report finalized
    assert set(report.metrics_after) == set(METRIC_KEYS)
    assert {r.rid: list(r.tokens_out) for r in reqs} == expect


def test_superseded_pending_swap_never_installs_executables(fp32_model):
    """Supersession is provable: let ticket A finish its compile (READY),
    supersede it with B before any step boundary — A must be CANCELLED
    and exactly ONE swap_plan installation may ever happen (B's)."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    eng = make_engine(model, params)
    cluster.register("e0", eng)

    installs = []
    real_swap = eng.swap_plan
    eng.swap_plan = lambda *a, **kw: (installs.append(kw.get("executables")),
                                      real_swap(*a, **kw))[1]

    ticket_a = cluster.reconfigure_async("e0", default_plan(),
                                         prefill_lengths=(6,))
    assert ticket_a.wait_ready(DEADLINE_S)        # compile FINISHED...
    assert ticket_a.state == "ready"              # ...but not committed
    ticket_b = cluster.reconfigure_async("e0", PINNED, prefill_lengths=(7,))
    assert ticket_a.state == "cancelled"          # superseded by B
    assert ticket_a.superseded_by is ticket_b

    _serve_until_done(cluster, ticket_b)
    assert ticket_b.state == "swapped"
    assert cluster.engine("e0").plan is PINNED
    assert len(installs) == 1                     # A's executables: never
    with pytest.raises(PrepareCancelled):
        ticket_a.result()
    # superseding a READY ticket leaves no stale pending state behind
    assert cluster.prepare_pending() == []


def test_ticket_cancel_before_commit_keeps_old_plan(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("e0", make_engine(model, params))
    old_plan = cluster.engine("e0").plan

    ticket = cluster.reconfigure_async("e0", PINNED)
    assert ticket.cancel()
    cluster.run(wait_pending=True)
    assert cluster.engine("e0").plan is old_plan
    assert cluster.prepare_pending() == []
    assert ticket.state == "cancelled"
    assert not ticket.cancel()                    # idempotently terminal


def test_retire_cancels_pending_ticket(fp32_model):
    """A retiring engine never swaps: retirement cancels its pending
    background PREPARE."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("e0", make_engine(model, params))
    cluster.register("e1", make_engine(model, params))
    ticket = cluster.reconfigure_async("e0", PINNED)
    cluster.retire_engine("e0")
    assert ticket.state == "cancelled"
    cluster.run(wait_pending=True)
    assert "e0" not in cluster.engines()


def test_spawn_engine_async_joins_pool_only_at_commit(fp32_model):
    cfg, model, params = fp32_model
    rng = np.random.default_rng(1)
    cluster = ServingCluster()
    cluster.register("base", make_engine(model, params))
    for rid in range(4):
        cluster.submit(make_request(rng, cfg, rid, "phi", new=3))

    ticket = cluster.spawn_engine_async(
        "phi-1", make_engine(model, params), labels={"data-type": "phi"},
        prefill_lengths=cluster.label_prompt_lengths("phi"))
    # invisible to routing until its swap commits; the name is reserved
    assert "phi-1" not in cluster.engines()
    assert cluster.pending_spawns() == ["phi-1"]
    with pytest.raises(ValueError):
        cluster.spawn_engine_async("phi-1", make_engine(model, params))
    with pytest.raises(ValueError):       # register honors the reservation
        cluster.register("phi-1", make_engine(model, params))

    _serve_until_done(cluster, ticket)
    assert ticket.state == "swapped"
    assert "phi-1" in cluster.engines()
    report = ticket.result()
    assert report.event == "spawn" and report.compiled_in_prepare >= 1
    # post-commit traffic closes the spawn's metrics_after window (the
    # pre-spawn wave may have fully drained before the commit landed)
    for rid in range(10, 14):
        cluster.submit(make_request(rng, cfg, rid, "phi", new=3))
    cluster.run()
    assert cluster.pending_reports() == []
    assert report.metrics_after["completed"] > 0


def test_autoscaler_async_spawn_never_stalls_tick_and_never_doubles(
        fp32_model):
    """With async_spawn the tick that decides a spawn returns without
    compiling; while the label's spawn is in flight, further spawn
    decisions for it are suppressed (no capacity double-request)."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", make_engine(model, params))
    scaler = Autoscaler(cluster, lambda label: make_engine(model, params),
                        tracker=LoadTracker(alpha=1.0), async_spawn=True)
    # the unlabeled base engine already serves phi: floor 2 forces one
    # dedicated spawn
    scaler.set_bounds("phi", 2)

    t0 = time.monotonic()
    decisions = scaler.tick()
    tick_s = time.monotonic() - t0
    assert [d.kind for d in decisions] == ["spawn"]
    assert len(scaler.pending_spawns()) == 1
    # the tick staged the compile but did not wait for it
    ticket = scaler._pending[0][1]
    if not ticket.done():
        assert tick_s < ticket.prepare_s + 1.0 or ticket.prepare_s == 0.0

    # while in flight: the floor is still unmet but no second spawn fires
    for _ in range(3):
        for d in scaler.tick():
            assert not (d.kind == "spawn" and d.label == "phi")
        cluster.step()

    deadline = time.monotonic() + DEADLINE_S
    while scaler.pending_spawns() and time.monotonic() < deadline:
        cluster.step()
        time.sleep(0.002)
        scaler.tick()
    assert len(cluster.engines_for_label("phi")) == 2
    spawn_events = [(d, r) for d, r in scaler.events if d.kind == "spawn"]
    assert len(spawn_events) == 1                 # exactly one spawn
    assert spawn_events[0][1].event == "spawn"    # with its real report


def test_failed_spawn_releases_its_name_reservation(fp32_model):
    """A spawn whose PREPARE fails (or is cancelled) must not squat on
    its engine name: register/spawn under the same name work again
    without waiting for a step boundary to sweep the dead ticket."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    eng = make_engine(model, params)
    eng.aot_executables = lambda *a, **kw: (_ for _ in ()).throw(
        RuntimeError("boom"))
    ticket = cluster.spawn_engine_async("phi-1", eng)
    assert ticket.wait(DEADLINE_S) and ticket.state == "failed"
    with pytest.raises(RuntimeError, match="boom"):
        ticket.result()
    assert cluster.pending_spawns() == []      # reservation released
    cluster.register("phi-1", make_engine(model, params))
    assert "phi-1" in cluster.engines()


def test_autoscaler_failed_async_spawn_surfaces_and_backs_off(fp32_model):
    """A FAILED background spawn must land in ``scaler.failures`` (never
    silently vanish) and hold the label off for ``cooldown`` ticks — a
    deterministic PREPARE failure must not loop one doomed compile per
    tick forever."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", make_engine(model, params))

    def broken_factory(label):
        eng = make_engine(model, params)
        eng.aot_executables = lambda *a, **kw: (_ for _ in ()).throw(
            RuntimeError("compile backend exploded"))
        return eng

    scaler = Autoscaler(cluster, broken_factory,
                        tracker=LoadTracker(alpha=1.0), async_spawn=True)
    scaler.set_bounds("phi", 2)

    decisions = scaler.tick()             # stages the doomed spawn
    assert [d.kind for d in decisions] == ["spawn"]
    scaler._pending[0][1].wait(DEADLINE_S)

    respawns = 0
    for _ in range(scaler.policy.cooldown):
        respawns += sum(d.kind == "spawn" for d in scaler.tick())
    assert respawns == 0                  # backoff held the label
    assert len(scaler.failures) == 1      # surfaced exactly once
    d, err = scaler.failures[0]
    assert d.label == "phi" and "exploded" in str(err)
    assert scaler.events == []            # no phantom capacity reported


def test_orchestrator_async_reconfig_finalizes_on_commit(fp32_model):
    """submit(apply_to=cluster, async_reconfig=True) returns tickets;
    serving continues, the swap commits at a step boundary, and the
    DowntimeReport finalizes with post-swap traffic."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(2)
    cluster = ServingCluster()
    cluster.register("edge0", make_engine(model, params))
    for rid in range(2):
        cluster.submit(make_request(rng, cfg, rid, "phi", new=3))

    orch = Orchestrator()
    res = orch.submit("Phi traffic must remain inside the pod.",
                      apply_to=cluster, async_reconfig=True)
    assert res.success
    ticket = res.reports["edge0"]
    assert not isinstance(ticket, dict)
    assert hasattr(ticket, "state")               # a PrepareTicket
    _serve_until_done(cluster, ticket)
    report = ticket.result()
    assert report.engine == "edge0"
    assert "pod" in cluster.engine("edge0").plan.forbidden_collective_axes

    cluster.submit(make_request(rng, cfg, 100, "phi", new=3))
    cluster.run()
    assert cluster.pending_reports() == []
    # the pre-swap wave may drain before OR after the commit (the compile
    # races real serving) — the invariant is that the post-swap window
    # finalized and saw at least the post-commit request
    assert report.metrics_after["completed"] >= 1


# ---------------------------------------------------------------------------
# multi-threaded stress
# ---------------------------------------------------------------------------


N_THREADS = 4
PER_THREAD = 10


@pytest.fixture
def flight_recorder():
    """Record the test body; always uninstalls, even on failure."""
    from repro.obs import Recorder, recording
    with recording(Recorder()) as rec:
        yield rec


def test_stress_concurrent_submit_reconfigure_spawn(fp32_model,
                                                    flight_recorder):
    """N submitter threads race against reconfigure_async (twice — the
    second supersedes the first), spawn_engine_async, and the serving
    loop. Invariants: no request is ever routed to an engine inside its
    blocking swap window, nothing is dropped or rejected, every ticket
    terminates, and every DowntimeReport finalizes — and the recorded
    trace PROVES the routing invariant: no route span interleaves any
    commit window."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("e0", make_engine(model, params, n_slots=2))
    cluster.register("e1", make_engine(model, params, n_slots=2))

    reqs = [[] for _ in range(N_THREADS)]
    errors = []

    def submitter(tid):
        rng = np.random.default_rng(100 + tid)
        try:
            for i in range(PER_THREAD):
                r = make_request(rng, cfg, tid * 1000 + i, new=3)
                reqs[tid].append(r)
                cluster.submit(r)
                time.sleep(0.001)
        except Exception as e:            # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=submitter, args=(tid,))
               for tid in range(N_THREADS)]
    for t in threads:
        t.start()

    # fire the async control-plane storm while the submitters run
    t_a = cluster.reconfigure_async("e0", default_plan(), prefill_lengths=(6,))
    t_b = cluster.reconfigure_async("e0", PINNED, prefill_lengths=(6,))
    t_spawn = cluster.spawn_engine_async("e2", make_engine(model, params),
                                         prefill_lengths=(6,))
    tickets = [t_b, t_spawn]

    deadline = time.monotonic() + DEADLINE_S
    while (any(t.is_alive() for t in threads)
           or not all(t.done() for t in tickets)):
        assert time.monotonic() < deadline, "stress run wedged"
        if cluster.step() == 0:
            time.sleep(0.001)
    for t in threads:
        t.join()
    cluster.run(wait_pending=True)

    assert errors == []
    # 1. the superseded swap was cancelled; the rest committed
    assert t_a.state == "cancelled"
    assert t_b.state == "swapped" and t_spawn.state == "swapped"
    assert cluster.engine("e0").plan is PINNED
    assert "e2" in cluster.engines()
    # 2. no routing decision ever chose an engine mid-swap
    assert cluster.midswap_routes == 0
    # 3. no dropped requests: everything submitted completed exactly once
    submitted = [r for per_thread in reqs for r in per_thread]
    assert len(submitted) == N_THREADS * PER_THREAD
    assert cluster.rejected == []
    assert cluster.metrics()["completed"] == len(submitted)
    assert all(len(r.tokens_out) == r.max_new_tokens for r in submitted)
    # 4. every report finalized after the post-event windows closed
    rng = np.random.default_rng(999)
    for rid in range(4):                  # post-swap wave on every engine
        cluster.submit(make_request(rng, cfg, 5000 + rid, new=2))
    cluster.run()
    assert cluster.pending_reports() == []
    for report in cluster.history:
        assert set(report.metrics_before) == set(METRIC_KEYS)
        assert set(report.metrics_after) == set(METRIC_KEYS)
        assert report.downtime_s < report.prepare_s or report.prepare_s == 0.0
    # 5. the trace proves invariant (2) span-by-span: routing and swap
    #    commits serialize on the cluster lock, so no route span may
    #    strictly overlap ANY commit span (swap or spawn) — not merely
    #    "no route chose a mid-swap engine", but "no routing decision
    #    was even being made while a commit window was open"
    from repro.obs import overlaps
    commits = [s for s in flight_recorder.trace.spans()
               if s.name in ("swap.commit", "spawn.commit")]
    routes = flight_recorder.trace.spans("route")
    assert len([s for s in commits if s.name == "swap.commit"]) >= 1
    assert len([s for s in commits if s.name == "spawn.commit"]) >= 1
    assert len(routes) >= N_THREADS * PER_THREAD
    clashes = [(r, c) for c in commits for r in routes if overlaps(r, c)]
    assert clashes == []
    # the ticket lifecycle landed on the bus, terminal states included
    states = {e.kind for e in flight_recorder.events("ticket")}
    assert {"ticket.preparing", "ticket.ready", "ticket.swapped",
            "ticket.cancelled"} <= states
