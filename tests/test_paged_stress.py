"""Paged-pool fragmentation stress (part of `make test-stress`): a high-
churn mixed-length trace that scrambles the free list until page
allocations are physically discontiguous, then checks the three
invariants fragmentation must never break:

  * token streams stay bitwise identical to the slot-granular oracle
    (page-table indirection hides physical layout from decode);
  * the allocator never leaks — every page returns to the free list
    once the trace drains;
  * admission keeps failing closed under pressure (queued, never
    dropped) and every request eventually completes.
"""
import numpy as np
from conftest import baseline_streams as _baseline_streams
from conftest import make_engine as _mk

from repro.serving import Request


def test_fragmentation_churn_streams_and_pool_integrity(fp32_model):
    cfg, model, params = fp32_model
    rng = np.random.default_rng(42)
    # bimodal lengths with interleaved retirement order: short requests
    # free small page runs inside long requests' extents, so the LIFO
    # free list hands later arrivals discontiguous pages
    sizes, news = [], []
    for i in range(60):
        if i % 3 == 2:
            sizes.append(int(rng.integers(12, 25)))
            news.append(int(rng.integers(6, 9)))
        else:
            sizes.append(int(rng.integers(3, 8)))
            news.append(int(rng.integers(2, 5)))
    prompts = [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in sizes]
    expect = {}
    for i in range(0, 60, 12):            # oracle in slot-sized batches
        expect.update({rid + i: toks for rid, toks in _baseline_streams(
            model, params, prompts[i:i + 12],
            new=max(news[i:i + 12])).items()})

    # small pages + a tight budget: constant alloc/free churn under load
    eng = _mk(model, params, n_slots=8, s_max=32, page_size=4,
              kv_tokens=160)
    reqs = [Request(i, p.copy(), max_new_tokens=news[i])
            for i, p in enumerate(prompts)]
    fragmented = False
    it = iter(reqs)
    pending = next(it, None)
    for _ in range(2000):
        # open-loop arrivals: two submissions per step keeps the queue hot
        for _ in range(2):
            if pending is not None:
                eng.submit(pending)
                pending = next(it, None)
        eng.step()
        fragmented = fragmented or any(
            pages and pages != list(range(pages[0], pages[0] + len(pages)))
            for pages in eng.slot_pages)
        if pending is None and not eng.load:
            break
    assert pending is None and eng.load == 0, "trace did not drain"
    assert fragmented, "trace never fragmented the pool (stress is vacuous)"

    # streams survived physical discontiguity bitwise (oracle ran with a
    # larger budget, so compare the prefix each request actually asked for)
    for r in reqs:
        assert r.tokens_out == expect[r.rid][: len(r.tokens_out)]
        assert len(r.tokens_out) == r.max_new_tokens
    # and the allocator is pristine again
    assert eng.pool.free_pages == eng.pool.n_pages
    assert eng.kv_allocated_tokens == 0
    assert len(eng.done) == len(reqs)
