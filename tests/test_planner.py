"""Tests for the workload-aware configuration planner (repro.planner):
device catalog, compiled-HLO roofline estimator (calibrated against
measured step latencies), fail-closed configuration search, heterogeneous
A100-vs-L40s choices, and PlanAction execution through the cluster's
ticketed async machinery.
"""
import math
import time

import numpy as np
import pytest

from conftest import make_engine, make_request

from repro.planner import (
    A100,
    L40S,
    DeviceProfile,
    EngineSpec,
    LabelDemand,
    TrafficMix,
    WorkloadPlanner,
    best_candidate,
    calibrate_host_profile,
    eligible_specs,
    estimate,
    features_from_engine,
    get_profile,
)
from repro.serving import LoadTracker, ServingCluster, ServingEngine
from repro.sharding.plan import ShardingPlan, default_plan


# ---------------------------------------------------------------------------
# catalog
# ---------------------------------------------------------------------------


def test_catalog_profiles():
    assert get_profile("a100").peak_flops > get_profile("l40s").peak_flops
    assert A100.hbm_bw > L40S.hbm_bw
    assert A100.mem_bytes > L40S.mem_bytes
    with pytest.raises(KeyError):
        get_profile("h100-that-does-not-exist")


def test_profile_pool_scales_compute_not_link():
    p4 = A100.pool(4)
    assert p4.total_flops == pytest.approx(4 * A100.peak_flops)
    assert p4.total_hbm_bw == pytest.approx(4 * A100.hbm_bw)
    assert p4.total_mem_bytes == pytest.approx(4 * A100.mem_bytes)
    assert p4.link_bw == A100.link_bw        # the wire does not scale
    assert p4.per_device().n_devices == 1
    with pytest.raises(ValueError):
        A100.pool(0)


def test_profile_scaled_preserves_ratios():
    a, l = A100.scaled(1e-6), L40S.scaled(1e-6)
    assert a.peak_flops / l.peak_flops == pytest.approx(
        A100.peak_flops / L40S.peak_flops)
    assert a.mem_bytes == A100.mem_bytes     # capacity is not a rate
    with pytest.raises(ValueError):
        A100.scaled(0.0)


def test_host_calibration_measures_positive_rates():
    host = calibrate_host_profile()
    assert host.peak_flops > 0 and host.hbm_bw > 0
    assert host.mem_bytes > 0 and host.link_bw > 0
    assert calibrate_host_profile() is host   # process-cached


# ---------------------------------------------------------------------------
# estimator
# ---------------------------------------------------------------------------


def _measured_step_s(engine, n_requests, *, steps=30):
    """Median wall-clock decode-step latency at full occupancy (the
    prefill + first step pay compilation; the clock starts after)."""
    rng = np.random.default_rng(0)
    cfg = engine.model.cfg
    for i in range(n_requests):
        engine.submit(make_request(rng, cfg, i, n=6, new=steps + 8))
    engine.step()                              # admit + compile
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        engine.step()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def test_estimator_ranking_matches_measured_step_latency(fp32_model):
    """SATELLITE (estimator calibration): the estimator's decode-step
    cost ranking over two plan/pool variants of the session model must
    match the measured per-variant step latencies on the calibrated host
    profile. Ranking, not absolute values — hardware-robust."""
    _, model, params = fp32_model
    small = make_engine(model, params, n_slots=2, s_max=32)
    big = make_engine(model, params, n_slots=8, s_max=128)

    host = calibrate_host_profile()
    est_small = estimate(features_from_engine(small), host)
    est_big = estimate(features_from_engine(big), host)
    meas_small = _measured_step_s(small, 2)
    meas_big = _measured_step_s(big, 8)

    assert est_small.step_s != est_big.step_s
    assert (est_small.step_s < est_big.step_s) \
        == (meas_small < meas_big), (
        f"estimator ranked {est_small.step_s:.2e} vs {est_big.step_s:.2e} "
        f"but measurement says {meas_small:.2e} vs {meas_big:.2e}")


def test_estimate_memory_fit_is_profile_sensitive(fp32_model):
    """The same engine fits a large-memory profile and fails a tiny one
    — the heterogeneity axis that prunes placements."""
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    big = DeviceProfile("big", 1e12, 1e12, mem_bytes=1e12, link_bw=1e12)
    tiny = DeviceProfile("tiny", 1e12, 1e12,
                         mem_bytes=feats.resident_bytes / 2, link_bw=1e12)
    assert estimate(feats, big).fits
    est = estimate(feats, tiny)
    assert not est.fits
    assert not est.meets(None, None)     # a misfit meets nothing


def test_estimate_load_sensitivity(fp32_model):
    """TTFT grows with utilization and diverges past capacity; TPOT is
    the roofline step time and is load-independent."""
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    host = calibrate_host_profile()
    idle = estimate(feats, host, TrafficMix(prompt_len=8, new_tokens=4,
                                            rate=0.0))
    cap = idle.throughput_tok_s / 4.0          # requests/s at capacity
    loaded = estimate(feats, host, TrafficMix(prompt_len=8, new_tokens=4,
                                              rate=0.5 * cap))
    swamped = estimate(feats, host, TrafficMix(prompt_len=8, new_tokens=4,
                                               rate=2.0 * cap))
    assert idle.ttft_s < loaded.ttft_s < math.inf
    assert math.isinf(swamped.ttft_s)
    assert idle.tpot_s == loaded.tpot_s == swamped.tpot_s
    # more engines absorb the same demand at lower utilization
    pooled = estimate(feats, host, TrafficMix(prompt_len=8, new_tokens=4,
                                              rate=0.5 * cap), engines=4)
    assert pooled.utilization < loaded.utilization


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------


def _flat_features(feats):
    return lambda spec: feats


def test_search_prunes_fail_closed(fp32_model):
    """A spec whose plan conflicts with the route constraint is never a
    candidate; with no surviving spec the label is INFEASIBLE (surfaced,
    not silently served)."""
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    required = ShardingPlan(device_constraints=(("pod", 0),))
    ok = EngineSpec(plan=default_plan())
    conflicted = EngineSpec(
        plan=default_plan().with_(device_constraints=(("pod", 1),)))
    kept = eligible_specs([ok, conflicted], required)
    assert len(kept) == 1
    assert dict(kept[0].plan.device_constraints).get("pod") == 0

    best = best_candidate(
        {"phi": LabelDemand(rate=1.0)}, {},
        specs=[conflicted], profiles=[calibrate_host_profile()],
        features_fn=_flat_features(feats),
        route_required={"phi": required})
    assert best.infeasible == ["phi"]
    assert "phi" not in best.config


def test_search_respects_scale_bounds(fp32_model):
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan())
    best = best_candidate(
        {"phi": LabelDemand(rate=0.0)}, {}, specs=[spec], profiles=[host],
        features_fn=_flat_features(feats), bounds={"phi": (2, 3)})
    assert best.config["phi"].count == 2       # floor is mandatory
    # zero demand and a zero floor -> no capacity at all
    best0 = best_candidate(
        {"phi": LabelDemand(rate=0.0)}, {}, specs=[spec], profiles=[host],
        features_fn=_flat_features(feats), bounds={"phi": (0, 3)})
    assert best0.config["phi"].count == 0


def test_search_picks_cheaper_profile_when_both_suffice(fp32_model):
    """With demand one engine of EITHER class can serve, the search
    takes the cheaper device (engine-seconds objective)."""
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    best = best_candidate(
        {"gen": LabelDemand(rate=0.0)}, {},
        specs=[EngineSpec(plan=default_plan())], profiles=[A100, L40S],
        features_fn=_flat_features(feats), bounds={"gen": (1, 2)})
    assert best.config["gen"].profile.name == "l40s"
    assert best.cost == pytest.approx(L40S.cost_rate)


def test_search_hetero_choice_differs_between_profiles(fp32_model):
    """ACCEPTANCE: the same demand picks a DIFFERENT configuration on an
    A100-like pool than on an L40s-like pool (fewer, bigger engines vs
    more, smaller ones) — demand derived from the estimator's own
    capacity numbers so the contract is model-agnostic."""
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    spec = EngineSpec(plan=default_plan())
    cap_a = estimate(feats, A100).throughput_tok_s
    demand = {"phi": LabelDemand(rate=0.7 * cap_a / 16.0)}
    best_a = best_candidate(demand, {}, specs=[spec], profiles=[A100],
                            features_fn=_flat_features(feats))
    best_l = best_candidate(demand, {}, specs=[spec], profiles=[L40S],
                            features_fn=_flat_features(feats))
    assert best_a.violations == 0 and best_l.violations == 0
    assert best_a.config["phi"].count < best_l.config["phi"].count


def test_search_slo_target_forces_capacity(fp32_model):
    """A TTFT target tightens the configuration: demand that one engine
    serves within the utilization ceiling still needs more engines once
    queue amplification would push TTFT past the target."""
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan())
    idle = estimate(feats, host, TrafficMix())
    # 80% utilization on one engine -> TTFT = 5x unloaded prefill
    demand = {"phi": LabelDemand(rate=0.8 * idle.throughput_tok_s / 16.0)}
    relaxed = best_candidate(demand, {}, specs=[spec], profiles=[host],
                             features_fn=_flat_features(feats))
    tight = best_candidate(
        demand, {"phi": (idle.prefill_s * 2.0, None)},
        specs=[spec], profiles=[host], features_fn=_flat_features(feats))
    assert tight.violations == 0
    assert tight.config["phi"].count > relaxed.config["phi"].count


# ---------------------------------------------------------------------------
# WorkloadPlanner end to end
# ---------------------------------------------------------------------------


def _mk_planner(model, params, cluster, profiles, **kw):
    def factory(spec, label):
        return make_engine(model, params, n_slots=spec.n_slots,
                           s_max=spec.s_max)
    spec = EngineSpec(plan=default_plan(), n_slots=2, s_max=32)
    kw.setdefault("dwell", 0)
    return WorkloadPlanner(cluster, factory, specs=[spec],
                           profiles=profiles, **kw)


def test_planner_spawns_through_async_tickets(fp32_model):
    """Demand with no capacity -> spawn PlanActions executed through
    `spawn_engine_async`; the engines join at step boundaries and a
    repeat plan holds still (hysteresis)."""
    _, model, params = fp32_model
    cluster = ServingCluster()
    planner = _mk_planner(model, params, cluster, [A100])
    cap = estimate(planner.features_for(planner.specs[0]),
                   A100).throughput_tok_s
    demand = {"phi": LabelDemand(rate=0.7 * cap / 16.0)}
    actions = planner.plan(demand)
    assert [a.kind for a in actions] == ["spawn"]
    from repro.serving import PrepareTicket
    results = planner.execute(actions, async_spawn=True)
    assert all(isinstance(r, PrepareTicket) for _, r in results)
    assert cluster.pending_spawn_labels().get("phi", 0) \
        + len(cluster.engines_for_label("phi")) == 1
    # ticket-awareness: replanning while the spawn compiles adds nothing
    assert planner.plan(demand) == []
    cluster.run(wait_pending=True)
    assert len(cluster.engines_for_label("phi")) == 1
    assert cluster.engine(cluster.engines_for_label("phi")[0]) \
                  .labels["data-type"] == "phi"


def test_planner_scales_down_when_demand_stops(fp32_model):
    _, model, params = fp32_model
    cluster = ServingCluster()
    planner = _mk_planner(model, params, cluster, [A100])
    cap = estimate(planner.features_for(planner.specs[0]),
                   A100).throughput_tok_s
    planner.execute(planner.plan(
        {"phi": LabelDemand(rate=0.7 * cap / 16.0)}), async_spawn=False)
    assert len(cluster.engines_for_label("phi")) == 1
    actions = planner.plan({"phi": LabelDemand(rate=0.0)})
    assert [a.kind for a in actions] == ["retire"]
    planner.execute(actions)
    cluster.run()
    assert cluster.engines_for_label("phi") == []


def test_planner_dwell_suppresses_flapping(fp32_model):
    """After acting, a pure cost-saving switch must wait out the dwell
    AND amortize its switching cost; a floor violation bypasses both."""
    _, model, params = fp32_model
    cluster = ServingCluster()
    planner = _mk_planner(model, params, cluster, [A100], dwell=3,
                          horizon_s=0.0)       # nothing ever amortizes
    planner.bounds["phi"] = (1, 2)
    actions = planner.plan({})                 # floor: mandatory, acts
    assert [a.kind for a in actions] == ["spawn"]
    assert "floor" in actions[0].reason
    planner.execute(actions, async_spawn=False)
    # floor satisfied; with horizon 0 no cost-saving move ever fires
    assert planner.plan({}) == []


def test_planner_infeasible_label_holds_fail_closed(fp32_model):
    _, model, params = fp32_model
    cluster = ServingCluster()
    cluster.set_route_constraint(
        "phi", ShardingPlan(device_constraints=(("pod", 0),)))

    def factory(spec, label):
        return make_engine(model, params)
    planner = WorkloadPlanner(
        cluster, factory,
        specs=[EngineSpec(plan=default_plan().with_(
            device_constraints=(("pod", 1),)))],
        profiles=[A100], dwell=0)
    actions = planner.plan({"phi": LabelDemand(rate=1.0)})
    assert [a.kind for a in actions] == ["hold"]
    assert planner.execute(actions) == [(actions[0], None)]
    assert cluster.engines() == []             # nothing non-compliant ran


def test_planner_apply_policy_installs_slo_and_bounds(fp32_model):
    """Orchestrator.submit(apply_to=planner): Φ_L targets and Φ_S bounds
    flow from an English intent into the planner objective."""
    from repro.core import Orchestrator

    _, model, params = fp32_model
    cluster = ServingCluster()
    planner = _mk_planner(model, params, cluster, [A100])
    orch = Orchestrator()
    res = orch.submit("Keep TTFT under 200 ms for phi traffic, and keep "
                      "at least one serving engine for phi traffic.",
                      apply_to=planner)
    assert res.success, res.report.summary()
    assert planner.slo_targets["phi"] == (pytest.approx(0.2), None)
    assert planner.bounds["phi"] == (1, None)
    assert orch.state.slo_targets["phi"][0] == pytest.approx(0.2)
    # repeated pins intersect (tighter wins)
    planner.set_slo_target("phi", 0.5, 0.05)
    assert planner.slo_targets["phi"] == (pytest.approx(0.2),
                                          pytest.approx(0.05))


def test_autoscaler_planner_mode_records_events(fp32_model):
    """Autoscaler(planner=...) replaces threshold ticks with planner
    decisions; events/trajectory record uniformly and spawned capacity
    serves labeled traffic."""
    from repro.serving import Autoscaler

    _, model, params = fp32_model
    rng = np.random.default_rng(0)
    cfg = model.cfg
    cluster = ServingCluster()
    cluster.register("base0", make_engine(model, params))
    planner = _mk_planner(model, params, cluster, [A100])
    scaler = Autoscaler(cluster, lambda label: make_engine(model, params),
                        planner=planner, tracker=LoadTracker(alpha=1.0),
                        bounds={"phi": (1, 2)})
    for rid in range(4):
        cluster.submit(make_request(rng, cfg, rid, "phi"))
    executed = scaler.tick()
    assert any(d.kind == "spawn" and d.label == "phi" for d in executed)
    cluster.run()
    scaler.tick()
    assert any(d.kind == "spawn" for d, r in scaler.events)
    assert len(cluster.engines_for_label("phi")) >= 1
    assert scaler.trajectory          # per-tick snapshots recorded
    cluster.run()
    assert cluster.metrics()["completed"] == 4


# ---------------------------------------------------------------------------
# review regressions
# ---------------------------------------------------------------------------


def test_search_overload_scales_up_not_down(fp32_model):
    """When demand exceeds ANY enumerable capacity, the graded violation
    score still prefers the configuration covering the most demand — a
    binary score would tie all violators and let cost scale DOWN."""
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan())
    cap1 = estimate(feats, host).throughput_tok_s
    demand = {"phi": LabelDemand(rate=20.0 * cap1 / 16.0)}   # 20x capacity
    best = best_candidate(demand, {}, specs=[spec], profiles=[host],
                          features_fn=_flat_features(feats),
                          bounds={"phi": (0, 4)})
    assert best.config["phi"].count == 4
    assert best.violations > 0           # honestly still overloaded


def test_search_explicit_max_bound_not_capped(fp32_model):
    """An intent-pinned max above the default enumeration cap is honored
    as stated (the cap applies only to unbounded labels)."""
    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan())
    cap1 = estimate(feats, host).throughput_tok_s
    demand = {"phi": LabelDemand(rate=5.5 * cap1 / 16.0)}    # needs ~7
    best = best_candidate(demand, {}, specs=[spec], profiles=[host],
                          features_fn=_flat_features(feats),
                          bounds={"phi": (0, 8)},
                          max_engines_per_label=4)
    assert best.config["phi"].count > 4
    assert best.violations == 0


def test_planner_floor_via_plan_bounds_argument(fp32_model):
    """A floor passed through plan(bounds=...) — the Autoscaler
    planner-mode path — is as mandatory as one in planner.bounds: it
    bypasses dwell AND the amortization gate."""
    _, model, params = fp32_model
    cluster = ServingCluster()
    planner = _mk_planner(model, params, cluster, [A100], dwell=3,
                          horizon_s=0.0)       # nothing ever amortizes
    actions = planner.plan({}, bounds={"phi": (1, 2)})
    assert [a.kind for a in actions] == ["spawn"]
    assert "floor" in actions[0].reason


# ---------------------------------------------------------------------------
# online estimator calibration (ResidualCalibration)
# ---------------------------------------------------------------------------


def test_calibration_cold_start_equals_analytical_exactly(fp32_model):
    """ACCEPTANCE (fail-closed cold start): with ZERO observations the
    calibrated estimate is the analytical roofline, field for field —
    calibration can only move an estimate after evidence exists."""
    import dataclasses as dc

    from repro.planner import ResidualCalibration, calibrated_estimate

    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    host = calibrate_host_profile()
    calib = ResidualCalibration()
    analytical = estimate(feats, host, engines=2)
    assert calib.factors("phi") == (1.0, 1.0)
    assert calib.apply("phi", analytical) is analytical   # not a copy
    assert dc.asdict(calibrated_estimate(
        feats, host, engines=2, calibration=calib, label="phi")) \
        == dc.asdict(analytical)
    assert dc.asdict(calibrated_estimate(feats, host, engines=2)) \
        == dc.asdict(analytical)                          # no calibration


def test_calibration_strictly_reduces_error_on_corpus(fp32_model):
    """ACCEPTANCE: on a recorded observation corpus whose true latencies
    sit at a constant multiple of the roofline (mild noise), the EWMA
    residual correction strictly reduces one-step-ahead TTFT and TPOT
    error vs the uncorrected analytical estimate."""
    from repro.planner import ResidualCalibration

    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    host = calibrate_host_profile()
    base = estimate(feats, host)
    rng = np.random.default_rng(42)
    calib = ResidualCalibration(alpha=0.3)
    k_ttft, k_tpot = 1.8, 0.4             # systematic roofline residuals
    err_a = {"ttft": [], "tpot": []}
    err_c = {"ttft": [], "tpot": []}
    for _ in range(40):
        noise = 1.0 + 0.05 * rng.standard_normal(2)
        measured_ttft = base.ttft_s * k_ttft * float(noise[0])
        measured_tpot = base.tpot_s * k_tpot * float(noise[1])
        cal = calib.apply("phi", base)    # prediction BEFORE folding
        err_a["ttft"].append(abs(base.ttft_s - measured_ttft))
        err_a["tpot"].append(abs(base.tpot_s - measured_tpot))
        err_c["ttft"].append(abs(cal.ttft_s - measured_ttft))
        err_c["tpot"].append(abs(cal.tpot_s - measured_tpot))
        calib.observe("phi", predicted_ttft_s=base.ttft_s,
                      predicted_tpot_s=base.tpot_s,
                      measured_ttft_s=measured_ttft,
                      measured_tpot_s=measured_tpot)
    assert calib.n_observations("phi") == 40
    for key in ("ttft", "tpot"):
        assert np.mean(err_c[key]) < np.mean(err_a[key])
    # the learned factors converged near the true residuals
    f_ttft, f_tpot = calib.factors("phi")
    assert f_ttft == pytest.approx(k_ttft, rel=0.15)
    assert f_tpot == pytest.approx(k_tpot, rel=0.15)
    # and only the latency fields move — capacity/feasibility stay
    # analytical (calibration corrects time, not memory)
    cal = calib.apply("phi", base)
    assert cal.step_s == base.step_s
    assert cal.throughput_tok_s == base.throughput_tok_s
    assert cal.mem_bytes == base.mem_bytes and cal.fits == base.fits


def test_calibration_rejects_degenerate_observations(fp32_model):
    """Non-finite / non-positive measurements are ignored (a broken
    probe must not poison the EWMA), and absurd ratios clip to the
    configured cap instead of exploding the estimate."""
    from repro.planner import ResidualCalibration

    _, model, params = fp32_model
    feats = features_from_engine(make_engine(model, params))
    host = calibrate_host_profile()
    base = estimate(feats, host)
    calib = ResidualCalibration(ratio_cap=50.0)
    for bad in (float("nan"), float("inf"), 0.0, -1.0):
        calib.observe("phi", predicted_ttft_s=base.ttft_s,
                      predicted_tpot_s=base.tpot_s,
                      measured_ttft_s=bad, measured_tpot_s=bad)
    assert calib.n_observations("phi") == 0
    assert calib.factors("phi") == (1.0, 1.0)
    calib.observe("phi", predicted_ttft_s=base.ttft_s,
                  predicted_tpot_s=base.tpot_s,
                  measured_ttft_s=base.ttft_s * 1e6,    # absurd ratio
                  measured_tpot_s=base.tpot_s / 1e6)
    f_ttft, f_tpot = calib.factors("phi")
    assert f_ttft == pytest.approx(50.0)               # clipped high
    assert f_tpot == pytest.approx(1.0 / 50.0)         # clipped low
    with pytest.raises(ValueError):
        ResidualCalibration(alpha=1.5)
    with pytest.raises(ValueError):
        ResidualCalibration(ratio_cap=0.5)


def test_planner_observe_measurement_closes_loop(fp32_model):
    """Planner-level loop: `observe_measurement` pairs a measurement
    with the ANALYTICAL prediction for the deployed configuration (so
    repeated folding never compounds), and `predicted_for` then reports
    a calibrated estimate shifted by the learned residual."""
    from repro.planner import ResidualCalibration

    _, model, params = fp32_model
    cluster = ServingCluster()
    planner = _mk_planner(model, params, cluster, [A100],
                          calibration=ResidualCalibration(alpha=1.0))
    cap = estimate(planner.features_for(planner.specs[0]),
                   A100).throughput_tok_s
    demand = LabelDemand(rate=0.5 * cap / 16.0)
    planner.execute(planner.plan({"phi": demand}), async_spawn=False)
    analytical = planner.predicted_for("phi", demand, calibrated=False)
    assert analytical is not None
    # measured = 3x predicted TTFT, 0.5x predicted TPOT
    planner.observe_measurement("phi", demand,
                                measured_ttft_s=3.0 * analytical.ttft_s,
                                measured_tpot_s=0.5 * analytical.tpot_s)
    calibrated = planner.predicted_for("phi", demand)
    assert calibrated.ttft_s == pytest.approx(3.0 * analytical.ttft_s)
    assert calibrated.tpot_s == pytest.approx(0.5 * analytical.tpot_s)
    # analytical view is unchanged — the residual lives in the
    # calibration, not in the roofline
    again = planner.predicted_for("phi", demand, calibrated=False)
    assert again.ttft_s == pytest.approx(analytical.ttft_s)
