"""Unit tests for `repro.obs.alerts` — the Watchtower evaluator driven
by synthetic event streams (the full-stack detection-latency contracts
live in benchmarks/watchtower.py).

Covers: multi-window burn-rate semantics (both windows must agree, no
traffic is not a violation, re-fire after clearing), drop / stuck-
PREPARE / starved-label watchdogs, estimator-drift warm-up gating and
excursion dedup, fail-closed rule errors, mandatory-fix wiring, and
debug-bundle determinism + round-trip.
"""
import dataclasses

import pytest

from repro.obs import (
    Alert,
    AlertEvaluator,
    BurnRateRule,
    Recorder,
    bundle_events,
    load_bundle,
    replay_ledger,
)

TARGETS = {"phi": (0.1, None)}


def _complete(rec, ts, ttft_s, label="phi", rid=0):
    rec.bus.emit("request.complete", engine="e0", rid=rid, label=label,
                 ts=ts, ttft_s=ttft_s, tpot_s=0.01, tokens_out=4)


def _violations(rec, t0, t1, ttft_s=1.0, per_s=4):
    for i in range(int((t1 - t0) * per_s)):
        _complete(rec, t0 + i / per_s, ttft_s, rid=1000 + i)


def test_burn_rate_needs_both_windows():
    rec = Recorder()
    ev = AlertEvaluator(rec, slo_targets=TARGETS,
                        burn=BurnRateRule(goal=0.9, short_s=2.0,
                                          long_s=8.0, factor=4.0))
    # violations confined to the last 1s: short window burns hot, but
    # the long window has 8s of mostly-good evidence -> no page
    for i in range(28):
        _complete(rec, 100.0 + i * 0.25, 0.01, rid=i)       # 7s healthy
    _violations(rec, 107.0, 108.0)
    assert ev.poll(t=108.0) == []
    # sustained violations: both windows over budget -> one page
    _violations(rec, 108.0, 112.0)
    fired = ev.poll(t=112.0)
    assert [a.name for a in fired] == ["slo.burn_rate"]
    assert fired[0].label == "phi" and fired[0].severity == "page"
    # same ongoing condition: no duplicate
    assert ev.poll(t=112.5) == []


def test_burn_rate_no_traffic_is_not_a_violation():
    rec = Recorder()
    ev = AlertEvaluator(rec, slo_targets=TARGETS)
    assert ev.poll(t=50.0) == []                  # nothing scored: None
    assert ev.alerts == []


def test_burn_rate_refires_after_clearing():
    rec = Recorder()
    ev = AlertEvaluator(rec, slo_targets=TARGETS,
                        burn=BurnRateRule(short_s=2.0, long_s=4.0))
    _violations(rec, 100.0, 104.0)
    assert len(ev.poll(t=104.0)) == 1
    # incident ends; trailing windows go clean -> condition clears
    for i in range(32):
        _complete(rec, 104.0 + i * 0.25, 0.01, rid=2000 + i)
    assert ev.poll(t=112.0) == []
    # second incident -> fires again (new onset)
    _violations(rec, 112.0, 116.0)
    assert [a.name for a in ev.poll(t=116.0)] == ["slo.burn_rate"]
    assert sum(a.name == "slo.burn_rate" for a in ev.alerts) == 2


def test_drops_watchdog_fires_once():
    rec = Recorder(capacity=4)
    ev = AlertEvaluator(rec, slo_targets=TARGETS)
    for i in range(10):
        rec.bus.emit("request.submit", rid=i, label="phi", ts=float(i))
    fired = ev.poll(t=10.0)
    assert [a.name for a in fired] == ["obs.drops"]
    assert fired[0].severity == "warn" and fired[0].value == 6.0
    # the counter is monotone: the same degradation never re-pages
    rec.bus.emit("request.submit", rid=99, label="phi", ts=11.0)
    assert ev.poll(t=11.0) == []


def test_stuck_prepare_watchdog():
    rec = Recorder()
    ev = AlertEvaluator(rec, slo_targets=TARGETS, stuck_prepare_s=10.0)
    rec.bus.emit("ticket.preparing", engine="e1", ts=100.0)
    assert ev.poll(t=105.0) == []                 # young ticket: fine
    fired = ev.poll(t=111.0)
    assert [a.name for a in fired] == ["prepare.stuck"]
    assert fired[0].engine == "e1"
    rec.bus.emit("ticket.swapped", engine="e1", ts=112.0)
    assert ev.poll(t=130.0) == []                 # terminal: cleared


def test_starved_label_watchdog_and_mandatory_fix():
    calls = []

    class Stub:
        def mandatory_fix(self, label, reason=""):
            calls.append((label, reason))

    rec = Recorder()
    ev = AlertEvaluator(rec, slo_targets=TARGETS, starve_s=10.0,
                        planner=Stub(), scaler=Stub())
    rec.bus.emit("request.submit", rid=1, label="phi", ts=100.0)
    assert ev.poll(t=105.0) == []
    fired = ev.poll(t=111.0)
    assert [a.name for a in fired] == ["label.starved"]
    # labeled page alerts drive BOTH mandatory-fix targets
    assert calls == [("phi", "label.starved"), ("phi", "label.starved")]
    # admission progress clears the condition
    rec.bus.emit("request.admit", engine="e0", rid=1, label="phi",
                 ts=112.0)
    assert ev.poll(t=130.0) == []


class _Cal:
    """ResidualCalibration stand-in: fixed observation count + band."""

    def __init__(self, n=5, ratio_cap=8.0):
        self.n = n
        self.ratio_cap = ratio_cap

    def n_observations(self, label):
        return self.n

    def factors(self, label):
        return (1.0, 1.0)


def test_drift_respects_warmup_and_excursion_dedup():
    rec = Recorder()
    cold = AlertEvaluator(rec, slo_targets=TARGETS,
                          calibration=_Cal(n=0))
    assert cold.observe_prediction(
        "phi", predicted_ttft_s=0.01, predicted_tpot_s=0.01,
        measured_ttft_s=1.0, measured_tpot_s=1.0, t=1.0) is None

    ev = AlertEvaluator(rec, slo_targets=TARGETS, calibration=_Cal())
    assert ev.drift_band == 8.0                   # from ratio_cap
    kw = dict(predicted_ttft_s=0.01, predicted_tpot_s=0.01,
              measured_tpot_s=0.01)
    a = ev.observe_prediction("phi", measured_ttft_s=0.5, t=2.0, **kw)
    assert a is not None and a.name == "estimator.drift"
    assert a.value == pytest.approx(50.0) and a.threshold == 8.0
    # same excursion: deduplicated until the ratio returns to band
    assert ev.observe_prediction("phi", measured_ttft_s=0.6, t=3.0,
                                 **kw) is None
    assert ev.observe_prediction("phi", measured_ttft_s=0.01, t=4.0,
                                 **kw) is None    # back in band: clears
    a2 = ev.observe_prediction("phi", measured_ttft_s=0.5, t=5.0, **kw)
    assert a2 is not None                         # new excursion
    # an under-prediction ratio (1/ratio) trips the same band
    ev2 = AlertEvaluator(rec, slo_targets=TARGETS, calibration=_Cal())
    a3 = ev2.observe_prediction(
        "phi", predicted_ttft_s=1.0, predicted_tpot_s=1.0,
        measured_ttft_s=0.05, measured_tpot_s=1.0, t=6.0)
    assert a3 is not None and a3.value == pytest.approx(20.0)


def test_drift_band_must_exceed_one():
    with pytest.raises(ValueError):
        AlertEvaluator(Recorder(), slo_targets=TARGETS, drift_band=1.0)


def test_rule_crash_fails_closed_as_watchtower_error():
    class Broken:
        ratio_cap = 8.0

        def n_observations(self, label):
            raise RuntimeError("boom")

    rec = Recorder()
    ev = AlertEvaluator(rec, slo_targets=TARGETS, calibration=Broken())
    a = ev.observe_prediction(
        "phi", predicted_ttft_s=1.0, predicted_tpot_s=1.0,
        measured_ttft_s=1.0, measured_tpot_s=1.0, t=1.0)
    assert a is not None and a.name == "watchtower.error"
    assert a.severity == "page" and "boom" in a.message


def _bundled_evaluator(tmp_path, sub):
    rec = Recorder()
    _violations(rec, 100.0, 108.0)
    ev = AlertEvaluator(rec, slo_targets=TARGETS,
                        bundle_dir=str(tmp_path / sub))
    fired = ev.poll(t=108.0)
    assert len(fired) == 1 and fired[0].bundle
    return rec, ev, fired[0]


def test_bundles_are_byte_deterministic_and_round_trip(tmp_path):
    rec, ev, alert = _bundled_evaluator(tmp_path, "a")
    _, _, alert_b = _bundled_evaluator(tmp_path, "b")
    with open(alert.bundle, "rb") as f:
        blob_a = f.read()
    with open(alert_b.bundle, "rb") as f:
        blob_b = f.read()
    assert blob_a == blob_b                       # identical runs
    bundle = load_bundle(alert.bundle)
    assert bundle["alert"]["name"] == "slo.burn_rate"
    assert bundle_events(bundle) == list(rec.events())
    # re-derived SLO accounting matches the live ledger's
    led = replay_ledger(bundle)
    assert led.attainment() == ev.ledger.attainment()
    assert led.as_dict() == ev.ledger.as_dict()


def test_load_bundle_rejects_foreign_json(tmp_path):
    p = tmp_path / "x.json"
    p.write_text('{"format": "something-else"}')
    with pytest.raises(ValueError):
        load_bundle(str(p))


def test_bundle_capture_failure_does_not_lose_the_alert(tmp_path):
    blocked = tmp_path / "blocked"
    blocked.write_text("a file where a directory must go")
    rec = Recorder()
    _violations(rec, 100.0, 108.0)
    ev = AlertEvaluator(rec, slo_targets=TARGETS,
                        bundle_dir=str(blocked / "sub"))
    fired = ev.poll(t=108.0)
    assert len(fired) == 1
    assert fired[0].bundle == ""
    assert "bundle capture failed" in fired[0].message


def test_as_dicts_and_alert_shape():
    a = Alert("slo.burn_rate", "page", label="phi", t=1.0, value=10.0,
              threshold=4.0, message="m")
    d = dataclasses.asdict(a)
    assert d["name"] == "slo.burn_rate" and d["bundle"] == ""
