"""The first-class serving clock (`repro.serving.clock`).

Pins the promoted `FakeClock` semantics (formerly a private test
harness in conftest), the install/restore mechanism, and the contract
the scale harness rests on: `Autoscaler` and `WorkloadPlanner`
decisions are functions of ticks and the INJECTED clock only — the
decision paths read no wall clock, so a simulated replay's scaling
behavior cannot depend on host speed.
"""
import re
import time as wall

import numpy as np

import pytest

from repro.planner import (
    A100,
    EngineSpec,
    LabelDemand,
    WorkloadPlanner,
    estimate,
)
from repro.serving import (
    SYSTEM_CLOCK,
    Autoscaler,
    ElasticPolicy,
    FakeClock,
    LoadTracker,
    ServingCluster,
    SystemClock,
    install_clock,
    installed_clock,
    simulated_time,
)
from repro.sharding.plan import default_plan
from conftest import make_engine, make_request


# ---------------------------------------------------------------------------
# clock semantics
# ---------------------------------------------------------------------------


def test_fakeclock_reads_advance_deterministically():
    clock = FakeClock(start=10.0, tick=0.5)
    assert clock.now == 10.0                 # `now` peeks without a read
    assert clock.time() == 10.5              # every read advances by tick
    assert clock.perf_counter() == 11.0      # perf_counter aliases time
    assert clock.monotonic() == 11.5         # so does monotonic
    clock.advance(100.0)
    assert clock.now == pytest.approx(111.5)
    assert clock.is_simulated


def test_fakeclock_sleep_jumps_without_blocking():
    clock = FakeClock()
    t0 = wall.monotonic()
    clock.sleep(3600.0)                      # an hour passes instantly
    assert wall.monotonic() - t0 < 1.0
    assert clock.now == pytest.approx(1_000.0 + 3600.0)


def test_system_clock_surface():
    assert not SYSTEM_CLOCK.is_simulated
    assert isinstance(SYSTEM_CLOCK, SystemClock)
    assert abs(SYSTEM_CLOCK.time() - wall.time()) < 5.0
    assert SYSTEM_CLOCK.monotonic() <= SYSTEM_CLOCK.monotonic()
    assert abs(SYSTEM_CLOCK.now - wall.time()) < 5.0


def test_install_clock_swaps_and_restores_all_serving_modules():
    from repro.serving import cluster, engine, migration, prepare

    before = installed_clock()
    clock = FakeClock()
    restore = install_clock(clock)
    try:
        for mod in (engine, cluster, migration, prepare):
            assert mod.time is clock
        assert installed_clock() is clock
    finally:
        restore()
    assert installed_clock() is before
    for mod in (engine, cluster, migration, prepare):
        assert mod.time is before


def test_simulated_time_context_manager_stamps_requests(fp32_model):
    """Request TTFT/TPOT stamps land in the simulated domain (the
    FakeClock epoch, not wall time) while the context is active."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(0)
    with simulated_time() as clock:
        eng = make_engine(model, params)
        req = make_request(rng, cfg, 0, "phi")
        eng.submit(req)
        eng.run()
        assert 1_000.0 < req.t_submit < req.t_first <= req.t_done
        assert req.t_done <= clock.now
    assert not getattr(installed_clock(), "is_simulated", False)


# ---------------------------------------------------------------------------
# decision paths are wall-clock-free
# ---------------------------------------------------------------------------


def test_no_wall_clock_reads_on_decision_paths():
    """Source-level pin: `autoscaler.py` and `planner/planner.py` never
    call the time module directly — all timing flows through the
    injected ``clock`` attribute (``self.clock.time()``)."""
    import inspect

    import repro.planner.planner as planner_mod
    import repro.serving.autoscaler as autoscaler_mod

    for mod in (autoscaler_mod, planner_mod):
        src = inspect.getsource(mod)
        assert not re.search(r"\btime\.(time|monotonic|perf_counter|sleep)"
                             r"\s*\(", src), mod.__name__
        assert "import time" not in src, mod.__name__


def test_autoscaler_hysteresis_counts_ticks_not_seconds(fp32_model):
    """Threshold-mode sustain hysteresis fires after N TICKS on the
    injected clock — jumping the clock hours between ticks changes the
    recorded tick_times but not the decisions."""
    cfg, model, params = fp32_model
    rng = np.random.default_rng(0)

    def run(gap_s):
        clock = FakeClock()
        cluster = ServingCluster()
        cluster.register("e0", make_engine(model, params),
                         labels={"data-type": "phi"})
        scaler = Autoscaler(
            cluster, lambda label: make_engine(model, params),
            policy=ElasticPolicy(spawn_depth=0.5, sustain=3, cooldown=2),
            tracker=LoadTracker(alpha=1.0), bounds={"phi": (1, 3)},
            clock=clock)
        kinds = []
        for rid in range(12):
            cluster.submit(make_request(rng, cfg, rid, "phi"))
        for _ in range(4):
            kinds.append([d.kind for d in scaler.tick()])
            clock.advance(gap_s)
        return kinds, list(scaler.tick_times)

    fast_kinds, fast_times = run(gap_s=0.0)
    slow_kinds, slow_times = run(gap_s=7200.0)
    assert fast_kinds == slow_kinds            # decisions: ticks only
    assert any(k == ["spawn"] for k in fast_kinds)
    # tick_times come from the injected clock, hours apart in the slow run
    assert slow_times[1] - slow_times[0] > 7000.0
    assert fast_times[1] - fast_times[0] < 1.0


def test_planner_dwell_s_honors_injected_clock(fp32_model):
    """`WorkloadPlanner(dwell_s=...)`: after an action, a non-mandatory
    move is suppressed until the INJECTED clock has advanced past the
    dwell — wall time never enters the decision."""
    _, model, params = fp32_model
    clock = FakeClock()
    cluster = ServingCluster()

    def factory(spec, label):
        return make_engine(model, params, n_slots=spec.n_slots,
                           s_max=spec.s_max)

    spec = EngineSpec(plan=default_plan(), n_slots=2, s_max=32)
    planner = WorkloadPlanner(cluster, factory, specs=[spec],
                              profiles=[A100], dwell=0, dwell_s=30.0,
                              horizon_s=1e9, clock=clock)
    cap = estimate(planner.features_for(spec), A100).throughput_tok_s
    demand = {"phi": LabelDemand(rate=0.7 * cap / 16.0)}
    actions = planner.plan(demand)             # mandatory: no capacity
    assert [a.kind for a in actions] == ["spawn"]
    planner.execute(actions, async_spawn=False)
    # demand stops -> retiring is a PURE cost saving: dwell_s gates it
    assert planner.plan({"phi": LabelDemand(rate=0.0)}) == []
    clock.advance(29.0)
    assert planner.plan({"phi": LabelDemand(rate=0.0)}) == []
    clock.advance(2.0)                         # now past the 30 s dwell
    actions = planner.plan({"phi": LabelDemand(rate=0.0)})
    assert [a.kind for a in actions] == ["retire"]
