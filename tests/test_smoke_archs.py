"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus exact decode-vs-prefill
consistency (fp32) for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced_config
from repro.models import build_model
from repro.models.common import padded_vocab


def _batch(cfg, key, B=2, S=17):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, 16, cfg.d_model))
    if cfg.pos_type == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None], (3, B, S))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch, rng):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init_params(rng, max_seq=64)
    loss, metrics = jax.jit(model.train_loss)(params, _batch(cfg, rng))
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch} loss not finite"
    assert jnp.isfinite(metrics["ce"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_gradients_finite(arch, rng):
    cfg = get_reduced_config(arch)
    model = build_model(cfg)
    params = model.init_params(rng, max_seq=64)
    grads = jax.jit(jax.grad(
        lambda p: model.train_loss(p, _batch(cfg, rng))[0]))(params)
    bad = [p for p, g in
           jax.tree_util.tree_flatten_with_path(grads)[0]
           if not bool(jnp.all(jnp.isfinite(g)))]
    assert not bad, f"{arch}: non-finite grads at {bad[:3]}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill_exactly(arch, rng):
    cfg = dataclasses.replace(get_reduced_config(arch),
                              param_dtype="float32", activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(rng, max_seq=64)
    B, S = 2, 17
    batch_full = _batch(cfg, rng, B, S)
    batch_pre = {k: (v[:, :, :-1] if k == "positions" else
                     v[:, :-1] if k == "tokens" else v)
                 for k, v in batch_full.items()}
    logits_full, _ = jax.jit(model.prefill)(params, batch_full)
    _, cache = jax.jit(model.prefill)(params, batch_pre)

    enc_len = 16 if cfg.family == "encdec" else None
    pool = model.init_cache(B, 32, dtype=jnp.float32, enc_len=enc_len)

    def merge(z, c):
        if c.shape == z.shape:
            return c.astype(z.dtype)
        ax = [i for i, (a, b) in enumerate(zip(z.shape, c.shape)) if a != b][0]
        sl = [slice(None)] * z.ndim
        sl[ax] = slice(0, c.shape[ax])
        return z.at[tuple(sl)].set(c.astype(z.dtype))

    cache_full = jax.tree.map(merge, pool, cache)
    logits_dec, _ = jax.jit(model.decode_step)(
        params, batch_full["tokens"][:, -1:], cache_full, jnp.int32(S - 1))
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    assert err < 2e-3, f"{arch}: decode/prefill mismatch {err}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full (dry-run) configs carry the exact published shapes."""
    cfg = get_config(arch)
    expected = {
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51_866),
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73_448),
        "nemotron_4_340b": (96, 18_432, 96, 8, 73_728, 256_000),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256_000),
        "deepseek_coder_33b": (62, 7168, 56, 8, 19_200, 32_256),
        "qwen2_vl_2b": (28, 1536, 12, 2, 8960, 151_936),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151_936),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163_840),
        "jamba_v0_1_52b": (32, 4096, 32, 8, 14_336, 65_536),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50_280),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


def test_moe_configs():
    q = get_config("qwen2_moe_a2_7b").moe
    assert (q.num_experts, q.top_k, q.num_shared_experts) == (60, 4, 4)
    m = get_config("moonshot_v1_16b_a3b").moe
    assert (m.num_experts, m.top_k) == (64, 6)
    j = get_config("jamba_v0_1_52b")
    assert (j.moe.num_experts, j.moe.top_k) == (16, 2)
    assert j.hybrid_period == 8 and j.hybrid_attn_offsets == (4,)
    s = get_config("mamba2_370m").ssm
    assert s.d_state == 128


def test_vocab_padding_excluded_from_loss(rng):
    """Padded vocab rows must not leak probability mass into the CE."""
    cfg = get_reduced_config("minitron_4b")
    assert padded_vocab(cfg.vocab_size) == 256  # reduced vocab already padded
    cfg249 = dataclasses.replace(cfg, vocab_size=249)  # force padding
    model = build_model(cfg249)
    params = model.init_params(rng)
    batch = {"tokens": jax.random.randint(rng, (2, 17), 0, 249)}
    loss, _ = jax.jit(model.train_loss)(params, batch)
    # uniform-ish CE must be close to log(249), not log(256-padded)
    assert abs(float(loss) - jnp.log(249)) < 0.5
