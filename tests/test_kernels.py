"""Pallas kernel validation: hypothesis sweeps over shapes/dtypes with
assert_allclose against the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref, moe_topk_ref
from repro.models.attention import sdpa
from repro.models.ssm import ssd_scan_ref, ssd_step_ref

settings.register_profile("kernels", max_examples=12, deadline=None)
settings.load_profile("kernels")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@given(
    B=st.sampled_from([1, 2]),
    S=st.sampled_from([64, 128, 200, 384]),
    heads=st.sampled_from([(2, 1), (4, 2), (4, 4), (6, 2)]),
    D=st.sampled_from([32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_kernel_matches_sdpa(B, S, heads, D, dtype):
    Hq, Hkv = heads
    key = jax.random.PRNGKey(B * S + Hq + D)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    gold = sdpa(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), scale=D ** -0.5, causal=True)
    out = ops.flash_attention(q, k, v, causal=True, q_block=64, k_block=64)
    tol = 5e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gold, np.float32), atol=tol, rtol=tol)


@given(
    S=st.sampled_from([96, 160, 320]),
    q_chunk=st.sampled_from([32, 64, 128]),
    k_chunk=st.sampled_from([32, 64]),
)
def test_flash_ref_matches_sdpa(S, q_chunk, k_chunk):
    key = jax.random.PRNGKey(S + q_chunk)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, S, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (2, S, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (2, S, 2, 32), jnp.float32)
    gold = sdpa(q, k, v, scale=32 ** -0.5, causal=True)
    out = flash_attention_ref(q, k, v, causal=True,
                              q_chunk=q_chunk, k_chunk=k_chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold),
                               atol=5e-6, rtol=5e-6)


def test_flash_kernel_noncausal():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.float32)
    gold = sdpa(q, k, v, scale=32 ** -0.5, causal=False)
    out = ops.flash_attention(q, k, v, causal=False, q_block=64, k_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(gold), atol=5e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

def _ssd_inputs(key, B, S, H, P, G, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    return x, dt, A, Bm, Cm


@given(
    S=st.sampled_from([32, 96, 128]),
    chunk=st.sampled_from([16, 32]),
    HG=st.sampled_from([(2, 1), (4, 2), (4, 4)]),
    PN=st.sampled_from([(8, 16), (16, 32)]),
)
def test_ssd_kernel_matches_ref(S, chunk, HG, PN):
    H, G = HG
    P, N = PN
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(S + H + P), 2, S, H, P, G, N)
    y_ref, h_ref = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
    y_ker, h_ker = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_ker), np.asarray(h_ref),
                               atol=1e-4, rtol=1e-4)


def test_ssd_ref_matches_stepwise_recurrence():
    """The chunked algorithm must equal the naive per-token recurrence."""
    B, S, H, P, G, N = 1, 24, 2, 8, 1, 16
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(7), B, S, H, P, G, N)
    y_chunk, h_chunk = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=8)
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y_t, h = ssd_step_ref(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               atol=1e-4, rtol=1e-4)


def test_ssd_padding_exactness():
    """Non-multiple S: padding must not change y[:S] or the final state."""
    B, S, H, P, G, N = 2, 37, 2, 8, 1, 16
    x, dt, A, Bm, Cm = _ssd_inputs(jax.random.PRNGKey(3), B, S, H, P, G, N)
    y, h = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=16)
    h_ref = jnp.zeros((B, H, P, N))
    for t in range(S):
        _, h_ref = ssd_step_ref(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h_ref)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# MoE top-k gating
# ---------------------------------------------------------------------------

@given(
    T=st.sampled_from([8, 100, 256, 300]),
    E=st.sampled_from([8, 16, 60]),
    k=st.sampled_from([1, 2, 4]),
    norm=st.booleans(),
)
def test_moe_topk_kernel_matches_ref(T, E, k, norm):
    logits = jax.random.normal(jax.random.PRNGKey(T + E + k), (T, E))
    wr, ir = moe_topk_ref(logits, k, norm_topk=norm)
    wk, ik = ops.moe_topk(logits, k, norm_topk=norm, block=128)
    np.testing.assert_allclose(np.asarray(wk), np.asarray(wr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ik), np.asarray(ir))
