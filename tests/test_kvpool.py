"""Paged KV-cache pool + continuous batching: allocator invariants
(fail-closed OOM, watermark headroom, double-free detection), the
bitwise-identity contract against the slot-granular engine, token-
granular admission beyond the slot-equivalent budget, migration across
pool layouts, and the `kv_utilization` metrics view.

Uses the shared serving harness from conftest (``fp32_model`` session
fixture, `make_engine`/`baseline_streams`)."""
import numpy as np
import pytest
from conftest import baseline_streams as _baseline_streams
from conftest import make_engine as _mk

from repro.serving import MigrationError, Request, ServingCluster
from repro.serving.kvpool import (
    SCRATCH_PAGE,
    PagedKVPool,
    PoolOOM,
    page_axes,
    supports_paging,
)


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


# ---------------------------------------------------------------------------
# allocator unit tests (no model, no device work)
# ---------------------------------------------------------------------------


def test_pool_alloc_free_roundtrip():
    pool = PagedKVPool(page_size=16, n_pages=8)
    assert pool.free_pages == 8
    assert pool.store_batch == 9          # data pages + the scratch page
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert len(a) == 3 and len(b) == 2
    assert not set(a) & set(b)
    assert SCRATCH_PAGE not in a + b      # page 0 is never handed out
    assert all(1 <= p <= 8 for p in a + b)
    assert pool.free_pages == 3
    assert pool.allocated_tokens == 5 * 16
    pool.free(a)
    pool.free(b)
    assert pool.free_pages == 8
    assert pool.allocated_tokens == 0


def test_pool_pages_for_rounds_up():
    pool = PagedKVPool(page_size=16, n_pages=4)
    assert pool.pages_for(1) == 1
    assert pool.pages_for(16) == 1
    assert pool.pages_for(17) == 2
    assert pool.pages_for(0) == 1         # every request owns >= 1 page


def test_pool_oom_fails_closed():
    """An allocation that does not fit raises and allocates NOTHING —
    the caller leaves the request queued, state unchanged."""
    pool = PagedKVPool(page_size=16, n_pages=4)
    pool.alloc(3)
    with pytest.raises(PoolOOM):
        pool.alloc(2)
    assert pool.free_pages == 1           # the failed alloc took nothing
    pool.alloc(1)                         # what remains is still usable
    assert pool.free_pages == 0


def test_pool_watermark_reserved_for_imports():
    """Plain admission must leave the watermark behind; migration
    imports (``reserve=True``) may spend it — that headroom exists
    exactly so an import burst cannot be starved by admissions."""
    pool = PagedKVPool(page_size=16, n_pages=6, watermark=2)
    assert pool.admittable_pages == 4
    pool.alloc(4)
    with pytest.raises(PoolOOM):
        pool.alloc(1)                     # would dip below the watermark
    assert pool.free_pages == 2
    got = pool.alloc(2, reserve=True)     # import spends the headroom
    assert len(got) == 2 and pool.free_pages == 0
    with pytest.raises(PoolOOM):
        pool.alloc(1, reserve=True)       # truly empty still fails closed


def test_pool_free_rejects_bookkeeping_bugs():
    pool = PagedKVPool(page_size=8, n_pages=4)
    pages = pool.alloc(2)
    pool.free(pages)
    with pytest.raises(ValueError):
        pool.free(pages)                  # double free
    p = pool.alloc(1)
    with pytest.raises(ValueError):
        pool.free(p + p)                  # duplicates within one call
    with pytest.raises(ValueError):
        pool.free([SCRATCH_PAGE])         # the scratch page is not freeable
    with pytest.raises(ValueError):
        pool.free([99])                   # out of range


def test_pool_ctor_validation():
    with pytest.raises(ValueError):
        PagedKVPool(page_size=0, n_pages=4)
    with pytest.raises(ValueError):
        PagedKVPool(page_size=8, n_pages=0)
    with pytest.raises(ValueError):
        PagedKVPool(page_size=8, n_pages=4, watermark=4)


# ---------------------------------------------------------------------------
# paging soundness predicate + axis probe
# ---------------------------------------------------------------------------


def test_supports_paging_and_axes(fp32_model):
    cfg, model, params = fp32_model
    assert supports_paging(model)         # attn mixers -> pageable
    import jax
    pax, sax = page_axes(model)
    for p, s in zip(jax.tree.leaves(pax), jax.tree.leaves(sax)):
        assert s == p + 1                 # seq right after the page axis


def test_ssm_models_fall_back_to_slot_pool():
    """SSM recurrent state has no sequence dim — `supports_paging` must
    exclude it, the engine must auto-select the slot pool, and forcing
    ``paged=True`` must fail loudly."""
    from conftest import build_tiny_model

    from repro.serving import ServingEngine

    cfg, model, params = build_tiny_model("mamba2_370m")
    assert not supports_paging(model)
    eng = ServingEngine(model, params, n_slots=2, s_max=32)
    assert not eng.paged and eng.pool is None
    with pytest.raises(ValueError):
        ServingEngine(model, params, n_slots=2, s_max=32, paged=True)


# ---------------------------------------------------------------------------
# engine integration: bitwise identity + token-granular admission
# ---------------------------------------------------------------------------


def test_paged_is_default_and_streams_bitwise_identical(fp32_model):
    """The headline contract: the paged engine (the default for attn
    models) produces bitwise-identical token streams to the slot-
    granular engine on a mixed-length trace — garbage in scratch-padded
    page extents is masked before the fp32 softmax."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg, (5, 9, 6, 13, 3, 8), seed=7)
    expect = _baseline_streams(model, params, prompts, new=8)

    eng = _mk(model, params, n_slots=4, s_max=32, page_size=8)
    assert eng.paged                      # default ON for attn models
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert {r.rid: list(r.tokens_out) for r in reqs} == expect
    # continuous batching drained everything and reclaimed every page
    assert eng.pool.free_pages == eng.pool.n_pages
    assert eng.kv_allocated_tokens == 0


def test_paged_slot_parity_when_forced_off(fp32_model):
    """``paged=False`` still serves the exact same streams (the fallback
    path the SSM/enc-dec models rely on is never behind)."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg, (4, 11, 7), seed=11)
    expect = _baseline_streams(model, params, prompts, new=6)
    eng = _mk(model, params, n_slots=4, s_max=32, paged=False)
    assert not eng.paged
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert {r.rid: list(r.tokens_out) for r in reqs} == expect


def test_token_budget_gates_admission_not_lanes(fp32_model):
    """A paged engine with a reduced ``kv_tokens`` budget throttles on
    memory, not lanes: requests wait in queue while pages are scarce,
    then complete with unchanged streams once pages free up."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg, (6, 6, 6, 6), seed=3)
    expect = _baseline_streams(model, params, prompts, new=8)
    # each request needs 6 + 8 = 14 tokens -> 2 pages of 8; budget of 4
    # pages admits exactly two at a time despite 4 free lanes
    eng = _mk(model, params, n_slots=4, s_max=32, page_size=8, kv_tokens=32)
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    resident = sum(r is not None for r in eng.slot_req)
    assert resident == 2                  # lanes free, pages exhausted
    assert eng.free_tokens == 0
    assert len(eng.queue) == 2            # fail-closed: still queued
    eng.run()
    assert {r.rid: list(r.tokens_out) for r in reqs} == expect
    assert eng.pool.free_pages == eng.pool.n_pages


def test_kv_utilization_reflects_used_over_allocated(fp32_model):
    cfg, model, params = fp32_model
    eng = _mk(model, params, n_slots=2, s_max=32, page_size=8)
    assert eng.kv_utilization == 0.0      # idle engine: no allocation
    req = Request(0, _prompts(cfg, (6,))[0], max_new_tokens=8)
    eng.submit(req)
    eng.step()
    # 6 prompt + 1 generated = slot_pos 7 used; 14-token worst case -> 2
    # pages = 16 allocated
    assert eng.kv_used_tokens == 7
    assert eng.kv_allocated_tokens == 16
    assert eng.kv_utilization == pytest.approx(7 / 16)
    before = eng.kv_utilization
    eng.step()
    assert eng.kv_utilization > before    # fills as decode proceeds
    eng.run()
    assert eng.kv_utilization == 0.0


# ---------------------------------------------------------------------------
# migration across pool layouts
# ---------------------------------------------------------------------------


def test_migrate_paged_to_paged_bitwise_identical(fp32_model):
    cfg, model, params = fp32_model
    prompts = _prompts(cfg, (5, 7, 6, 8), seed=5)
    expect = _baseline_streams(model, params, prompts, new=8)
    cluster = ServingCluster()
    cluster.register("src", _mk(model, params, n_slots=4, page_size=8))
    reqs = [Request(i, p, max_new_tokens=8) for i, p in enumerate(prompts)]
    for r in reqs:
        cluster.submit(r)
    for _ in range(3):
        cluster.step()
    cluster.register("dst", _mk(model, params, n_slots=4, page_size=8))
    records = cluster.migrate_requests("src", "dst")
    assert len(records) == 4
    # the whole decoding cohort moved in ONE batched transfer
    assert all(m.batch == 4 for m in records if m.phase == "decoding")
    assert cluster.engine("src").load == 0
    cluster.run()
    assert {r.rid: list(r.tokens_out) for r in reqs} == expect


def test_migrate_across_pool_layouts_bitwise_identical(fp32_model):
    """Slot -> paged and paged -> slot both preserve streams: the
    migration snapshot is layout-neutral (a dense single-sequence KV)."""
    cfg, model, params = fp32_model
    prompts = _prompts(cfg, (5, 9), seed=9)
    expect = _baseline_streams(model, params, prompts, new=8)
    for src_kw, dst_kw in (
            (dict(paged=False), dict(page_size=8)),
            (dict(page_size=8), dict(paged=False))):
        cluster = ServingCluster()
        cluster.register("src", _mk(model, params, n_slots=4, **src_kw))
        reqs = [Request(i, p, max_new_tokens=8)
                for i, p in enumerate(prompts)]
        for r in reqs:
            cluster.submit(r)
        for _ in range(2):
            cluster.step()
        cluster.register("dst", _mk(model, params, n_slots=4, **dst_kw))
        cluster.migrate_requests("src", "dst")
        cluster.run()
        assert {r.rid: list(r.tokens_out) for r in reqs} == expect


def test_migrate_into_exhausted_pool_fails_closed(fp32_model):
    """A destination whose pool cannot hold the incoming pages refuses
    the migration; the request is restored and finishes at the source."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("src", _mk(model, params, n_slots=2, s_max=48))
    # 2 lanes but only 2 pages of 8 = 16 tokens of budget; the resident
    # request below needs 8 + 20 = 28 tokens -> 4 pages
    cluster.register("tiny", _mk(model, params, n_slots=2, s_max=48,
                                 page_size=8, kv_tokens=16))
    rng = np.random.default_rng(2)
    req = Request(0, rng.integers(2, cfg.vocab_size, size=8)
                  .astype(np.int32), max_new_tokens=20)
    cluster.engine("src").submit(req)
    cluster.step()
    with pytest.raises(MigrationError):
        cluster.migrate_requests("src", "tiny", rids=[0])
    assert cluster.engine("src").load == 1   # restored, not dropped
    assert cluster.engine("tiny").pool.free_pages == 2  # nothing leaked
    cluster.run()
    assert len(req.tokens_out) == 20


def test_cluster_kv_utilization_view(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("a", _mk(model, params, n_slots=2, page_size=8))
    cluster.register("b", _mk(model, params, n_slots=2, page_size=8))
    util = cluster.kv_utilization()
    assert util == {"a": 0.0, "b": 0.0, "*": 0.0}
    req = Request(0, _prompts(cfg, (6,))[0], max_new_tokens=8)
    cluster.engine("a").submit(req)
    cluster.step()
    util = cluster.kv_utilization()
    assert util["a"] > 0.0 and util["b"] == 0.0
    assert util["*"] == pytest.approx(util["a"])  # b holds no allocation
    cluster.run()
