"""Flight-recorder tests: ring retention, sketch accuracy, simulated-
clock determinism, and the recorded-replay acceptance cross-checks —
trace spans vs `MigrationRecord` pause totals (ms-exact), `SLOLedger`
attainment vs `ReplayStats.attainment` from the very same run, and the
incremental `metrics_by_label` vs a from-scratch full scan.

The recorded-replay fixtures are module-scoped: one full-stack replay
(`repro.traffic.replay.recorded_replay`) feeds every cross-check; the
determinism test pays for the second run itself.
"""
import json
import math
import os

import numpy as np
import pytest
from conftest import make_engine, make_request

from repro.obs import (
    EventBus,
    Histogram,
    Recorder,
    SLOLedger,
    Span,
    TraceBuffer,
    meets_slo,
    overlaps,
    recording,
    validate_chrome,
)
from repro.obs import events as obs_events
from repro.serving import ServingCluster
from repro.serving.engine import METRIC_KEYS, compute_metrics

#: fixture replay size — big enough to trigger autoscaler migrations,
#: small enough that the module stays a minor slice of the suite
N_REQ = int(os.environ.get("OBS_TEST_REQUESTS", "400"))


# ---------------------------------------------------------------------------
# rings: bounded, counted, oldest-out
# ---------------------------------------------------------------------------


def test_event_bus_overflow_drops_oldest_and_counts():
    bus = EventBus(capacity=8)
    for i in range(20):
        bus.emit("unit.tick", rid=i, ts=float(i))
    assert len(bus) == 8
    assert bus.emitted == 20
    assert bus.dropped == 12                      # observable, not silent
    kept = bus.events()
    assert [e.rid for e in kept] == list(range(12, 20))   # oldest gone
    assert [e.seq for e in kept] == list(range(12, 20))   # seq == emit order
    assert all(a.ts <= b.ts for a, b in zip(kept, kept[1:]))


def test_event_bus_kind_prefix_filter():
    bus = EventBus()
    bus.emit("request.submit")
    bus.emit("request.complete")
    bus.emit("requestor")                         # prefix, not substring
    bus.emit("cluster.swap")
    assert len(bus.events("request")) == 2
    assert len(bus.events("request.submit")) == 1
    assert len(bus.events("cluster")) == 1


def test_trace_buffer_overflow_drops_oldest_and_counts():
    buf = TraceBuffer(capacity=4)
    for i in range(10):
        buf.add(Span("s", float(i), 0.5))
    spans = buf.spans()
    assert len(spans) == 4
    assert buf.added == 10 and buf.dropped == 6
    assert [s.ts for s in spans] == [6.0, 7.0, 8.0, 9.0]


def test_rings_reject_nonpositive_capacity():
    with pytest.raises(ValueError):
        EventBus(capacity=0)
    with pytest.raises(ValueError):
        TraceBuffer(capacity=-1)


# ---------------------------------------------------------------------------
# histogram sketch: bounded error vs exact percentiles
# ---------------------------------------------------------------------------


def test_histogram_quantiles_within_sketch_error():
    rng = np.random.default_rng(3)
    xs = rng.lognormal(mean=-4.0, sigma=1.0, size=20_000)   # latency-shaped
    h = Histogram(growth=1.1)
    for x in xs:
        h.observe(float(x))
    for q in (0.5, 0.9, 0.99):
        exact = float(np.percentile(xs, 100 * q))
        # log-bucketed, growth 1.1: any in-bucket point is within half a
        # bucket of the geometric midpoint -> ~5% relative error
        assert abs(h.quantile(q) - exact) / exact < 0.06, q
    # the mean is an exact running sum, not sketched
    assert h.mean == pytest.approx(float(np.mean(xs)), rel=1e-9)


def test_histogram_edge_values():
    h = Histogram()
    h.observe(0.0)                 # underflow bucket
    h.observe(5.0)
    assert h.quantile(0.0) == 0.0
    assert h.quantile(1.0) == pytest.approx(5.0, rel=0.06)
    h.observe(float("nan"))        # counted; quantiles propagate NaN
    snap = h.snapshot()
    assert snap["count"] == 3
    assert math.isnan(h.quantile(0.5))   # np.percentile semantics


# ---------------------------------------------------------------------------
# recorder plumbing
# ---------------------------------------------------------------------------


def test_recording_disabled_by_default_and_restores():
    assert obs_events.RECORDER is None
    with recording(Recorder()) as rec:
        assert obs_events.RECORDER is rec
        with recording() as inner:               # nests + auto-creates
            assert obs_events.RECORDER is inner
        assert obs_events.RECORDER is rec
    assert obs_events.RECORDER is None


def test_request_complete_events_fold_into_metrics():
    rec = Recorder()
    rec.emit("request.complete", rid=1, label="phi", ttft_s=0.1, tpot_s=0.01)
    rec.emit("request.complete", rid=2, label="phi", ttft_s=0.3, tpot_s=0.02)
    rec.emit("request.reject", rid=3, label="gen")
    snap = rec.snapshot()["metrics"]
    assert snap["counters"]["requests_completed{label=phi}"] == 2
    assert snap["counters"]["requests_rejected{label=gen}"] == 1
    assert snap["histograms"]["ttft_s{label=phi}"]["count"] == 2


# ---------------------------------------------------------------------------
# the recorded full-stack replay: one run, many cross-checks
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def recorded_run():
    from repro.traffic.replay import recorded_replay
    return recorded_replay(N_REQ, seed=7)


def test_replay_records_the_request_lifecycle(recorded_run):
    stats, rec, _ = recorded_run
    assert rec.bus.dropped == 0 and rec.trace.dropped == 0
    assert len(rec.events("request.submit")) == stats.submitted
    assert len(rec.events("request.complete")) == stats.completed
    assert stats.completed > 0
    assert len(rec.events("planner.decision")) > 0
    assert len(rec.trace.spans("route")) == stats.submitted


def test_no_wall_clock_on_the_event_stream(recorded_run):
    """Every timestamp sits in the FakeClock's epoch (starts at 1000 s),
    nowhere near the wall clock's ~1.7e9 — recording reads the installed
    clock, never `time.time` off the real module."""
    stats, rec, _ = recorded_run
    ts = [e.ts for e in rec.events()] + [s.ts for s in rec.trace.spans()]
    assert ts
    assert all(1_000.0 <= t < 1e6 for t in ts)


def test_fake_clock_replays_are_bit_identical(recorded_run):
    """Same trace seed + fresh recorder -> the identical event stream
    and span list: the flight recorder is deterministic evidence, not a
    heisen-log."""
    from repro.traffic.replay import recorded_replay

    stats_a, rec_a, _ = recorded_run
    stats_b, rec_b, _ = recorded_replay(N_REQ, seed=7)

    assert stats_b.completed == stats_a.completed
    assert stats_b.duration_s == stats_a.duration_s

    def freeze_events(rec):
        return [(e.seq, e.ts, e.kind, e.engine, e.rid, e.label,
                 json.dumps(e.data, sort_keys=True, default=repr))
                for e in rec.events()]

    def freeze_spans(rec):
        return [(s.name, s.ts, s.dur, s.track, s.cat,
                 json.dumps(s.args, sort_keys=True, default=repr))
                for s in rec.trace.spans()]

    assert freeze_events(rec_b) == freeze_events(rec_a)
    assert freeze_spans(rec_b) == freeze_spans(rec_a)
    # identical spans -> byte-identical Perfetto export
    assert json.dumps(rec_b.export_chrome(), sort_keys=True) \
        == json.dumps(rec_a.export_chrome(), sort_keys=True)


def test_replay_migration_pauses_consistent(recorded_run):
    """Whatever migrations the replay's planner chose to run, the trace
    and the event stream must agree with the `MigrationRecord`s retained
    on the cluster's DowntimeReports — ms-exact."""
    _, rec, planner = recorded_run
    records = [m for rep in planner.cluster.history for m in rep.migrations]
    spans = rec.trace.spans("migration.pause")
    span_pauses = sorted((s.args.get("rid", -1), s.dur) for s in spans)
    rec_pauses = sorted((m.rid, m.pause_s) for m in records)
    assert span_pauses == rec_pauses            # per-request, bit-exact
    ev_total = sum(e.data["pause_s"] for e in rec.events("migration.pause"))
    assert abs(ev_total - sum(m.pause_s for m in records)) * 1e3 < 1e-6


def test_migration_pause_spans_match_records_ms_exact(fp32_model):
    """Acceptance check: migration-pause spans reproduce the per-request
    `MigrationRecord` pause totals exactly — the exported trace is the
    downtime ledger, not an approximation of it. Driven directly so the
    migrations are guaranteed, on both the `migrate_requests` and the
    `retire_engine(mode="migrate")` paths."""
    cfg, model, params = fp32_model
    with recording(Recorder()) as rec:
        cluster = ServingCluster()
        cluster.register("e0", make_engine(model, params, n_slots=4))
        rng = np.random.default_rng(5)
        for rid in range(4):
            cluster.submit(make_request(rng, cfg, rid, new=8))
        for _ in range(3):              # decode a little: KV state exists
            cluster.step()
        cluster.register("e1", make_engine(model, params, n_slots=4))
        moved = cluster.migrate_requests("e0", "e1", rids=[0, 1])
        report = cluster.retire_engine("e1", mode="migrate")   # back to e0
        records = list(moved) + list(report.migrations)
        cluster.run()
    assert len(records) >= 4, records

    spans = rec.trace.spans("migration.pause")
    span_pauses = sorted((s.args.get("rid", -1), s.dur) for s in spans)
    rec_pauses = sorted((m.rid, m.pause_s) for m in records)
    assert span_pauses == rec_pauses            # per-request, bit-exact
    assert abs(sum(s.dur for s in spans)
               - sum(m.pause_s for m in records)) * 1e3 < 1e-6   # ms-exact
    # spans carry the destination so the trace answers "what happened
    # to request R" without joining against the bus
    assert all(s.args.get("dst") for s in spans)


def test_slo_ledger_matches_replay_attainment(recorded_run):
    """The ledger scores `request.complete` events with the replay
    harness's own predicate, so per-label attainment from the event
    stream must match `ReplayStats.attainment` from the same run."""
    stats, rec, planner = recorded_run
    ledger = SLOLedger.from_policy(planner).consume(rec.events())

    assert set(ledger.attainment()) == set(stats.attainment)
    for label, expected in stats.attainment.items():
        assert ledger.attainment()[label] == pytest.approx(expected,
                                                           abs=1e-12)
    assert ledger.attainment_overall() == pytest.approx(
        stats.attainment_overall, abs=1e-12)
    assert sum(ledger.completed().values()) == stats.completed

    # windowed series folds back to the aggregate, per label
    for label in ledger.attainment():
        wins = ledger.windows(label)
        assert wins
        ok = sum(w.ok for w in wins)
        scored = sum(w.scored for w in wins)
        assert ok / scored == pytest.approx(ledger.attainment()[label],
                                            abs=1e-12)
    # every pause cause observed in the run is attributed
    pauses = ledger.pause_accounting()
    assert set(pauses) == set(SLOLedger.CAUSES)
    assert pauses["migration"]["count"] == len(
        rec.events("migration.pause"))


def test_chrome_export_is_perfetto_loadable(recorded_run):
    _, rec, _ = recorded_run
    doc = json.loads(json.dumps(rec.export_chrome()))    # JSON round-trip
    n = validate_chrome(doc)
    assert n == rec.trace.added - rec.trace.dropped
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert "route" in names
    assert names <= {"route", "swap.commit", "spawn.commit",
                     "migration.pause"}


def test_meets_slo_predicate_matches_harness_semantics():
    assert meets_slo(0.1, 0.01, (0.2, 0.02))
    assert not meets_slo(0.3, 0.01, (0.2, 0.02))         # ttft over
    assert not meets_slo(0.1, 0.03, (0.2, 0.02))         # tpot over
    assert not meets_slo(math.inf, 0.01, (0.2, None))    # ttft must finish
    assert meets_slo(0.1, math.nan, (0.2, 0.02))         # 1-token request
    assert meets_slo(math.inf, math.inf, (None, None))   # unscored


# ---------------------------------------------------------------------------
# incremental metrics_by_label vs the full scan it replaced
# ---------------------------------------------------------------------------


def test_metrics_by_label_matches_full_scan(fp32_model):
    """`ServingCluster.metrics_by_label` now folds completions into
    per-label `RequestAggregate`s incrementally; this cross-checks it
    against the original recompute-from-every-Request scan."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("e0", make_engine(model, params, n_slots=2),
                     labels={"data-type": "phi"})
    cluster.register("e1", make_engine(model, params, n_slots=2))

    rng = np.random.default_rng(17)
    for rid in range(12):
        label = "phi" if rid % 3 else "gen"
        cluster.submit(make_request(rng, cfg, rid, label, new=3))
    cluster.run()

    def full_scan():
        per_label = {}
        for name in cluster.engines():
            for r in cluster.engine(name).done:
                v = r.labels.get(ServingCluster.ROUTE_KEY, "*")
                per_label.setdefault(v, []).append(r)
        return {v: compute_metrics(rs) for v, rs in per_label.items()}

    got = cluster.metrics_by_label()
    expected = full_scan()
    assert set(expected) <= set(got)       # + known-but-idle labels
    for v, exp in expected.items():
        assert set(got[v]) == set(METRIC_KEYS)
        assert got[v]["completed"] == exp["completed"]
        for key in ("ttft_mean_s", "tpot_mean_s"):
            assert got[v][key] == pytest.approx(exp[key], rel=1e-9), (v, key)
        for key in ("ttft_p99_s", "tpot_p99_s"):       # sketched: ~5% error
            assert got[v][key] == pytest.approx(exp[key], rel=0.12), (v, key)

    # drain resets the folds: later views only see later completions
    drained = cluster.drain_completed()
    assert len(drained) == 12
    after = cluster.metrics_by_label()
    assert all(m["completed"] == 0 for m in after.values())
    cluster.submit(make_request(rng, cfg, 100, "gen", new=2))
    cluster.run()
    assert cluster.metrics_by_label()["gen"]["completed"] == 1
