"""Scale harness: seeded synthetic traffic replayed through the full
planner + autoscaler + migration + paged-KV stack on the SIMULATED
clock (`repro.serving.clock` + `repro.traffic`).

Tier-1 keeps a ~2k-request smoke (finishes, zero drops, every
`DowntimeReport` finalized, SLO attainment computed, deterministic);
the 10^5+-request stress replay rides behind ``make test-stress``
(RUN_SLOW=1, pytest marker ``slow``) so CI latency stays bounded.

No wall-clock sleeps anywhere: decode steps advance virtual time by the
modeled step duration and idle gaps are jumped, so simulated minutes
cost only the decode math.
"""
import dataclasses
import os

import pytest

from conftest import make_engine
from repro.planner import (
    EngineSpec,
    ResidualCalibration,
    WorkloadPlanner,
    calibrate_host_profile,
)
from repro.serving import (
    Autoscaler,
    FakeClock,
    LoadTracker,
    ServingCluster,
    install_clock,
)
from repro.serving.engine import METRIC_KEYS
from repro.sharding.plan import default_plan
from repro.traffic import (
    FlashCrowd,
    LabelProfile,
    LongPromptFlood,
    TrafficPattern,
    generate_trace,
    replay_trace,
)

STEP_TIME_S = 4e-3       # modeled decode-step duration (simulated)


def _pattern(duration_s, base_rate, *, seed=7, adversarial=True):
    """Two-tenant pattern: phi-heavy mix, a phi flash crowd mid-trace,
    and an adversarial long-prompt flood on gen."""
    crowds = (FlashCrowd(t_start=duration_s / 3, duration_s=duration_s / 6,
                         multiplier=3.0, label="phi"),) if adversarial \
        else ()
    floods = (LongPromptFlood(t_start=2 * duration_s / 3,
                              duration_s=duration_s / 12, rate=base_rate / 6,
                              label="gen", prompt_len=24, new_tokens=2),) \
        if adversarial else ()
    return TrafficPattern(
        duration_s=duration_s, base_rate=base_rate,
        labels={"phi": LabelProfile(weight=2.0),
                "gen": LabelProfile(weight=1.0)},
        diurnal_period_s=duration_s / 2,
        flash_crowds=crowds, floods=floods, seed=seed)


def _stack(model, params, clock, *, n_slots=4, max_engines=3):
    """A planner-mode serving stack on ``clock``: empty cluster, floor
    bounds per label (pre-seeded so t<first-tick arrivals never reject),
    residual calibration installed, sync spawns for determinism."""
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan(), n_slots=n_slots, s_max=32)

    def factory(spec, label):
        return make_engine(model, params, n_slots=spec.n_slots,
                           s_max=spec.s_max)

    cluster = ServingCluster()
    planner = WorkloadPlanner(cluster, factory, specs=[spec],
                              profiles=[host], dwell=0,
                              calibration=ResidualCalibration(alpha=0.3),
                              clock=clock)
    for label in ("phi", "gen"):
        planner.bounds[label] = (1, max_engines)
        planner.set_slo_target(label, 50 * STEP_TIME_S, 2 * STEP_TIME_S)
    scaler = Autoscaler(cluster, lambda label: factory(spec, label),
                        planner=planner, tracker=LoadTracker(alpha=0.5),
                        async_spawn=False, clock=clock)
    planner.execute(planner.plan({}), async_spawn=False)   # seed floors
    return cluster, planner, scaler


def _replay(model, params, cfg, pattern, **kw):
    clock = FakeClock(tick=1e-6)
    restore = install_clock(clock)
    try:
        cluster, planner, scaler = _stack(model, params, clock,
                                          **kw.pop("stack", {}))
        trace = generate_trace(pattern)
        stats = replay_trace(trace, cluster, scaler, clock,
                             vocab_size=cfg.vocab_size,
                             step_time_s=STEP_TIME_S, **kw)
        return trace, stats, cluster, planner
    finally:
        restore()


def test_scale_smoke_2k(fp32_model):
    """ACCEPTANCE (tier-1 tier): a ~2k-request replay with diurnal
    modulation, a flash crowd, and a long-prompt flood finishes on the
    simulated clock with zero drops, every DowntimeReport finalized,
    and SLO attainment computed per label."""
    cfg, model, params = fp32_model
    pattern = _pattern(12.0, 170.0)
    trace, stats, cluster, planner = _replay(
        model, params, cfg, pattern, tick_s=1.0, window_ticks=3)
    assert len(trace) >= 2000
    assert stats.n_requests == len(trace)
    assert stats.dropped == 0 and not cluster.rejected
    assert stats.completed == stats.submitted == len(trace)
    # every reconfiguration event produced a FINALIZED DowntimeReport
    assert stats.reports_finalized
    for r in cluster.history:
        assert set(METRIC_KEYS) <= set(r.metrics_after)
        assert r.downtime_s >= 0.0
    # SLO attainment is computed for both labels, in [0, 1]
    assert set(stats.attainment) == {"gen", "phi"}
    assert all(0.0 <= a <= 1.0 for a in stats.attainment.values())
    assert stats.attainment_overall is not None
    # the replay covered the whole trace in simulated time
    assert stats.duration_s >= trace[-1].t
    assert stats.engine_seconds >= stats.duration_s      # >= 1 engine live
    assert stats.peak_engines >= 2
    # the calibration loop closed: windows scored, factors learned
    err = stats.prediction_error()
    assert err["windows_scored"] > 0
    assert planner.calibration.n_observations("phi") > 0


def test_scale_replay_deterministic(fp32_model):
    """ACCEPTANCE: same seed -> identical replay, end to end — window
    records, per-label metrics, engine-seconds, and step count all match
    bitwise across two independent stacks."""
    cfg, model, params = fp32_model
    runs = []
    for _ in range(2):
        _, stats, _, _ = _replay(model, params, cfg,
                                 _pattern(5.0, 50.0, seed=3),
                                 tick_s=1.0, window_ticks=2)
        runs.append((stats.per_label, stats.attainment,
                     stats.engine_seconds, stats.steps,
                     [dataclasses.astuple(w) for w in stats.windows]))
    assert runs[0] == runs[1]


@pytest.mark.slow
@pytest.mark.skipif(not os.environ.get("RUN_SLOW"),
                    reason="10^5-request stress replay; set RUN_SLOW=1 "
                           "(make test-stress) to run")
def test_scale_stress_100k(fp32_model):
    """The 10^5+-request replay: sustained overload forces the planner
    to scale out, and the run still finishes with zero drops and
    calibrated predictions beating the analytical roofline."""
    cfg, model, params = fp32_model
    # sized against pooled capacity at full scale-out (4 engines x
    # 8 slots / 4 ms = 8000 slot-tokens/s): diurnal peaks run just
    # under it, the flash crowd pushes past it transiently
    pattern = _pattern(72.0, 1400.0, seed=11)
    trace, stats, cluster, planner = _replay(
        model, params, cfg, pattern, tick_s=1.0, window_ticks=4,
        stack={"n_slots": 8, "max_engines": 4})
    assert len(trace) >= 100_000
    assert stats.dropped == 0
    assert stats.completed == stats.submitted == len(trace)
    assert stats.reports_finalized
    assert stats.attainment_overall is not None
    err = stats.prediction_error()
    assert err["windows_scored"] > 0
    assert err["calibrated_mare"] < err["analytical_mare"]
