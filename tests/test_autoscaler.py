"""Elastic autoscaling tests: per-label load tracking, spawn on sustained
overload, retire strictly after drain, anti-flapping hysteresis, auto-
finalized DowntimeReports for every scale event, intent-pinned scaling
bounds (Orchestrator.submit(apply_to=autoscaler)), and the per-label
cluster-metrics aggregation the LoadTracker depends on.

Uses the shared serving harness from conftest (``fp32_model`` session
fixture, `make_request`/`make_engine`); this file's traces default to
``max_new_tokens=3``."""
import numpy as np
import pytest
from conftest import make_engine as _mk
from conftest import make_request

from repro.core import Orchestrator
from repro.serving import (
    METRIC_KEYS,
    Autoscaler,
    ElasticPolicy,
    LoadTracker,
    ServingCluster,
)
from repro.sharding import ShardingPlan, plan_satisfies


def _req(rng, cfg, rid, label=None, n=6, new=3):
    return make_request(rng, cfg, rid, label, n=n, new=new)


# ---------------------------------------------------------------------------
# load tracking + per-label metrics (the LoadTracker's substrate)
# ---------------------------------------------------------------------------


def test_load_tracker_ewma_rates_and_decay(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("e", _mk(model, params))
    rng = np.random.default_rng(0)
    tracker = LoadTracker(alpha=0.5)

    for rid in range(4):
        cluster.submit(_req(rng, cfg, rid, "phi"))
    tracker.observe(cluster)
    assert tracker.rate("phi") == pytest.approx(2.0)   # 0.5 * 4/1
    assert tracker.depth("phi") == pytest.approx(2.0)
    # no new arrivals: the rate EWMA decays, never goes negative
    cluster.run()
    tracker.observe(cluster)
    assert tracker.rate("phi") == pytest.approx(1.0)
    assert tracker.depth("phi") == pytest.approx(1.0)
    assert tracker.rate("never-seen") == 0.0


def test_cluster_metrics_aggregate_late_and_retired_engines(fp32_model):
    """The aggregation bugfix: engines registered after traffic started are
    included, and a retired engine's completions are never lost."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("a", _mk(model, params))
    rng = np.random.default_rng(1)
    cluster.submit(_req(rng, cfg, 0, "phi"))
    cluster.run()
    assert cluster.metrics()["completed"] == 1

    # registered AFTER the first request — must still aggregate
    cluster.register("b", _mk(model, params),
                     labels={"data-type": "general"})
    cluster.submit(_req(rng, cfg, 1, "general"))
    cluster.run()
    assert cluster.metrics()["completed"] == 2

    # retiring b keeps its completions in the cluster aggregate
    cluster.retire_engine("b")
    cluster.run()
    assert "b" not in cluster.engines()
    assert cluster.metrics()["completed"] == 2
    assert cluster.metrics_by_label()["general"]["completed"] == 1


def test_metrics_by_label_zero_fills_idle_labels(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("e", _mk(model, params))
    cluster.set_route_constraint("phi", ShardingPlan())  # vacuous, test-only
    rng = np.random.default_rng(2)
    cluster.submit(_req(rng, cfg, 0, "general"))
    cluster.run()

    by_label = cluster.metrics_by_label(extra_labels=("audio",))
    # constrained-but-idle and explicitly requested labels are zero-filled
    for label in ("phi", "audio"):
        assert set(by_label[label]) == set(METRIC_KEYS)
        assert by_label[label]["completed"] == 0
        assert np.isnan(by_label[label]["ttft_mean_s"])
    assert by_label["general"]["completed"] == 1
    depths = cluster.queue_depth_by_label(extra_labels=("audio",))
    assert depths["phi"] == 0 and depths["audio"] == 0


# ---------------------------------------------------------------------------
# scale-up: spawn on sustained per-label overload
# ---------------------------------------------------------------------------


def test_spawn_on_sustained_overload_not_on_transient(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    scaler = Autoscaler(
        cluster, lambda label: _mk(model, params),
        policy=ElasticPolicy(spawn_depth=3.0, sustain=2, cooldown=2,
                             prefer_rebalance=False),
        tracker=LoadTracker(alpha=1.0))
    rng = np.random.default_rng(3)
    for rid in range(8):
        cluster.submit(_req(rng, cfg, rid, "phi"))

    # one hot tick is transient — no spawn yet (sustain=2)
    assert scaler.tick() == []
    decisions = scaler.tick()
    assert [d.kind for d in decisions] == ["spawn"]
    assert decisions[0].label == "phi"

    (_, report), = scaler.events
    name = report.engine
    assert name in cluster.engines()
    assert report.event == "spawn"
    assert report.compiled_in_prepare > 0          # AOT'd in PREPARE
    spawned = cluster.engine(name)
    assert spawned.labels["data-type"] == "phi"    # dedicated capacity
    # the spawn took its share of the backlog immediately
    assert spawned.load > 0
    # moved requests keep their original submission timestamps
    assert all(r.t_submit > 0 for r in spawned.queue)


def test_spawned_engine_never_jits_on_serving_path(fp32_model):
    """A spawn AOT-compiles prefill for the label's live prompt lengths, so
    admission uses the AOT executable, not the JIT fallback."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    rng = np.random.default_rng(4)
    for rid in range(3):
        cluster.submit(_req(rng, cfg, rid, "phi", n=7))
    assert cluster.label_prompt_lengths("phi") == [7]

    engine = _mk(model, params)
    report = cluster.spawn_engine(
        "phi-1", engine, labels={"data-type": "phi"},
        prefill_lengths=cluster.label_prompt_lengths("phi"))
    assert report.compiled_in_prepare == 2         # decode + prefill(7)
    assert 7 in engine._prefill_exec
    assert engine._decode_exec is not None


# ---------------------------------------------------------------------------
# scale-down: retire strictly after drain, never route to draining
# ---------------------------------------------------------------------------


def test_retire_only_after_drain(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    cluster.spawn_engine("phi-0", _mk(model, params),
                         labels={"data-type": "phi"})
    scaler = Autoscaler(
        cluster, lambda label: _mk(model, params),
        policy=ElasticPolicy(retire_rate=0.25, sustain=2, cooldown=0),
        tracker=LoadTracker(alpha=1.0))
    rng = np.random.default_rng(5)
    for rid in range(4):
        cluster.submit(_req(rng, cfg, rid, "phi"))

    # cold rate but the dedicated engine still has work: no retirement
    for _ in range(3):
        assert all(d.kind != "retire" for d in scaler.tick())
    assert "phi-0" in cluster.engines()

    cluster.run()                                  # drains everything
    for _ in range(2):
        decisions = scaler.tick()
    assert [d.kind for d in decisions] == ["retire"]
    cluster.run()
    assert "phi-0" not in cluster.engines()
    # completions survived the retirement
    assert cluster.metrics_by_label()["phi"]["completed"] == 4


def test_no_request_routed_to_draining_engine(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    cluster.register("phi-0", _mk(model, params),
                     labels={"data-type": "phi"})
    rng = np.random.default_rng(6)
    # park work directly on the dedicated engine so it must drain, not vanish
    cluster.engine("phi-0").submit(_req(rng, cfg, 0, "phi"))
    assert cluster.engine("phi-0").load == 1

    report = cluster.retire_engine("phi-0")
    assert report.event == "retire" and report.downtime_s == 0.0
    assert cluster.draining() == ["phi-0"]
    assert "phi-0" in cluster.engines()            # still serving its queue

    # new traffic lands on the remaining engine, never the draining one
    for rid in range(1, 4):
        assert cluster.submit(_req(rng, cfg, rid, "phi")) == "base"
    assert "phi-0" not in cluster.eligible(_req(rng, cfg, 99, "phi"))

    cluster.run()
    assert "phi-0" not in cluster.engines()        # reaped once empty
    assert cluster.retire_engine("base").event == "retire"


def test_retire_draining_twice_raises(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("a", _mk(model, params))
    rng = np.random.default_rng(7)
    cluster.submit(_req(rng, cfg, 0, "phi"))       # keep it busy
    cluster.retire_engine("a")
    with pytest.raises(ValueError):
        cluster.retire_engine("a")


# ---------------------------------------------------------------------------
# anti-flapping hysteresis
# ---------------------------------------------------------------------------


def test_no_flapping_under_oscillating_trace(fp32_model):
    """A trace oscillating around the threshold must not churn engines:
    sustain windows + cooldown bound the number of scale events."""
    cfg, model, params = fp32_model

    def run_trace(policy):
        cluster = ServingCluster()
        cluster.register("base", _mk(model, params))
        scaler = Autoscaler(cluster, lambda label: _mk(model, params),
                            policy=policy, tracker=LoadTracker(alpha=1.0))
        rng = np.random.default_rng(8)
        rid = 0
        for t in range(10):
            if t % 2 == 0:                          # hot tick
                for _ in range(8):
                    cluster.submit(_req(rng, cfg, rid, "phi", new=2))
                    rid += 1
            scaler.tick()
            cluster.run()                           # cold by the next tick
        return len(scaler.events)

    eager = run_trace(ElasticPolicy(spawn_depth=3.0, sustain=1, cooldown=0,
                                    default_bounds=(0, 2),
                                    prefer_rebalance=False))
    damped = run_trace(ElasticPolicy(spawn_depth=3.0, sustain=2, cooldown=3,
                                     default_bounds=(0, 2),
                                     prefer_rebalance=False))
    assert eager >= 2                 # an undamped policy thrashes
    assert damped == 0                # hysteresis rides out the oscillation


# ---------------------------------------------------------------------------
# report finalization + rebalance
# ---------------------------------------------------------------------------


def test_every_scale_event_report_finalized(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    scaler = Autoscaler(
        cluster, lambda label: _mk(model, params),
        policy=ElasticPolicy(spawn_depth=2.0, retire_rate=0.25, sustain=2,
                             cooldown=1, prefer_rebalance=False),
        tracker=LoadTracker(alpha=1.0))
    rng = np.random.default_rng(9)
    rid = 0
    for t in range(4):                              # burst
        for _ in range(6):
            cluster.submit(_req(rng, cfg, rid, "phi", new=2))
            rid += 1
        scaler.tick()
        cluster.step()
    cluster.run()
    for _ in range(4):                              # quiet tail -> retire
        scaler.tick()
        cluster.run()

    kinds = {d.kind for d, _ in scaler.events}
    assert "spawn" in kinds and "retire" in kinds
    assert cluster.pending_reports() == []          # all finalized
    for _, report in scaler.events:
        assert set(report.metrics_after) == set(METRIC_KEYS)
        if report.event == "spawn":                 # spawned capacity served
            assert report.metrics_after["completed"] > 0


def test_rebalance_retargets_idle_engine_instead_of_cold_spawn(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    cluster.spawn_engine("phi-0", _mk(model, params),
                         labels={"data-type": "phi"})
    scaler = Autoscaler(
        cluster, lambda label: _mk(model, params),
        policy=ElasticPolicy(spawn_depth=3.0, sustain=2, cooldown=2,
                             prefer_rebalance=True),
        tracker=LoadTracker(alpha=1.0))
    rng = np.random.default_rng(10)
    for rid in range(10):
        cluster.submit(_req(rng, cfg, rid, "general"))

    scaler.tick()
    decisions = scaler.tick()
    assert [d.kind for d in decisions] == ["rebalance"]
    assert decisions[0].engine == "phi-0"
    assert len(cluster.engines()) == 2              # resized, not spawned
    assert cluster.engine("phi-0").labels["data-type"] == "general"
    (_, report), = scaler.events
    assert report.event == "rebalance"
    # the retargeted engine immediately shares the general backlog
    assert cluster.engine("phi-0").load > 0


# ---------------------------------------------------------------------------
# intent-pinned scaling bounds (Orchestrator.submit(apply_to=autoscaler))
# ---------------------------------------------------------------------------


def test_intent_pins_scaling_bounds_and_floor_spawns(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    scaler = Autoscaler(cluster, lambda label: _mk(model, params),
                        tracker=LoadTracker(alpha=1.0))

    orch = Orchestrator()
    res = orch.submit("Keep at least two serving engines for phi traffic.",
                      apply_to=scaler)
    assert res.success
    assert scaler.bounds["phi"] == (2, None)
    assert orch.state.scale_bounds["phi"] == (2, None)

    # the pinned floor is enforced on the next ticks, bypassing sustain
    scaler.tick()
    scaler.tick()
    assert len(cluster.engines_for_label("phi")) >= 2
    assert all(r.event == "spawn" and r.compiled_in_prepare > 0
               for _, r in scaler.events)


def test_intent_routing_plus_scaling_spawns_compliant_engines(fp32_model):
    """A hybrid intent: pod confinement AND a capacity floor. Spawned
    engines must satisfy the installed route constraint."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    scaler = Autoscaler(cluster, lambda label: _mk(model, params),
                        tracker=LoadTracker(alpha=1.0))

    orch = Orchestrator()
    res = orch.submit("Phi traffic must remain inside the pod, and keep "
                      "at least two engines for phi traffic.",
                      apply_to=scaler)
    assert res.success
    assert "base" in res.reports                   # base was reconfigured
    required = cluster.route_constraints()["phi"]

    scaler.tick()
    scaler.tick()
    phi_engines = cluster.engines_for_label("phi")
    assert len(phi_engines) >= 2
    for name in phi_engines:
        assert plan_satisfies(cluster.engine(name).plan, required)

    # the scaled cluster still serves phi end-to-end
    rng = np.random.default_rng(11)
    for rid in range(4):
        cluster.submit(_req(rng, cfg, rid, "phi"))
    cluster.run()
    assert cluster.metrics_by_label()["phi"]["completed"] == 4


def test_invalid_scaling_intent_fails_closed(fp32_model):
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    scaler = Autoscaler(cluster, lambda label: _mk(model, params))

    orch = Orchestrator()
    res = orch.submit("Keep at least two engines for financial records.",
                      apply_to=scaler)
    assert not res.success                          # unknown workload class
    assert scaler.bounds == {}                      # nothing was pinned
    assert len(cluster.engines()) == 1


def test_set_bounds_validation(fp32_model):
    cfg, model, params = fp32_model
    scaler = Autoscaler(ServingCluster(), lambda label: None)
    with pytest.raises(ValueError):
        scaler.set_bounds("phi", -1)
    with pytest.raises(ValueError):
        scaler.set_bounds("phi", 3, 2)


def test_scaling_bound_without_routing_label_fails_closed():
    """A scaling selector that matches components carrying no data-type
    label can never be enforced by the autoscaler — the compiler must
    error (fail-closed), not silently drop the bound."""
    from repro.core import Component, DeterministicInterpreter
    from repro.core.compiler import compile_intent
    from repro.core.labels import build_fabric
    from repro.core.validator import validate

    comps = (Component("doctor", {"app": "doctor"}),)   # no data-type
    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    res = DeterministicInterpreter().interpret(
        "Keep at least two instances of the doctor app.", fabric, comps)
    assert res.intent.scaling                          # parsed...
    policy = compile_intent(res.intent, fabric, comps)
    assert policy.scale_bounds == {}                   # ...but unenforceable
    assert any("scaling selector" in e for e in policy.errors)
    assert not validate(policy, fabric, comps).passed


def test_capacity_clause_keeps_colocated_placement():
    """A clause carrying both a capacity phrase and a placement predicate
    must compile BOTH constraints — the capacity grammar must not swallow
    the placement half."""
    from repro.core import DEFAULT_WORKLOAD, DeterministicInterpreter
    from repro.core.labels import build_fabric

    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    res = DeterministicInterpreter().interpret(
        "Keep at least two patient instances in the cloud zone.",
        fabric, DEFAULT_WORKLOAD)
    assert len(res.intent.scaling) == 1
    assert res.intent.scaling[0].min_engines == 2
    assert len(res.intent.placement) == 1
    assert dict(res.intent.placement[0].require) == {"zone": "cloud"}


def test_retire_and_rebalance_never_target_same_engine(fp32_model):
    """One tick can decide to retire a cold label's engine AND fix a hot
    label — but never by handing the freshly-retiring engine out as a
    rebalance donor (a draining engine is unroutable and unswappable)."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("a0", _mk(model, params), labels={"data-type": "a"})
    scaler = Autoscaler(
        cluster, lambda label: _mk(model, params),
        policy=ElasticPolicy(retire_rate=0.25, sustain=1, cooldown=0,
                             prefer_rebalance=True),
        tracker=LoadTracker(alpha=1.0))
    rng = np.random.default_rng(13)
    # label "b" demand fails closed (no engine serves it) but still counts
    for rid in range(4):
        with pytest.raises(Exception):
            cluster.submit(_req(rng, cfg, rid, "b"))

    decisions = scaler.tick()
    targeted = [d.engine for d in decisions if d.engine]
    assert len(targeted) == len(set(targeted))     # no double-targeting
    for d in decisions:
        if d.kind == "rebalance":
            assert d.engine not in cluster.draining()
    # the draining-engine guard also holds at the cluster layer
    if cluster.draining():
        with pytest.raises(ValueError):
            cluster.reconfigure(cluster.draining()[0],
                                cluster.engine(cluster.draining()[0]).plan)


def test_no_respawn_flapping_from_residual_ewma(fp32_model):
    """After traffic stops and capacity fully retires, the geometrically
    decaying EWMA (never exactly 0.0) must not read as 'hot' forever."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    scaler = Autoscaler(
        cluster, lambda label: _mk(model, params),
        policy=ElasticPolicy(sustain=1, cooldown=0),
        tracker=LoadTracker(alpha=0.5))
    rng = np.random.default_rng(14)
    # demand for an unservable label; hold scaling off while it decays
    scaler.set_bounds("phi", 0, 0)
    for rid in range(4):
        with pytest.raises(Exception):
            cluster.submit(_req(rng, cfg, rid, "phi"))
    for _ in range(10):
        scaler.tick()                              # rate: 2.0 -> ~0.004
    assert scaler.tracker.rate("phi") > 0.0        # residual, not zero
    scaler.set_bounds("phi", 0, 4)                 # allow scaling again
    for _ in range(3):
        scaler.tick()
    assert scaler.events == []                     # residual is not demand


def test_floor_blocked_by_constraint_conflict_does_not_accumulate(fp32_model):
    """If spawned engines can never satisfy the label's route constraint,
    floor enforcement must stop instead of spawning forever."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    # constraint pins pod 1; the factory's engines are pinned to pod 0 —
    # merge_restrictions degrades the conflict to axis confinement, which
    # does NOT satisfy the pin, so spawns never become eligible
    cluster.set_route_constraint("phi", ShardingPlan(
        device_constraints=(("pod", 1),)))
    factory = lambda label: _mk(  # noqa: E731
        model, params, plan=ShardingPlan(device_constraints=(("pod", 0),)))
    scaler = Autoscaler(cluster, factory, tracker=LoadTracker(alpha=1.0))
    scaler.set_bounds("phi", 2)

    for _ in range(4):
        scaler.tick()
    assert len(cluster.engines_for_label("phi")) == 0   # still ineligible
    assert len(cluster.engines()) <= 2                  # bounded by floor


def test_overlapping_scaling_constraints_intersect(fp32_model):
    """Two clauses landing on the same data-type label intersect their
    bounds; an empty intersection fails closed."""
    from repro.core import DEFAULT_WORKLOAD, DeterministicInterpreter
    from repro.core.compiler import compile_intent
    from repro.core.labels import build_fabric

    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    it = DeterministicInterpreter()
    # patient app carries data-type=phi -> both clauses hit "phi"
    res = it.interpret("Keep at least two engines for phi traffic, and "
                       "at most three instances of the patient service.",
                       fabric, DEFAULT_WORKLOAD)
    policy = compile_intent(res.intent, fabric, DEFAULT_WORKLOAD)
    assert policy.scale_bounds["phi"] == (2, 3)
    assert policy.errors == []

    res2 = it.interpret("Keep at least two engines for phi traffic, and "
                        "at most one instance of the patient service.",
                        fabric, DEFAULT_WORKLOAD)
    policy2 = compile_intent(res2.intent, fabric, DEFAULT_WORKLOAD)
    assert any("conflicting scaling bounds" in e for e in policy2.errors)


def test_number_words_need_word_boundary():
    """'fourteen' must not parse as 'four'; unknown number words yield no
    constraint rather than a wrong one. Digits always work."""
    from repro.core import DEFAULT_WORKLOAD, DeterministicInterpreter
    from repro.core.labels import build_fabric

    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    it = DeterministicInterpreter()
    res = it.interpret("Keep at least fourteen engines for phi traffic.",
                       fabric, DEFAULT_WORKLOAD)
    assert res.intent.scaling == ()                # not min_engines=4
    res2 = it.interpret("Keep at least 14 engines for phi traffic.",
                        fabric, DEFAULT_WORKLOAD)
    assert res2.intent.scaling[0].min_engines == 14


def test_donor_with_conflicting_pins_is_not_rebalanced(fp32_model):
    """A donor whose device pins conflict with the hot label's route
    constraint would come out of the swap unroutable — the policy must
    spawn instead of bricking it."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    # idle donor dedicated to cold label "a", pinned to pod 0
    cluster.register("a0", _mk(model, params, plan=ShardingPlan(
        device_constraints=(("pod", 0),))), labels={"data-type": "a"})
    # hot label "phi" requires pod 1 — conflicts with the donor's pin
    cluster.set_route_constraint("phi", ShardingPlan(
        device_constraints=(("pod", 1),)))
    scaler = Autoscaler(
        cluster,
        lambda label: _mk(model, params, plan=ShardingPlan(
            device_constraints=(("pod", 1),))),
        policy=ElasticPolicy(sustain=1, cooldown=0, prefer_rebalance=True),
        tracker=LoadTracker(alpha=1.0))
    rng = np.random.default_rng(15)
    for rid in range(4):                           # phi demand, fails closed
        with pytest.raises(Exception):
            cluster.submit(_req(rng, cfg, rid, "phi"))

    decisions = scaler.tick()
    # the hot label is fixed by a SPAWN; the conflicting donor is never
    # rebalanced (retiring it as idle cold surplus is fine)
    assert all(d.kind != "rebalance" for d in decisions)
    assert any(d.kind == "spawn" and d.label == "phi" for d in decisions)
    assert len(cluster.engines_for_label("phi")) == 1        # spawn works


def test_floor_enforced_despite_preexisting_ineligible_engine(fp32_model):
    """A pre-existing dedicated-but-ineligible engine must not count
    against the floor: eligible capacity is what the bound promises."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.set_route_constraint("phi", ShardingPlan(
        device_constraints=(("pod", 1),)))
    # dedicated to phi but pinned to the wrong pod -> never eligible
    cluster.register("stale", _mk(model, params, plan=ShardingPlan(
        device_constraints=(("pod", 0),))), labels={"data-type": "phi"})
    scaler = Autoscaler(cluster, lambda label: _mk(model, params),
                        tracker=LoadTracker(alpha=1.0))
    scaler.set_bounds("phi", 2)

    for _ in range(4):
        scaler.tick()
    # the floor fills with ELIGIBLE engines despite the stale one, and
    # enforcement then stops (no unbounded accumulation)
    assert len(cluster.engines_for_label("phi")) == 2
    assert len(cluster.engines()) == 3             # stale + 2 spawned


def test_orphaned_capacity_clause_recovered_from_full_sentence():
    """Clause splitting can orphan the capacity phrase from its subject;
    the whole-sentence fallback must recover scaling too."""
    from repro.core import DEFAULT_WORKLOAD, DeterministicInterpreter
    from repro.core.labels import build_fabric

    fabric = build_fabric((2, 16, 16), ("pod", "data", "model"))
    res = DeterministicInterpreter().interpret(
        "For the phi workloads. Provision at least two engines.",
        fabric, DEFAULT_WORKLOAD)
    assert len(res.intent.scaling) == 1
    assert res.intent.scaling[0].min_engines == 2


def test_spawn_names_skip_existing_engines(fp32_model):
    """A scaler must not crash when its generated name is already taken
    (previous scaler instance, manual registration)."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("phi-as0", _mk(model, params),
                     labels={"data-type": "phi"})
    scaler = Autoscaler(cluster, lambda label: _mk(model, params),
                        tracker=LoadTracker(alpha=1.0))
    scaler.set_bounds("phi", 2)
    scaler.tick()
    assert len(cluster.engines_for_label("phi")) == 2
    assert "phi-as1" in cluster.engines()          # collision skipped


def test_redistributed_requests_feed_aot_length_set(fp32_model):
    """Requests that reach an engine via redistribute_queued must still
    register their prompt length, so a later default-lengths reconfigure
    AOT-compiles them instead of JITting on the serving path."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    rng = np.random.default_rng(16)
    for rid in range(4):
        cluster.submit(_req(rng, cfg, rid, "phi", n=9))

    spawned = _mk(model, params)
    cluster.spawn_engine("phi-1", spawned, labels={"data-type": "phi"},
                         prefill_lengths=(9,))
    assert spawned.queue                           # took backlog
    assert 9 in spawned.seen_prompt_lengths        # length registered
    # a default-lengths reconfigure therefore covers the live shape
    report = cluster.reconfigure("phi-1", spawned.plan)
    assert 9 in spawned._prefill_exec
    assert report.compiled_in_prepare >= 2


def test_retire_paused_engine_still_drains(fp32_model):
    """Retiring a paused engine must resume it so the drain can finish —
    otherwise its queued requests would be stranded forever."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("a", _mk(model, params))
    rng = np.random.default_rng(12)
    eng = cluster.engine("a")
    eng.submit(_req(rng, cfg, 0, "phi"))
    eng.pause()

    cluster.retire_engine("a")
    assert not eng.paused                          # resumed to drain
    cluster.run()
    assert "a" not in cluster.engines()            # reaped once empty
    assert cluster.metrics()["completed"] == 1     # nothing stranded


# ---------------------------------------------------------------------------
# ticket-aware policy: in-flight spawn tickets count as pending capacity
# ---------------------------------------------------------------------------


def test_policy_counts_inflight_spawn_tickets_as_capacity(fp32_model):
    """While a label's async spawn is still compiling, the policy sizes
    further scale-ups against live + PENDING capacity: a pinned floor of
    1 with one ticket in flight emits no second spawn, independent of the
    autoscaler's suppression backstop."""
    cfg, model, params = fp32_model
    cluster = ServingCluster()
    cluster.register("base", _mk(model, params))
    ticket = cluster.spawn_engine_async(
        "phi-inflight", _mk(model, params), labels={"data-type": "phi"})
    assert cluster.pending_spawn_labels() == {"phi": 1}

    policy = ElasticPolicy(sustain=1, cooldown=0)
    tracker = LoadTracker(alpha=1.0)
    rng = np.random.default_rng(0)
    for rid in range(3):
        cluster.submit(_req(rng, cfg, rid, "phi"))
    tracker.observe(cluster)
    decisions = policy.decide(tracker, cluster, {"phi": (1, 4)})
    assert not any(d.kind == "spawn" and d.label == "phi"
                   for d in decisions), \
        f"duplicate spawn despite in-flight ticket: {decisions}"

    # once the ticket commits the pending view empties and the live
    # engine carries the floor — still no duplicate spawn
    cluster.run(wait_pending=True)
    assert ticket.done() and cluster.pending_spawn_labels() == {}
    tracker.observe(cluster)
    decisions = policy.decide(tracker, cluster, {"phi": (1, 4)})
    assert not any(d.kind == "spawn" and d.label == "phi"
                   for d in decisions)
    cluster.run()
    assert cluster.metrics()["completed"] == 3
