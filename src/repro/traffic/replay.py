"""Discrete-event replay: drive the full serving stack over a generated
trace on a simulated clock.

The harness owns a virtual-time cursor ``t`` and advances the installed
`FakeClock` (see `repro.serving.clock`) in lockstep:

  * each ``cluster.step()`` costs ``step_time_s`` simulated seconds —
    the service-rate model; engines decode in parallel, so one step
    boundary is one step duration regardless of engine count (more
    engines == more slots per step == more throughput, exactly the
    roofline's pooling assumption);
  * arrivals are submitted at their trace timestamps, between steps;
  * idle gaps (no queued or resident work anywhere) are JUMPED, not
    slept — wall-clock never gates scale;
  * the autoscaler ticks every ``tick_s`` of simulated time, and every
    ``window_ticks`` ticks the harness drains completions
    (`ServingCluster.drain_completed` — O(window), not O(history)),
    folds windowed TTFT/TPOT into the planner's `ResidualCalibration`
    (planner mode), and records the predicted-vs-measured pair — the
    one-step-ahead evaluation `BENCH_scale.json` reports;
  * calibration learns from QUASI-STEADY windows only: when the queued
    backlog exceeded ``steady_backlog`` times the pooled slot capacity
    at any control tick of the window (or the previous one — early
    completions can be stragglers of the prior transient), the window's
    latency reflects a queueing transient the roofline already models
    through rho — folding its ratio (which clips at ``ratio_cap``)
    would poison the stationary residual and corrupt every later
    prediction. The window is still SCORED — gating affects learning,
    never the evaluation.

TTFT/TPOT/SLO attainment are therefore *simulated-time* quantities,
fully determined by (trace, step_time_s, policy) — deterministic under a
fixed seed, independent of host speed. Use sync spawns
(``Autoscaler(async_spawn=False)``, the default): an async PREPARE
commits at a wall-dependent step boundary, which would leak wall time
back into the simulation.
"""
from __future__ import annotations

import dataclasses
import math
import sys
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.serving.cluster import RoutingError, ServingCluster
from repro.serving.engine import METRIC_KEYS, Request
from repro.traffic.generator import TraceRequest

SLOTargets = Mapping[str, Tuple[Optional[float], Optional[float]]]


@dataclasses.dataclass
class WindowRecord:
    """One measurement window's per-label predicted-vs-measured pair.

    ``predicted_*`` is the raw analytical roofline for the label's
    deployed configuration; ``calibrated_*`` is the same estimate with
    the residual factors learned from PREVIOUS windows (one-step-ahead:
    the window's own measurement is folded only after the prediction is
    recorded). None where no planner/calibration/deployment applies.
    """

    t: float
    label: str
    completed: int
    measured_ttft_s: float
    measured_tpot_s: float
    predicted_ttft_s: Optional[float] = None
    predicted_tpot_s: Optional[float] = None
    calibrated_ttft_s: Optional[float] = None
    calibrated_tpot_s: Optional[float] = None


@dataclasses.dataclass
class ReplayStats:
    """What a replay produced (all times simulated seconds)."""

    n_requests: int
    submitted: int
    completed: int
    dropped: int
    duration_s: float
    steps: int
    engine_seconds: float
    peak_engines: int
    final_engines: int
    per_label: Dict[str, Dict[str, float]]
    attainment: Dict[str, float]
    attainment_overall: Optional[float]
    windows: List[WindowRecord]
    downtime_max_s: float
    reports: int
    reports_finalized: bool

    def prediction_error(self) -> Dict[str, Optional[float]]:
        """Mean |relative error| of predicted vs measured TTFT/TPOT over
        the windows where BOTH the analytical and the calibrated
        estimator produced a prediction. ``*_mare`` is averaged over
        TTFT and TPOT errors jointly; None when no such window exists
        (e.g. threshold mode — no planner, nothing predicted)."""
        analytical: List[float] = []
        calibrated: List[float] = []
        for w in self.windows:
            for pred_a, pred_c, meas in (
                    (w.predicted_ttft_s, w.calibrated_ttft_s,
                     w.measured_ttft_s),
                    (w.predicted_tpot_s, w.calibrated_tpot_s,
                     w.measured_tpot_s)):
                if pred_a is None or pred_c is None:
                    continue
                if not (math.isfinite(pred_a) and math.isfinite(pred_c)
                        and math.isfinite(meas) and meas > 0):
                    continue
                analytical.append(abs(pred_a - meas) / meas)
                calibrated.append(abs(pred_c - meas) / meas)
        if not analytical:
            return {"analytical_mare": None, "calibrated_mare": None,
                    "windows_scored": 0}
        return {"analytical_mare": float(np.mean(analytical)),
                "calibrated_mare": float(np.mean(calibrated)),
                "windows_scored": len(analytical)}


def _has_work(cluster: ServingCluster) -> bool:
    for name in cluster.engines():
        try:
            eng = cluster.engine(name)
        except KeyError:
            continue
        if eng.paused:
            continue
        if eng.queue or any(r is not None for r in eng.slot_req):
            return True
    return False


def _backlog_and_slots(cluster: ServingCluster) -> Tuple[int, int]:
    """(queued requests, pooled slot capacity) across live engines."""
    backlog = slots = 0
    for name in cluster.engines():
        try:
            eng = cluster.engine(name)
        except KeyError:
            continue
        backlog += len(eng.queue)
        slots += len(eng.slot_req)
    return backlog, slots


class _LabelStats:
    """Streaming per-label accumulators (TTFT list kept for p99)."""

    __slots__ = ("ttft", "tpot", "ok", "scored")

    def __init__(self):
        self.ttft: List[float] = []
        self.tpot: List[float] = []
        self.ok = 0
        self.scored = 0


def replay_trace(trace: List[TraceRequest], cluster: ServingCluster,
                 scaler, clock, *,
                 vocab_size: int,
                 step_time_s: float,
                 tick_s: float = 1.0,
                 window_ticks: int = 20,
                 slo_targets: Optional[SLOTargets] = None,
                 steady_backlog: float = 1.0,
                 seed: int = 0,
                 max_steps: Optional[int] = None,
                 alert_evaluator=None,
                 step_time_fn=None) -> ReplayStats:
    """Replay ``trace`` through ``cluster``/``scaler`` on ``clock``.

    Args:
        trace: the generated trace (monotone arrival times).
        cluster: the serving cluster (capacity is grown/shrunk by the
            scaler; the cluster may start empty if the scaler's bounds
            or planner will spawn a first engine).
        scaler: an `Autoscaler` (threshold or planner mode) driving the
            cluster; its ``tick(dt=tick_s)`` runs every simulated
            ``tick_s``.
        clock: the INSTALLED simulated clock (`FakeClock`) — the
            harness advances it so every request/downtime stamp lands
            in simulated time. It must already be installed into the
            serving layer (`install_clock` / `simulated_time`).
        vocab_size: prompt tokens are drawn uniformly from
            ``[2, vocab_size)``.
        step_time_s: simulated duration of one ``cluster.step()``.
        tick_s: autoscaler control-loop period, simulated seconds.
        window_ticks: ticks per measurement window (drain + calibrate).
        slo_targets: per-label ``(max_ttft_s, max_tpot_s)`` attainment
            targets; defaults to the planner's targets when the scaler
            runs planner mode.
        steady_backlog: calibration steadiness gate — a window's
            measurement is folded into the planner's calibration only
            when the queued backlog stayed at or below this multiple of
            the pooled slot capacity at EVERY control tick of the
            window AND of the previous window (completions early in a
            window can be stragglers whose TTFT carries the previous
            window's queueing transient; sampling every tick catches
            transients that drain before a window boundary, e.g. the
            cold-start ramp before the first scale-out). Saturated
            windows are still scored, just not learned from: a
            transient's (clipped) ratio would corrupt the stationary
            residual for every later prediction.
        seed: PRNG seed for prompt-token materialization.
        max_steps: decode-step budget (a wedged replay raises instead
            of spinning); default scales with the trace.
        alert_evaluator: optional `repro.obs.alerts.AlertEvaluator` —
            polled at every control tick and fed each measurement
            window's calibrated-prediction/measurement pair (the
            estimator-drift signal). Purely observational: under a
            FakeClock an evaluated replay is bit-identical to an
            unevaluated one.
        step_time_fn: optional ``t -> seconds`` override of
            ``step_time_s`` (degradation injection: a slowed engine is
            a step that starts taking longer at some simulated time).

    Returns:
        The `ReplayStats`; ``dropped`` counts fail-closed routing
        rejections (0 on a healthy replay).

    Raises:
        ValueError: empty trace, non-simulated clock, bad step time.
        RuntimeError: the step budget was exhausted.
    """
    if not trace:
        raise ValueError("cannot replay an empty trace")
    if step_time_s <= 0:
        raise ValueError(f"step_time_s must be positive, got {step_time_s}")
    if not getattr(clock, "is_simulated", False):
        raise ValueError("replay_trace needs the simulated clock that is "
                         "installed into the serving layer (FakeClock)")
    planner = getattr(scaler, "planner", None)
    if slo_targets is None:
        slo_targets = dict(getattr(planner, "slo_targets", {}) or {})
    rng = np.random.default_rng(seed)
    if max_steps is None:
        max_steps = int(trace[-1].t / step_time_s) * 20 + 100_000

    epoch = clock.now
    t = 0.0
    engine_seconds = 0.0
    peak_engines = 0
    steps = 0
    submitted = 0
    dropped = 0
    stats: Dict[str, _LabelStats] = {}
    windows: List[WindowRecord] = []

    def sync(target: float) -> None:
        """Advance simulated time to ``target``, integrating
        engine-seconds over the interval."""
        nonlocal t, engine_seconds
        if target <= t:
            return
        engine_seconds += len(cluster.engines()) * (target - t)
        delta = (epoch + target) - clock.now
        if delta > 0:
            clock.advance(delta)
        t = target

    def submit(ev: TraceRequest) -> None:
        nonlocal submitted, dropped
        prompt = rng.integers(2, vocab_size,
                              size=ev.prompt_len).astype(np.int32)
        req = Request(ev.rid, prompt, max_new_tokens=ev.new_tokens,
                      labels={"data-type": ev.label})
        try:
            cluster.submit(req)
            submitted += 1
        except RoutingError:
            dropped += 1

    def measure(now: float) -> None:
        """Drain the window's completions, score them against the SLO
        targets, and close the calibration loop (predict, record, THEN
        observe — one-step-ahead)."""
        nonlocal win_ok, win_ok_prev
        # quasi-steady only when every tick of this window AND the
        # previous one was unbacklogged: early completions can be
        # stragglers still carrying the prior transient's queueing
        steady = win_ok and win_ok_prev
        win_ok_prev = win_ok
        win_ok = True
        done = cluster.drain_completed()
        if not done:
            return
        by_label: Dict[str, List[Request]] = {}
        for r in done:
            by_label.setdefault(r.labels.get("data-type", "*"),
                                []).append(r)
        demand = (planner.forecast(scaler.tracker)
                  if planner is not None else {})
        for label in sorted(by_label):
            rs = by_label[label]
            acc = stats.setdefault(label, _LabelStats())
            ttfts = [r.ttft for r in rs if math.isfinite(r.ttft)]
            tpots = [r.tpot for r in rs if math.isfinite(r.tpot)]
            acc.ttft.extend(ttfts)
            acc.tpot.extend(tpots)
            targets = slo_targets.get(label)
            if targets is not None and (targets[0] is not None
                                        or targets[1] is not None):
                for r in rs:
                    acc.scored += 1
                    ok = True
                    if targets[0] is not None and not \
                            (math.isfinite(r.ttft)
                             and r.ttft <= targets[0]):
                        ok = False
                    if targets[1] is not None and math.isfinite(r.tpot) \
                            and r.tpot > targets[1]:
                        ok = False
                    acc.ok += ok
            if not ttfts or not tpots:
                continue
            rec = WindowRecord(
                t=now, label=label, completed=len(rs),
                measured_ttft_s=float(np.mean(ttfts)),
                measured_tpot_s=float(np.mean(tpots)))
            d = demand.get(label)
            if planner is not None and d is not None and d.rate > 0:
                pa = planner.predicted_for(label, d, calibrated=False)
                pc = planner.predicted_for(label, d, calibrated=True)
                if pa is not None:
                    rec.predicted_ttft_s = pa.ttft_s
                    rec.predicted_tpot_s = pa.tpot_s
                if pc is not None:
                    rec.calibrated_ttft_s = pc.ttft_s
                    rec.calibrated_tpot_s = pc.tpot_s
                    if alert_evaluator is not None:
                        alert_evaluator.observe_prediction(
                            label,
                            predicted_ttft_s=pc.ttft_s,
                            predicted_tpot_s=pc.tpot_s,
                            measured_ttft_s=rec.measured_ttft_s,
                            measured_tpot_s=rec.measured_tpot_s)
                if steady:
                    planner.observe_measurement(
                        label, d, measured_ttft_s=rec.measured_ttft_s,
                        measured_tpot_s=rec.measured_tpot_s)
            windows.append(rec)

    i, n = 0, len(trace)
    next_tick = tick_s
    ticks = 0
    win_ok = True          # no over-limit backlog seen this window
    win_ok_prev = True     # ... nor in the previous window
    while True:
        while i < n and trace[i].t <= t:
            submit(trace[i])
            i += 1
        busy = _has_work(cluster)
        if not busy and i >= n:
            break
        if busy:
            if steps >= max_steps:
                raise RuntimeError(
                    f"replay exhausted its step budget ({max_steps}) at "
                    f"t={t:.1f}s with {i}/{n} submitted — the service "
                    "model cannot keep up with the trace")
            # charge the step's cost FIRST: tokens (and their TTFT/TPOT
            # stamps) arrive at the END of the step window, and arrivals
            # inside the window wait for the next admission boundary
            dt_step = step_time_s if step_time_fn is None \
                else float(step_time_fn(t))
            if dt_step <= 0:
                raise ValueError(
                    f"step_time_fn({t}) must be positive, got {dt_step}")
            sync(t + dt_step)
            cluster.step()
            steps += 1
        else:
            # idle: jump to whichever comes first — the next arrival or
            # the next control tick (the scaler must keep ticking to
            # retire idle capacity)
            jump = trace[i].t if i < n else next_tick
            sync(max(t, min(jump, next_tick)))
        while t >= next_tick - 1e-9:
            scaler.tick(tick_s)
            if alert_evaluator is not None:
                alert_evaluator.poll()
            ticks += 1
            next_tick += tick_s
            peak_engines = max(peak_engines, len(cluster.engines()))
            backlog, slots = _backlog_and_slots(cluster)
            if backlog > steady_backlog * max(1, slots):
                win_ok = False
            if ticks % window_ticks == 0:
                measure(t)

    cluster.run()                     # reap draining engines
    measure(t)                        # final partial window
    if alert_evaluator is not None:
        alert_evaluator.poll()        # ingest the tail of the run

    per_label: Dict[str, Dict[str, float]] = {}
    attainment: Dict[str, float] = {}
    completed = 0
    ok_total = scored_total = 0
    for label in sorted(stats):
        acc = stats[label]
        completed += len(acc.ttft)
        per_label[label] = {
            "completed": len(acc.ttft),
            "ttft_mean_s": float(np.mean(acc.ttft)) if acc.ttft
            else float("nan"),
            "ttft_p99_s": float(np.percentile(acc.ttft, 99)) if acc.ttft
            else float("nan"),
            "tpot_mean_s": float(np.mean(acc.tpot)) if acc.tpot
            else float("nan"),
        }
        if acc.scored:
            attainment[label] = acc.ok / acc.scored
            ok_total += acc.ok
            scored_total += acc.scored
    history = cluster.history
    return ReplayStats(
        n_requests=n, submitted=submitted, completed=completed,
        dropped=max(dropped, len(cluster.rejected)),
        duration_s=t, steps=steps, engine_seconds=engine_seconds,
        peak_engines=peak_engines,
        final_engines=len(cluster.engines()),
        per_label=per_label, attainment=attainment,
        attainment_overall=(ok_total / scored_total) if scored_total
        else None,
        windows=windows,
        downtime_max_s=max((r.downtime_s for r in history), default=0.0),
        reports=len(history),
        reports_finalized=all(
            set(METRIC_KEYS) <= set(r.metrics_after) for r in history))


def recorded_replay(n_requests: int = 2000, *, arch: str = "minitron_4b",
                    step_time_s: float = 4e-3, seed: int = 11,
                    recorder=None, timings: Optional[Dict[str, float]] = None,
                    alert_evaluator_factory=None,
                    step_time_fn=None,
                    bounds: Tuple[int, int] = (1, 4),
                    flash_multiplier: float = 3.0):
    """Build a compact full stack (planner + autoscaler + cluster on a
    `FakeClock`), replay a generated trace with the flight recorder ON,
    and return ``(stats, recorder, planner)``.

    This is the one-call recorded-run recipe behind ``python -m
    repro.traffic.replay --trace-out run.trace.json`` and the
    observability tests: everything is simulated-time deterministic, so
    two calls with the same arguments (and fresh recorders) produce
    identical event streams.

    Args:
        n_requests: approximate trace size (base_rate * duration).
        arch: reduced-config architecture name.
        step_time_s: simulated duration of one decode step.
        seed: trace-generation seed.
        recorder: a `repro.obs.Recorder` to record into (a fresh one is
            created when None). Pass ``False`` to run with recording
            DISABLED — the overhead benchmark's baseline; the returned
            recorder is then None.
        timings: optional dict; when given, ``timings["replay_wall_s"]``
            is set to the REAL wall-clock seconds of the replay loop
            alone (model build + AOT compile excluded) — the overhead
            benchmark compares recorded vs unrecorded on this number so
            compile-time noise cannot masquerade as recorder cost.
        alert_evaluator_factory: optional ``(recorder, planner, scaler)
            -> AlertEvaluator`` callable; the result is polled through
            the replay (see `replay_trace`). The factory sees the fully
            built stack, so it can wire the evaluator's mandatory-fix
            hooks and calibration; keep a reference in a closure to
            inspect the alerts afterwards.
        step_time_fn: forwarded to `replay_trace` (degradation
            injection).
        bounds: per-label (min, max) engine bounds — tighten the max to
            build an over-capacity scenario the planner cannot absorb.
        flash_multiplier: the built-in phi flash crowd's rate multiple
            (t in [duration/3, duration/2)); raise it to overload.
    """
    import contextlib
    import dataclasses as _dc

    import jax

    from repro.configs import get_reduced_config
    from repro.models import build_model
    from repro.obs import Recorder, recording
    from repro.planner import (
        EngineSpec,
        ResidualCalibration,
        WorkloadPlanner,
        calibrate_host_profile,
    )
    from repro.serving import (
        Autoscaler,
        FakeClock,
        LoadTracker,
        ServingEngine,
        install_clock,
    )
    from repro.sharding.plan import default_plan
    from repro.traffic.generator import (
        FlashCrowd,
        LabelProfile,
        TrafficPattern,
        generate_trace,
    )

    cfg = _dc.replace(get_reduced_config(arch), param_dtype="float32",
                      activ_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    host = calibrate_host_profile()
    spec = EngineSpec(plan=default_plan(), n_slots=8, s_max=32)

    def engine_factory(sp, label):
        return ServingEngine(model, params, n_slots=sp.n_slots,
                             s_max=sp.s_max)

    duration_s = 24.0
    base_rate = n_requests / duration_s
    pattern = TrafficPattern(
        duration_s=duration_s, base_rate=base_rate,
        labels={"phi": LabelProfile(weight=2.0),
                "gen": LabelProfile(weight=1.0)},
        diurnal_period_s=duration_s / 2,
        flash_crowds=(FlashCrowd(t_start=duration_s / 3,
                                 duration_s=duration_s / 6,
                                 multiplier=flash_multiplier,
                                 label="phi"),),
        seed=seed)

    if recorder is False:
        rec = None
    else:
        rec = recorder if recorder is not None else Recorder()
    clock = FakeClock(tick=1e-6)
    restore = install_clock(clock)
    try:
        with (recording(rec) if rec is not None
              else contextlib.nullcontext()):
            cluster = ServingCluster()
            calibration = ResidualCalibration(alpha=0.3)
            planner = WorkloadPlanner(cluster, engine_factory,
                                      specs=[spec], profiles=[host],
                                      dwell=0, calibration=calibration,
                                      clock=clock)
            for label in ("phi", "gen"):
                planner.bounds[label] = tuple(bounds)
                planner.set_slo_target(label, 50 * step_time_s,
                                       2 * step_time_s)
            scaler = Autoscaler(cluster,
                                lambda label: engine_factory(spec, label),
                                planner=planner,
                                tracker=LoadTracker(alpha=0.5),
                                async_spawn=False, clock=clock)
            planner.execute(planner.plan({}), async_spawn=False)  # floors
            planner.attach_calibrated_profiles()
            trace = generate_trace(pattern)
            evaluator = (alert_evaluator_factory(rec, planner, scaler)
                         if alert_evaluator_factory is not None else None)
            # real wall clock on purpose: this module is not registered
            # for clock injection, so `wall` is untouched by install_clock
            import time as wall
            t_loop = wall.perf_counter()
            stats = replay_trace(trace, cluster, scaler, clock,
                                 vocab_size=cfg.vocab_size,
                                 step_time_s=step_time_s, tick_s=1.0,
                                 window_ticks=4, seed=1,
                                 alert_evaluator=evaluator,
                                 step_time_fn=step_time_fn)
            if timings is not None:
                timings["replay_wall_s"] = wall.perf_counter() - t_loop
    finally:
        restore()
    return stats, rec, planner


def main(argv: Optional[List[str]] = None) -> int:
    """CLI: replay a generated trace with the flight recorder on.

        PYTHONPATH=src python -m repro.traffic.replay \\
            --requests 2000 --trace-out run.trace.json

    ``--trace-out`` dumps a Chrome ``trace_event`` JSON of the whole
    simulated run (with per-request cross-engine flow arrows) — open it
    in Perfetto (https://ui.perfetto.dev) or chrome://tracing.
    ``--slo-out`` dumps the `repro.obs.SLOLedger` accounting (windowed
    per-label attainment + pause attribution). ``--alerts-out`` runs the
    Watchtower `repro.obs.AlertEvaluator` through the replay and dumps
    every fired alert; ``--bundle-dir`` additionally captures a debug
    bundle per alert. Recorder ring drops are warned about always and
    fail the run under ``--strict-obs`` (dropped events corrupt
    attribution silently otherwise).
    """
    import argparse
    import json

    parser = argparse.ArgumentParser(
        description="recorded serving replay on a simulated clock")
    parser.add_argument("--requests", type=int, default=2000,
                        help="approximate trace size (default 2000)")
    parser.add_argument("--step-time-s", type=float, default=4e-3,
                        help="simulated decode-step duration")
    parser.add_argument("--arch", default="minitron_4b")
    parser.add_argument("--seed", type=int, default=11)
    parser.add_argument("--trace-out", default="",
                        help="write a Perfetto-loadable Chrome "
                             "trace_event JSON here")
    parser.add_argument("--slo-out", default="",
                        help="write the SLO/downtime ledger JSON here")
    parser.add_argument("--alerts-out", default="",
                        help="run the Watchtower AlertEvaluator and "
                             "write fired alerts (JSON) here")
    parser.add_argument("--bundle-dir", default="",
                        help="capture a debug bundle per fired alert "
                             "into this directory (implies alerting)")
    parser.add_argument("--strict-obs", action="store_true",
                        help="exit nonzero when the recorder dropped "
                             "events or spans (ring overflow)")
    args = parser.parse_args(argv)

    holder: Dict[str, object] = {}
    factory = None
    if args.alerts_out or args.bundle_dir:
        from repro.obs import AlertEvaluator

        def factory(rec_, planner_, scaler_):
            ev = AlertEvaluator(
                rec_, policy=planner_, calibration=planner_.calibration,
                planner=planner_, scaler=scaler_,
                bundle_dir=args.bundle_dir or None)
            holder["evaluator"] = ev
            return ev

    stats, rec, planner = recorded_replay(
        args.requests, arch=args.arch, step_time_s=args.step_time_s,
        seed=args.seed, alert_evaluator_factory=factory)
    print(f"replayed {stats.submitted} requests "
          f"({stats.completed} completed, {stats.dropped} dropped) over "
          f"{stats.duration_s:.1f} simulated seconds in {stats.steps} steps")
    print(f"recorded {rec.bus.emitted} events "
          f"({rec.bus.dropped} dropped), {rec.trace.added} spans")
    obs_drops = rec.bus.dropped + rec.trace.dropped
    if obs_drops:
        print(f"WARNING: recorder dropped {rec.bus.dropped} events and "
              f"{rec.trace.dropped} spans (ring overflow) — attribution "
              "and SLO windows are incomplete; raise Recorder capacity",
              file=sys.stderr)
    if args.trace_out or args.slo_out:
        from repro.obs import RequestLineage
        lineage = RequestLineage.from_recorder(rec)
    if args.trace_out:
        doc = rec.export_chrome(args.trace_out,
                                flows=lineage.chrome_flows())
        cons = lineage.conservation()
        worst = max(cons["ttft_max_rel_err"], cons["tpot_max_rel_err"])
        print(f"wrote {args.trace_out}: "
              f"{sum(1 for e in doc['traceEvents'] if e['ph'] == 'X')} "
              "trace events (open in Perfetto / chrome://tracing); "
              f"attributed {len(lineage)} requests, max conservation "
              f"error {worst:.2e}")
    if args.slo_out:
        from repro.obs import SLOLedger
        ledger = SLOLedger.from_policy(planner).consume(rec.events())
        with open(args.slo_out, "w") as f:
            json.dump(ledger.as_dict(), f, indent=1)
        print(f"wrote {args.slo_out}: attainment "
              f"{ledger.attainment_overall()}")
    if args.alerts_out or args.bundle_dir:
        evaluator = holder["evaluator"]
        alerts = evaluator.as_dicts()
        if args.alerts_out:
            with open(args.alerts_out, "w") as f:
                json.dump(alerts, f, indent=1, sort_keys=True)
            print(f"wrote {args.alerts_out}: {len(alerts)} alerts")
        for a in alerts:
            print(f"  ALERT {a['name']} [{a['severity']}] "
                  f"{a['label'] or a['engine']}: {a['message']}")
    if args.strict_obs and obs_drops:
        print("--strict-obs: failing on recorder drops", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
