"""Synthetic traffic + discrete-event replay at 10^5–10^6-request scale.

The paper's headline claims (<50 ms downtime, <10% TTFT/TPOT overhead)
are only meaningful under sustained, shifting load. This package is the
scale harness that makes them measurable deterministically:

    generator   seeded synthetic traffic: diurnal cycles, flash crowds,
                multi-tenant label mixes, adversarial long-prompt
                floods, heavy-tailed prompt/decode lengths — the same
                seed reproduces the trace bit for bit;
    replay      a discrete-event harness driving the full planner +
                autoscaler + migration + paged-KV stack over a trace on
                a SIMULATED clock (`repro.serving.clock`): decode steps
                advance virtual time by a modeled step duration, idle
                gaps are jumped, and wall-clock never gates scale.

See docs/architecture.md (scale harness box) and
benchmarks/scale_serving.py (the BENCH_scale.json contract).
"""
from repro.traffic.generator import (  # noqa: F401
    FlashCrowd,
    LabelProfile,
    LongPromptFlood,
    TraceRequest,
    TrafficPattern,
    generate_trace,
)
from repro.traffic.replay import (  # noqa: F401
    ReplayStats,
    replay_trace,
)
