"""Seeded synthetic traffic generator.

Serverless LLM traffic is bursty and multi-tenant (LLM-Mesh's motivating
observation): arrival rates breathe on a diurnal cycle, flash crowds
multiply them for minutes at a time, tenants mix labels unevenly, and
prompt/decode lengths are heavy-tailed — with the occasional adversarial
flood of near-capacity prompts that stresses KV admission rather than
request count. `generate_trace` composes exactly those ingredients into
one deterministic trace: the same `TrafficPattern` (same seed) yields a
bitwise-identical request list, arrival times are monotone
non-decreasing, and the per-label mix converges to the configured
weights (properties pinned by tests/test_properties.py).

The generator emits *shape only* — ``(t, label, prompt_len,
new_tokens)`` — so a trace is cheap to hold at 10^6 requests; the replay
harness materializes token arrays lazily when it submits.

Arrival process: a non-homogeneous Poisson process, realized per
``bin_s`` slice — counts drawn from the rate integral over the slice,
offsets uniform within it. Prompt lengths are drawn from a ranked
bucket distribution with Zipf-like tail weight (mostly short, sometimes
long — buckets, not raw lengths, so a replay compiles a bounded ladder
of prefill shapes instead of one executable per distinct length).
Decode lengths are geometric (the memoryless heavy-ish tail), clipped
to the profile's cap.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One generated request (shape only; tokens are materialized at
    replay time).

    Attributes:
        rid: request id, dense in arrival order (0..n-1).
        t: arrival time, seconds from trace start (monotone across the
            trace).
        label: the ``data-type`` label value.
        prompt_len: prompt length, tokens.
        new_tokens: generation budget, tokens.
    """

    rid: int
    t: float
    label: str
    prompt_len: int
    new_tokens: int


@dataclasses.dataclass(frozen=True)
class LabelProfile:
    """One tenant/label's traffic shape.

    Attributes:
        weight: relative share of base arrivals routed to this label.
        prompt_buckets: the prompt lengths this label draws from,
            ascending (a bounded ladder keeps replay compiles bounded).
        prompt_tail: Zipf exponent over the bucket ranks — bucket ``i``
            (0-based, shortest first) has weight ``(i+1) ** -tail``.
            Larger == shorter-dominated; 0 == uniform.
        new_tokens_mean: mean generation length (geometric draw).
        new_tokens_cap: hard cap on the generation budget.
    """

    weight: float = 1.0
    prompt_buckets: Tuple[int, ...] = (4, 6, 8, 12, 16)
    prompt_tail: float = 1.2
    new_tokens_mean: float = 3.0
    new_tokens_cap: int = 8

    def __post_init__(self):
        if self.weight < 0:
            raise ValueError(f"weight must be >= 0, got {self.weight}")
        if not self.prompt_buckets:
            raise ValueError("prompt_buckets must be non-empty")
        if self.new_tokens_mean < 1.0:
            raise ValueError("new_tokens_mean must be >= 1")


@dataclasses.dataclass(frozen=True)
class FlashCrowd:
    """A transient rate multiplier: arrivals in ``[t_start, t_start +
    duration_s)`` are generated at ``multiplier`` x the ambient rate
    (all labels, or one ``label`` only)."""

    t_start: float
    duration_s: float
    multiplier: float = 4.0
    label: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class LongPromptFlood:
    """An adversarial window of near-capacity prompts: ``rate`` extra
    requests/s for ``label``, every one at ``prompt_len`` tokens — the
    attack that saturates paged-KV admission without moving request
    counts much."""

    t_start: float
    duration_s: float
    rate: float
    label: str
    prompt_len: int = 24
    new_tokens: int = 2


@dataclasses.dataclass(frozen=True)
class TrafficPattern:
    """The full parameterization of one synthetic trace.

    Attributes:
        duration_s: trace length, simulated seconds.
        base_rate: mean ambient arrival rate, requests/s (before the
            diurnal modulation).
        labels: per-label `LabelProfile`s; label weights are normalized
            to a categorical mix.
        diurnal_amplitude: rate swing in [0, 1): rate(t) = base *
            (1 + A sin(2 pi t / period)).
        diurnal_period_s: one "day" of the diurnal cycle.
        flash_crowds: transient rate multipliers.
        floods: adversarial long-prompt windows.
        seed: the PRNG seed — the ONLY entropy source; a pattern is a
            pure function from seed to trace.
        bin_s: arrival-process slice width (resolution of the rate
            modulation).
    """

    duration_s: float
    base_rate: float
    labels: Mapping[str, LabelProfile]
    diurnal_amplitude: float = 0.4
    diurnal_period_s: float = 240.0
    flash_crowds: Tuple[FlashCrowd, ...] = ()
    floods: Tuple[LongPromptFlood, ...] = ()
    seed: int = 0
    bin_s: float = 1.0

    def __post_init__(self):
        if self.duration_s <= 0 or self.base_rate < 0:
            raise ValueError("duration_s must be > 0 and base_rate >= 0")
        if not self.labels:
            raise ValueError("at least one label profile is required")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1)")
        if self.bin_s <= 0:
            raise ValueError("bin_s must be positive")

    def rate_at(self, t: float, label: Optional[str] = None) -> float:
        """The modulated ambient arrival rate at time ``t`` (requests/s
        across all labels; flood arrivals are additive on top). With
        ``label``, the rate seen by crowds pinned to that label."""
        r = self.base_rate * (
            1.0 + self.diurnal_amplitude
            * np.sin(2.0 * np.pi * t / self.diurnal_period_s))
        for c in self.flash_crowds:
            if c.t_start <= t < c.t_start + c.duration_s \
                    and (c.label is None or c.label == label):
                r *= c.multiplier
        return float(max(r, 0.0))


def _bucket_weights(profile: LabelProfile) -> np.ndarray:
    ranks = np.arange(1, len(profile.prompt_buckets) + 1, dtype=np.float64)
    w = ranks ** -profile.prompt_tail
    return w / w.sum()


def _draw_shape(rng: np.random.Generator, profile: LabelProfile,
                weights: np.ndarray) -> Tuple[int, int]:
    prompt = int(profile.prompt_buckets[
        rng.choice(len(profile.prompt_buckets), p=weights)])
    # geometric with the configured mean, clipped to the cap
    p = min(1.0 / profile.new_tokens_mean, 1.0)
    new = int(min(rng.geometric(p), profile.new_tokens_cap))
    return prompt, max(new, 1)


def generate_trace(pattern: TrafficPattern) -> List[TraceRequest]:
    """Generate the deterministic trace for ``pattern``.

    Returns:
        `TraceRequest`s sorted by arrival time (monotone
        non-decreasing), rids dense in that order. Same pattern ->
        bitwise-identical output.
    """
    rng = np.random.default_rng(pattern.seed)
    label_names = sorted(pattern.labels)
    weights = np.array([pattern.labels[v].weight for v in label_names],
                       dtype=np.float64)
    if weights.sum() <= 0:
        raise ValueError("label weights must sum to > 0")
    weights = weights / weights.sum()
    bucket_w = {v: _bucket_weights(pattern.labels[v]) for v in label_names}

    events: List[Tuple[float, str, int, int]] = []
    n_bins = int(np.ceil(pattern.duration_s / pattern.bin_s))
    for b in range(n_bins):
        t0 = b * pattern.bin_s
        width = min(pattern.bin_s, pattern.duration_s - t0)
        mid = t0 + width / 2.0
        # per-label expected counts: ambient share x label-aware crowds
        lam = np.array([pattern.rate_at(mid, v) for v in label_names],
                       dtype=np.float64) * weights * width
        counts = rng.poisson(lam)
        for v, k in zip(label_names, counts):
            if k == 0:
                continue
            offsets = np.sort(rng.uniform(0.0, width, size=int(k)))
            prof = pattern.labels[v]
            for off in offsets:
                prompt, new = _draw_shape(rng, prof, bucket_w[v])
                events.append((float(t0 + off), v, prompt, new))
        # adversarial floods: additive near-capacity prompts
        for f in pattern.floods:
            lo = max(f.t_start, t0)
            hi = min(f.t_start + f.duration_s, t0 + width)
            if hi <= lo:
                continue
            k = int(rng.poisson(f.rate * (hi - lo)))
            if k == 0:
                continue
            for off in np.sort(rng.uniform(lo, hi, size=k)):
                events.append((float(off), f.label, int(f.prompt_len),
                               int(f.new_tokens)))

    events.sort(key=lambda e: e[0])
    return [TraceRequest(rid=i, t=t, label=v, prompt_len=p, new_tokens=n)
            for i, (t, v, p, n) in enumerate(events)]


def label_mix(trace: List[TraceRequest]) -> Dict[str, float]:
    """Empirical per-label request fractions of a trace."""
    counts: Dict[str, int] = {}
    for r in trace:
        counts[r.label] = counts.get(r.label, 0) + 1
    total = max(len(trace), 1)
    return {v: c / total for v, c in sorted(counts.items())}
