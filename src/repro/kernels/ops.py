"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python op-by-op, which validates BlockSpec indexing and the
online-softmax/recurrence logic. On TPU the same call sites compile to
Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_dispatch as _moe
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "scale", "q_block", "k_block"))
def flash_attention(q, k, v, *, causal: bool = True,
                    scale: Optional[float] = None,
                    q_block: int = _fa.DEFAULT_Q_BLOCK,
                    k_block: int = _fa.DEFAULT_K_BLOCK) -> jax.Array:
    return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                               q_block=q_block, k_block=k_block,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B_mat, C_mat, *, chunk: int = 256
             ) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd_scan(x, dt, A, B_mat, C_mat, chunk=chunk,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("k", "norm_topk", "block"))
def moe_topk(logits, k: int, *, norm_topk: bool = False,
             block: int = _moe.DEFAULT_BLOCK) -> Tuple[jax.Array, jax.Array]:
    return _moe.moe_topk(logits, k, norm_topk=norm_topk, block=block,
                         interpret=_interpret())
