"""Pure-jnp oracles for every Pallas kernel (and the long-context jnp path).

`flash_attention_ref` is used two ways:
  * as the allclose oracle for the Pallas flash kernel;
  * as the *production jnp path* for 32k+ prefill under pjit — the chunked
    online-softmax scan never materializes the (S, S) logits, which is what
    lets prefill_32k fit HBM without the kernel (the kernel then wins on
    VMEM locality, not on asymptotic memory).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# flash attention (online softmax, chunked over q and k)
# ---------------------------------------------------------------------------


def flash_attention_ref(
    q: jax.Array,            # (B, Sq, Hq, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jax.Array:
    """Returns (B, Sq, Hq, D). fp32 accumulation, never materializes SqxSk."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    # pad to chunk multiples
    Sq_p = (Sq + q_chunk - 1) // q_chunk * q_chunk
    Sk_p = (Sk + k_chunk - 1) // k_chunk * k_chunk
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    nq, nk = Sq_p // q_chunk, Sk_p // k_chunk

    # (B, nq, qc, Hkv, G, D) view
    qh = qp.reshape(B, nq, q_chunk, Hkv, G, D)
    kh = kp.reshape(B, nk, k_chunk, Hkv, D)
    vh = vp.reshape(B, nk, k_chunk, Hkv, D)

    def q_block(qi, q_blk):
        # q_blk: (B, qc, Hkv, G, D). Keep operands in their storage dtype and
        # accumulate in fp32 via preferred_element_type — converting k/v to
        # fp32 per step would get hoisted out of the scan by XLA and
        # materialize the whole K in fp32.
        def k_step(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_index_in_dim(kh, ki, axis=1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vh, ki, axis=1, keepdims=False)
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k_blk,
                                preferred_element_type=jnp.float32) * scale
            q_pos = qi * q_chunk + jnp.arange(q_chunk)
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            mask = k_pos[None, :] < Sk                      # kv padding
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32)
        if causal:
            # only k blocks up to the diagonal contribute — static bound, so
            # the causal 2x flop saving is real (and visible to the roofline)
            hi = min(nk, ((qi + 1) * q_chunk + k_chunk - 1) // k_chunk)
        else:
            hi = nk

        (m, l, acc), _ = jax.lax.scan(
            lambda c, ki: (k_step(c, ki)[0], None), (m0, l0, a0),
            jnp.arange(hi))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, Hkv, G, qc, D) -> (B, qc, Hkv, G, D)
        return jnp.moveaxis(out, 3, 1)

    outs = []
    for qi in range(nq):
        outs.append(q_block(qi, qh[:, qi]))
    out = jnp.stack(outs, axis=1)                            # (B, nq, qc, Hkv, G, D)
    out = out.reshape(B, Sq_p, Hq, D)[:, :Sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD scan oracle — re-export of the model reference
# ---------------------------------------------------------------------------

from repro.models.ssm import ssd_scan_ref  # noqa: E402,F401


# ---------------------------------------------------------------------------
# MoE top-k gating oracle
# ---------------------------------------------------------------------------


def moe_topk_ref(logits: jax.Array, k: int, *, norm_topk: bool = False
                 ) -> Tuple[jax.Array, jax.Array]:
    """(T, E) fp32 logits -> (weights (T, k) fp32, idx (T, k) int32)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    if norm_topk:
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, idx.astype(jnp.int32)
