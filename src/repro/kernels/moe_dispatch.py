"""Pallas TPU kernel for MoE top-k gating (softmax + iterative top-k).

Grid: (n_token_blocks,). Each step loads a (block, E) logit tile into VMEM,
computes a fp32 softmax, then peels off the top-k experts with k
max+mask sweeps (k <= 8 in all assigned configs, so the sweep beats a sort).
Outputs per-token weights (block, k) and expert ids (block, k).

VMEM working set: (block x E) fp32 + small outputs — with block=1024 and
E<=64: 256 KiB.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

DEFAULT_BLOCK = 1024


def _gate_kernel(logits_ref, w_ref, i_ref, *, k: int, norm_topk: bool):
    logits = logits_ref[...].astype(jnp.float32)            # (blk, E)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)

    blk, E = probs.shape
    work = probs
    ws = []
    ids = []
    for _ in range(k):                                       # k static sweeps
        best = jnp.max(work, axis=-1)                        # (blk,)
        bid = jnp.argmax(work, axis=-1).astype(jnp.int32)    # (blk,)
        ws.append(best)
        ids.append(bid)
        onehot = jax.lax.broadcasted_iota(jnp.int32, (blk, E), 1) == bid[:, None]
        work = jnp.where(onehot, NEG_INF, work)

    w = jnp.stack(ws, axis=-1)                               # (blk, k)
    if norm_topk:
        w = w / jnp.sum(w, axis=-1, keepdims=True)
    w_ref[...] = w
    i_ref[...] = jnp.stack(ids, axis=-1)


def moe_topk(
    logits: jax.Array,       # (T, E) any float dtype
    k: int,
    *,
    norm_topk: bool = False,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (weights (T, k) fp32, idx (T, k) int32)."""
    T, E = logits.shape
    block = min(block, T)
    T_pad = (T + block - 1) // block * block
    lp = jnp.pad(logits, ((0, T_pad - T), (0, 0)), constant_values=NEG_INF)

    kernel = functools.partial(_gate_kernel, k=k, norm_topk=norm_topk)
    w, idx = pl.pallas_call(
        kernel,
        grid=(T_pad // block,),
        in_specs=[pl.BlockSpec((block, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block, k), lambda i: (i, 0)),
            pl.BlockSpec((block, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((T_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )(lp)
    return w[:T], idx[:T]
