"""Pallas TPU flash attention (causal, GQA-aware).

Grid: (B * Hq, n_q_blocks, n_k_blocks) — the k-block axis is the minormost
grid dim, so TPU executes it sequentially per (head, q-block) and the
online-softmax state (m, l, acc) lives in VMEM scratch across k steps.

BlockSpecs keep the VMEM working set at
  q_block x D  +  k_block x D x 2  +  q_block x k_block (logits)
≈ (128x128 + 2x256x128 + 128x256) x 4B ≈ 0.5 MiB — far under the ~16 MiB
VMEM budget, with all matmul dims multiples of 128 for the MXU.

Causal skipping: blocks strictly above the diagonal short-circuit via
pl.when on the block indices (the classic flash-attention 2x win).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_Q_BLOCK = 128
DEFAULT_K_BLOCK = 256


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, q_block: int, k_block: int,
                  seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * q_block
    k_start = ki * k_block

    # causal: skip blocks strictly above the diagonal
    run = (k_start <= q_start + q_block - 1) if causal else (ki >= 0)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale           # (qb, D)
        k = k_ref[0].astype(jnp.float32)                   # (kb, D)
        v = v_ref[0].astype(jnp.float32)                   # (kb, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)            # (qb, kb)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (q_block, k_block), 1)
        mask = k_pos < seq_len
        if causal:
            mask = mask & (k_pos <= q_pos)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # (B, Sq, Hq, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    q_block: int = DEFAULT_Q_BLOCK,
    k_block: int = DEFAULT_K_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5

    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    Sq_p = (Sq + q_block - 1) // q_block * q_block
    Sk_p = (Sk + k_block - 1) // k_block * k_block
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))

    # (B*H, S, D) layout — one grid row per (batch, head)
    qh = jnp.moveaxis(qp, 2, 1).reshape(B * Hq, Sq_p, D)
    kh = jnp.moveaxis(kp, 2, 1).reshape(B * Hkv, Sk_p, D)
    vh = jnp.moveaxis(vp, 2, 1).reshape(B * Hkv, Sk_p, D)

    grid = (B * Hq, Sq_p // q_block, Sk_p // k_block)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        q_block=q_block, k_block=k_block, seq_len=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda h, qi, ki, G=G: (h // G, ki, 0)),
            pl.BlockSpec((1, k_block, D),
                         lambda h, qi, ki, G=G: (h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, D), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),   # running max m
            pltpu.VMEM((q_block, 1), jnp.float32),   # running sum l
            pltpu.VMEM((q_block, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out.reshape(B, Hq, Sq_p, D)[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)                       # (B, Sq, Hq, D)
