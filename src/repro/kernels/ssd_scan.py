"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (B, H, n_chunks) — chunks are the minormost (sequential) dim, so the
inter-chunk recurrent state h (P x N fp32) lives in VMEM scratch and is
carried across chunk steps, while each step does the dense intra-chunk work
on the MXU:

  scores = C_c B_c^T  (L x L)   -> masked by the decay kernel exp(segsum)
  y_diag = (scores * decay) (dt x)_c
  y_off  = C_c h_prev * exp(cumsum dA)
  h      = h * exp(sum dA) + B_c^T (decay_states * dt * x)_c

VMEM working set per step: x/dt/B/C chunks + two L x L fp32 tiles + the
(P, N) state ≈ (256x64 + 2x256x256 + 64x128) x 4B ≈ 0.7 MiB. L (=chunk),
P, N are multiples of 8/128 where the config allows — MXU-aligned.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref,
                *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)     # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)   # (L, 1) — keep 2D for TPU
    A = a_ref[...]                              # (1,) fp32
    Bm = b_ref[0, 0, 0].astype(jnp.float32)    # (L, N)
    Cm = c_ref[0, 0, 0].astype(jnp.float32)    # (L, N)

    L = x.shape[0]
    dA = dt[:, 0] * A[0]                        # (L,)
    dA_cum = jnp.cumsum(dA)                     # (L,)

    # decay kernel: exp(segsum) lower-triangular
    # segsum convention: sum_{j < t <= i} dA_t = dA_cum[i] - dA_cum[j]
    seg = dA_cum[:, None] - dA_cum[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(row >= col, jnp.exp(seg), 0.0)        # (L, L)

    dtx = x * dt                                            # (L, P)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, L)
    y_diag = jax.lax.dot_general(scores * decay, dtx,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (L, P)

    # inter-chunk contribution from carried state
    h_prev = h_ref[...]                                     # (P, N)
    state_decay = jnp.exp(dA_cum)[:, None]                  # (L, 1)
    y_off = jax.lax.dot_general(Cm * state_decay, h_prev,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (L, P)

    y_ref[0, 0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: h = h * exp(sum dA) + (decay_states * dtx)^T B
    chunk_decay = jnp.exp(dA_cum[L - 1])
    decay_states = jnp.exp(dA_cum[L - 1] - dA_cum)[:, None]  # (L, 1)
    hb = jax.lax.dot_general(dtx * decay_states, Bm,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)      # (P, N)
    h_ref[...] = h_prev * chunk_decay + hb

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


def ssd_scan(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H) fp32 (post-softplus)
    A: jax.Array,      # (H,) fp32 negative
    B_mat: jax.Array,  # (B, S, G, N)
    C_mat: jax.Array,  # (B, S, G, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final_state (B,H,P,N)). S padded to chunk."""
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    rep = H // G
    S_orig = S
    if S % chunk:
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = x.shape[1]
    nc = S // chunk

    # layouts: (B, H, nc, L, ...) so blocks are contiguous per grid row
    xh = jnp.moveaxis(x, 2, 1).reshape(Bb, H, nc, chunk, P)
    dth = jnp.moveaxis(dt, 2, 1).reshape(Bb, H, nc, chunk, 1).astype(jnp.float32)
    bh = jnp.moveaxis(B_mat, 2, 1).reshape(Bb, G, nc, chunk, N)
    ch = jnp.moveaxis(C_mat, 2, 1).reshape(Bb, G, nc, chunk, N)

    grid = (Bb, H, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)

    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, 1, chunk, N),
                         lambda b, h, c, rep=rep: (b, h // rep, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, N),
                         lambda b, h, c, rep=rep: (b, h // rep, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, H, nc, chunk, P), x.dtype),
            jax.ShapeDtypeStruct((Bb, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dth, A.astype(jnp.float32), bh, ch)
    y = y.reshape(Bb, H, S, P)
    y = jnp.moveaxis(y, 1, 2)[:, :S_orig]                   # (B, S, H, P)
    return y, h_final
