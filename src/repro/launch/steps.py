"""Step builders: jit-wrapped train / prefill / decode with shardings.

`input_specs(cfg, cell)` returns ShapeDtypeStruct stand-ins for every model
input — weak-type-correct, shardable, no device allocation — used by the
dry-run and by ahead-of-time compilation in the reconfiguration engine.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import Model
from repro.optim import AdamW
from repro.sharding import (
    ShardingPlan,
    batch_specs,
    cache_specs,
    opt_state_specs,
    param_specs,
)
from repro.sharding.ctx import activation_sharding

PyTree = Any


# ---------------------------------------------------------------------------
# ShapeDtypeStruct inputs (no allocation)
# ---------------------------------------------------------------------------


def batch_struct(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    """Input stand-ins for a train/prefill batch of the given cell."""
    B = cell.global_batch
    S = cell.seq_len + 1 if cell.kind == "train" else cell.seq_len
    sds = jax.ShapeDtypeStruct
    batch: Dict[str, jax.ShapeDtypeStruct] = {
        "tokens": sds((B, S), jnp.int32)}
    if cell.kind == "train":
        batch["loss_mask"] = sds((B, S - 1), jnp.float32)
    if cfg.encdec is not None:
        batch["frames"] = sds((B, cfg.encdec.encoder_seq_len, cfg.d_model),
                              jnp.bfloat16)
    if cfg.pos_type == "mrope":
        batch["positions"] = sds((3, B, S), jnp.int32)
    return batch


def decode_struct(model: Model, cell: ShapeCell,
                  cache_dtype=jnp.bfloat16) -> Tuple[PyTree, ...]:
    """(tokens, cache, pos) stand-ins for a decode step at S_max=cell.seq_len."""
    B = cell.global_batch
    sds = jax.ShapeDtypeStruct
    tokens = sds((B, 1), jnp.int32)
    cache = model.cache_shapes(B, cell.seq_len, dtype=cache_dtype)
    pos = sds((), jnp.int32)
    return tokens, cache, pos


def param_struct(model: Model, cell: Optional[ShapeCell] = None) -> PyTree:
    max_seq = cell.seq_len + 1 if cell is not None else None
    return model.param_shapes(max_seq=max_seq)


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def named(mesh: jax.sharding.Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def _split_micro(batch: Dict[str, jax.Array], accum: int) -> Dict[str, jax.Array]:
    """Reshape each batch leaf to (accum, B/accum, ...). `positions` carries
    batch on axis 1 (M-RoPE layout), everything else on axis 0."""

    def one(key, x):
        ax = 1 if key == "positions" else 0
        assert x.shape[ax] % accum == 0, (key, x.shape, accum)
        new = x.shape[:ax] + (accum, x.shape[ax] // accum) + x.shape[ax + 1:]
        x = x.reshape(new)
        return jnp.moveaxis(x, ax, 0)

    return {k: one(k, v) for k, v in batch.items()}


def make_train_step(model: Model, optimizer: AdamW,
                    mesh: Optional[jax.sharding.Mesh] = None,
                    plan: Optional[ShardingPlan] = None,
                    accum_steps: int = 1,
                    grad_reduce_dtype: Optional[str] = None,
                    shard_grads: bool = True):
    """(params, opt_state, batch) -> (params, opt_state, loss, metrics).

    accum_steps > 1 runs gradient accumulation over microbatches (sharded
    accumulator) — the standard memory lever at global batch 256.

    shard_grads pins gradients to the parameters' (FSDP) sharding right
    after the backward pass, turning the cross-data-axis gradient
    all-reduce into a reduce-scatter and keeping all optimizer math sharded
    (ZeRO-2). grad_reduce_dtype="bfloat16" additionally halves the gradient
    reduction wire bytes (beyond-paper distributed-optimization levers).
    """
    pspecs = param_specs(model.cfg, plan) if mesh is not None else None

    def _constrain_grads(g):
        if not shard_grads or pspecs is None:
            return g
        shardings = named(mesh, pspecs)
        return jax.tree.map(jax.lax.with_sharding_constraint, g, shardings)

    def _cast(g):
        if grad_reduce_dtype is None:
            return g
        return jax.tree.map(lambda x: x.astype(grad_reduce_dtype), g)

    def train_step(params, opt_state, batch):
        ctx = (activation_sharding(mesh, plan) if mesh is not None
               else _null_ctx())
        with ctx:
            if accum_steps == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: model.train_loss(p, batch), has_aux=True)(params)
                grads = _constrain_grads(_cast(grads))
            else:
                micro = _split_micro(batch, accum_steps)
                acc_dtype = jnp.dtype(grad_reduce_dtype or jnp.float32)

                def one_micro(carry, mb):
                    gacc, lacc = carry
                    (l, met), g = jax.value_and_grad(
                        lambda p: model.train_loss(p, mb), has_aux=True)(params)
                    g = _constrain_grads(_cast(g))
                    gacc = jax.tree.map(
                        lambda a, b: a + b.astype(acc_dtype), gacc, g)
                    return (_constrain_grads(gacc), lacc + l), met

                g0 = _constrain_grads(jax.tree.map(
                    lambda p: jnp.zeros(p.shape, acc_dtype), params))
                (gsum, lsum), mets = jax.lax.scan(
                    one_micro, (g0, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g: g / accum_steps, gsum)
                loss = lsum / accum_steps
                metrics = jax.tree.map(lambda m: m[-1], mets)
            new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss, metrics

    return train_step


import contextlib


@contextlib.contextmanager
def _null_ctx():
    yield


def jit_train_step(model: Model, optimizer: AdamW, mesh: jax.sharding.Mesh,
                   plan: ShardingPlan, cell: ShapeCell, accum_steps: int = 1,
                   grad_reduce_dtype: Optional[str] = None,
                   shard_grads: bool = True):
    pspecs = param_specs(model.cfg, plan)
    ospecs = opt_state_specs(pspecs)
    bspecs = batch_specs(model.cfg, plan, cell)
    step = make_train_step(model, optimizer, mesh, plan, accum_steps,
                           grad_reduce_dtype, shard_grads)
    return jax.jit(
        step,
        in_shardings=(named(mesh, pspecs), named(mesh, ospecs), named(mesh, bspecs)),
        out_shardings=(named(mesh, pspecs), named(mesh, ospecs),
                       NamedSharding(mesh, P()),
                       jax.tree.map(lambda _: NamedSharding(mesh, P()),
                                    {"ce": 0, "moe_aux": 0})),
        donate_argnums=(0, 1),
    )


def jit_prefill(model: Model, mesh: jax.sharding.Mesh, plan: ShardingPlan,
                cell: ShapeCell):
    bspecs = batch_specs(model.cfg, plan, cell)
    cspecs = cache_specs(model.cfg, plan, batch=cell.global_batch)
    pspecs = param_specs(model.cfg, plan)
    b_ax = plan.batch_axes if cell.global_batch > 1 else None
    logits_spec = P(b_ax, plan.tp if plan.shard_vocab else None)

    def prefill(params, batch):
        with activation_sharding(mesh, plan):
            return model.prefill(params, batch)

    return jax.jit(
        prefill,
        in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
        out_shardings=(NamedSharding(mesh, logits_spec), named(mesh, cspecs)),
    )


def jit_decode_step(model: Model, mesh: jax.sharding.Mesh, plan: ShardingPlan,
                    cell: ShapeCell):
    cspecs = cache_specs(model.cfg, plan, batch=cell.global_batch)
    pspecs = param_specs(model.cfg, plan)
    b_ax = plan.batch_axes if cell.global_batch > 1 else None
    logits_spec = P(b_ax, plan.tp if plan.shard_vocab else None)
    tok_spec = P(b_ax, None)

    def decode(params, tokens, cache, pos):
        with activation_sharding(mesh, plan):
            return model.decode_step(params, tokens, cache, pos)

    return jax.jit(
        decode,
        in_shardings=(named(mesh, pspecs), NamedSharding(mesh, tok_spec),
                      named(mesh, cspecs), NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, logits_spec), named(mesh, cspecs)),
        donate_argnums=(2,),
    )
