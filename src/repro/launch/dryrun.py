import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-moe-a2.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]

Per cell this prints & records:
  * compiled.memory_analysis()  -> bytes/device (proves fit)
  * compiled.cost_analysis()    -> HLO FLOPs / bytes for the roofline
  * collective wire bytes per device, split by mesh axis (parsed HLO)
  * the three roofline terms + dominant bottleneck
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, applicable_cells, get_config, get_shape_cell
from repro.configs.base import ModelConfig, ShapeCell
from repro.core import hlo_cost
from repro.launch import mesh as mesh_lib
from repro.launch.steps import (
    batch_struct,
    decode_struct,
    jit_decode_step,
    jit_prefill,
    jit_train_step,
    param_struct,
)
from repro.models import build_model
from repro.optim import AdamW
from repro.sharding import ShardingPlan, default_plan, opt_state_specs, param_specs
from repro.launch.steps import named


# gradient-accumulation steps per arch for train_4k: sized so the saved
# scan-carry residuals (+ transients) fit the 16 GiB HBM budget
TRAIN_ACCUM = {
    "nemotron-4-340b": 16,
    "deepseek-coder-33b": 4,
    "jamba-v0.1-52b": 4,
    "whisper-large-v3": 4,
    "minicpm3-4b": 2,
    "moonshot-v1-16b-a3b": 2,
    "mamba2-370m": 2,
}


def plan_for_cell(cfg: ModelConfig, cell: ShapeCell, multi_pod: bool,
                  overrides: Optional[Dict] = None,
                  profile: str = "baseline") -> ShardingPlan:
    plan = default_plan(multi_pod)
    if cell.kind == "train" and cfg.family in ("dense", "moe", "vlm", "encdec"):
        # Megatron-style sequence parallelism for the residual carry.
        # SSM/hybrid scan over the (sharded) chunk dim, so SP is off there.
        plan = plan.with_(sequence_parallel=True)
    n_devices = 512 if multi_pod else 256
    if (profile == "optimized" and cell.kind == "train"
            and cfg.param_count() < 1e9
            and cell.global_batch % n_devices == 0):
        # §Perf iteration A1: sub-1B models waste the model axis on TP
        # (104 GB/step of partial-sum all-reduce for mamba2-370m) — use it
        # for data parallelism instead (pure DP-256 + 2-axis FSDP)
        axes = (("pod", "data", "model") if multi_pod
                else ("data", "model"))
        plan = plan.with_(tp_axis=None, ep_axis=None, batch_axes=axes,
                          fsdp_axes=axes, sequence_parallel=False)
    if cell.kind in ("decode", "prefill"):
        # KV caches shard the sequence dim (flash-decoding style)
        if cell.global_batch == 1:
            # long-context decode: batch unshardable -> context-parallel KV
            # over every available axis
            axes = ("pod", "data", "model") if multi_pod else ("data", "model")
            plan = plan.with_(seq_axis=axes)
        else:
            plan = plan.with_(seq_axis="model")
    if overrides:
        plan = plan.with_(**overrides)
    return plan


def lower_cell(arch: str, shape: str, *, multi_pod: bool = False,
               plan_overrides: Optional[Dict] = None,
               loss_chunk: Optional[int] = 2048,
               remat_policy: Optional[str] = "nothing",
               opt_state_dtype: Optional[str] = "bfloat16",
               accum_steps: Optional[int] = None,
               cfg_patch: Optional[Dict] = None,
               moe_patch: Optional[Dict] = None,
               ssm_patch: Optional[Dict] = None,
               cache_dtype: str = "bfloat16",
               grad_reduce_dtype: Optional[str] = None,
               shard_grads: bool = True,
               profile: str = "baseline"):
    """Lower + compile one cell. Returns (record dict, compiled).

    The *_patch / cache_dtype knobs are the §Perf hillclimbing levers:
    e.g. moe_patch={"capacity_factor": 0.5}, ssm_patch={"chunk_size": 128},
    cache_dtype="float8_e4m3fn" (fp8 KV cache).
    """
    cfg = get_config(arch)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    if moe_patch and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, **moe_patch))
    if ssm_patch and cfg.ssm is not None:
        cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm, **ssm_patch))
    cell = get_shape_cell(shape)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    plan = plan_for_cell(cfg, cell, multi_pod, plan_overrides, profile)
    model = build_model(cfg, loss_chunk=loss_chunk, remat_policy=remat_policy)
    if accum_steps is None:
        # NB: lookup by canonical dashed name (cfg.name), not the CLI arg
        accum_steps = (TRAIN_ACCUM.get(cfg.name, 1)
                       if cell.kind == "train" else 1)
        if plan.tp_axis is None and cell.kind == "train":
            # pure-DP plans shard the batch over every axis — microbatches
            # must still cover all devices (§Perf iteration A1 lesson)
            accum_steps = max(1, cell.global_batch // int(mesh.devices.size))
            accum_steps = min(accum_steps,
                              cell.global_batch // int(mesh.devices.size) or 1)

    t0 = time.time()
    if cell.kind == "train":
        optimizer = AdamW(lr=3e-4, state_dtype=opt_state_dtype)
        step = jit_train_step(model, optimizer, mesh, plan, cell, accum_steps,
                              grad_reduce_dtype, shard_grads)
        params = param_struct(model, cell)
        opt_state = jax.eval_shape(optimizer.init, params)
        batch = batch_struct(cfg, cell)
        lowered = step.lower(params, opt_state, batch)
    elif cell.kind == "prefill":
        step = jit_prefill(model, mesh, plan, cell)
        params = param_struct(model, cell)
        batch = batch_struct(cfg, cell)
        lowered = step.lower(params, batch)
    else:  # decode
        step = jit_decode_step(model, mesh, plan, cell)
        params = param_struct(model, cell)
        import jax.numpy as _jnp
        tokens, cache, pos = decode_struct(model, cell, cache_dtype=_jnp.dtype(cache_dtype))
        lowered = step.lower(params, tokens, cache, pos)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, (list, tuple)):
        xla_cost = xla_cost[0] if xla_cost else {}
    hlo = compiled.as_text()
    # trip-count-aware cost model (XLA's cost_analysis counts while bodies
    # once — useless for scan-over-layers; see repro.core.hlo_cost)
    csum = hlo_cost.analyze(hlo, mesh.devices.shape, mesh.axis_names)

    n_chips = int(mesh.devices.size)
    flops_total = float(csum["flops"])
    bytes_total = float(csum["bytes"])
    compute_s = flops_total / mesh_lib.PEAK_FLOPS_BF16
    memory_s = bytes_total / mesh_lib.HBM_BW
    wire = csum["wire_bytes_per_device"]
    # split wire bytes by link class: ICI within a pod, DCN across pods
    dcn_bytes = csum["wire_bytes_by_axis"].get("pod", 0.0)
    ici_bytes = wire - dcn_bytes
    collective_s = ici_bytes / mesh_lib.ICI_BW + dcn_bytes / mesh_lib.DCN_BW

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)

    # model-FLOPs utilisation proxy
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens_proc = cell.global_batch * cell.seq_len
        model_flops = 6 * n_active * tokens_proc
    elif cell.kind == "prefill":
        tokens_proc = cell.global_batch * cell.seq_len
        model_flops = 2 * n_active * tokens_proc
    else:
        tokens_proc = cell.global_batch
        model_flops = 2 * n_active * tokens_proc
    hlo_flops_all = flops_total * n_chips
    useful_ratio = model_flops / hlo_flops_all if hlo_flops_all else 0.0

    record = {
        "arch": arch,
        "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "accum_steps": accum_steps,
        "plan": {k: v for k, v in dataclasses.asdict(plan).items()},
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
            "hbm_capacity": mesh_lib.HBM_BYTES,
            "fits": (mem.argument_size_in_bytes + mem.temp_size_in_bytes)
                    <= mesh_lib.HBM_BYTES,
        },
        "cost": {
            "hlo_flops_per_device": flops_total,
            "hlo_bytes_per_device": bytes_total,
            "transcendentals": float(csum["transcendentals"]),
            "xla_cost_analysis_flops": float(xla_cost.get("flops", 0.0)),
        },
        "collectives": {
            "n": csum["n_collective_ops"],
            "by_kind": csum["collectives_by_kind"],
            "wire_bytes_by_axis": csum["wire_bytes_by_axis"],
            "wire_bytes_per_device": wire,
            "ici_bytes": ici_bytes,
            "dcn_bytes": dcn_bytes,
        },
        "roofline": {
            **{k: float(v) for k, v in terms.items()},
            "bottleneck": bottleneck,
            "model_flops": model_flops,
            "hlo_flops_all_chips": hlo_flops_all,
            "useful_flops_ratio": useful_ratio,
            "step_time_lower_bound_s": max(terms.values()),
            "roofline_fraction": (
                compute_s / max(max(terms.values()), 1e-30)),
        },
        "params": {"total": n_params, "active": n_active},
    }
    return record, compiled


# dry-run profiles: the paper-faithful conservative configuration vs the
# beyond-paper optimized defaults (§Perf winners)
PROFILES = {
    "baseline": dict(shard_grads=False, grad_reduce_dtype=None,
                     profile="baseline"),
    "optimized": dict(shard_grads=True, grad_reduce_dtype="bfloat16",
                      cache_dtype="float8_e4m3fn",   # §Perf C1: fp8 KV cache
                      profile="optimized"),
}


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path,
             plan_overrides: Optional[Dict] = None, tag: str = "",
             **lower_kwargs) -> Dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    name = f"{arch}__{shape}__{mesh_name}{('__' + tag) if tag else ''}"
    try:
        record, compiled = lower_cell(arch, shape, multi_pod=multi_pod,
                                      plan_overrides=plan_overrides,
                                      **lower_kwargs)
        record["status"] = "ok"
        print(f"[dryrun] {name}: OK compile={record['compile_s']}s "
              f"peak={record['memory']['peak_bytes']/2**30:.2f}GiB "
              f"bottleneck={record['roofline']['bottleneck']} "
              f"rf={record['roofline']['roofline_fraction']:.3f}")
    except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
        record = {"arch": arch, "shape": shape, "mesh": mesh_name,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
        print(f"[dryrun] {name}: FAIL {type(e).__name__}: {e}")
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{name}.json").write_text(json.dumps(record, indent=1, default=str))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--profile", default="baseline", choices=sorted(PROFILES))
    args = ap.parse_args()
    out_dir = Path(args.out)
    profile_kwargs = PROFILES[args.profile]

    jobs = []
    if args.all:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for cell in applicable_cells(cfg):
                jobs.append((arch, cell.name))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        jobs.append((args.arch, args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch, shape in jobs:
        for mp in meshes:
            results.append(run_cell(arch, shape, mp, out_dir,
                                    **profile_kwargs))

    ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"[dryrun] {ok}/{len(results)} cells compiled")
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
