"""Production mesh construction.

`make_production_mesh` is a FUNCTION so importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Hardware model (TPU v5e-like):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI, 16 GiB HBM.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax

# roofline hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
HBM_BYTES = 16 * 1024 ** 3        # capacity
DCN_BW = 12.5e9                   # B/s per host, cross-pod (assumed)

SINGLE_POD = (16, 16)
SINGLE_POD_AXES = ("data", "model")
MULTI_POD = (2, 16, 16)
MULTI_POD_AXES = ("pod", "data", "model")


def make_production_mesh(*, multi_pod: bool = False,
                         devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = MULTI_POD_AXES if multi_pod else SINGLE_POD_AXES
    n = int(np.prod(shape))
    if devices is None:
        devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "dryrun.py must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh(shape: Sequence[int] = (1, 1),
                   axes: Sequence[str] = ("data", "model")) -> jax.sharding.Mesh:
    """Tiny mesh over the real local devices (tests / examples)."""
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    return jax.sharding.Mesh(np.asarray(devices).reshape(tuple(shape)), tuple(axes))
