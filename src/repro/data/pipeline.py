"""Deterministic, shardable synthetic-LM data pipeline.

Every batch is a pure function of (seed, step) via PRNG fold-in, so:
  * restart-from-checkpoint resumes the exact stream (only the step counter
    is checkpointed);
  * each data shard can be generated *locally* on its host with
    `jax.make_array_from_callback` — no central dispatcher, which is the
    property that matters at 1000+ nodes;
  * elastic re-sharding is trivial (the global batch is identical for any
    mesh, hosts just own different slices).

The stream emulates documents: geometric-length spans of "content" tokens
separated by BOS, with a loss mask over content.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

BOS = 1


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 64

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k_tok, k_doc = jax.random.split(key)
        S = self.seq_len + 1
        # skewed (power-law-ish) unigram over content ids, via inverse
        # CDF: u^4 concentrates mass on the low ids, so the stream has a
        # LEARNABLE unigram structure (a uniform draw's cross-entropy is
        # irreducibly ln(vocab-2) — a model can't demonstrably improve
        # on it within a short smoke test). Still a pure function of
        # (seed, step): determinism/restart semantics are unchanged.
        u = jax.random.uniform(k_tok, (self.global_batch, S))
        tokens = (2 + (self.vocab_size - 2) * u ** 4.0).astype(jnp.int32)
        tokens = jnp.clip(tokens, 2, self.vocab_size - 1)
        # document boundaries (BOS) with prob 1/mean_doc_len
        doc = jax.random.bernoulli(
            k_doc, 1.0 / self.mean_doc_len, (self.global_batch, S))
        tokens = jnp.where(doc, BOS, tokens)
        loss_mask = (tokens[:, 1:] != BOS).astype(jnp.float32)
        return {"tokens": tokens, "loss_mask": loss_mask}

    def sharded_batch_at(self, step: int, sharding_tree) -> Dict[str, jax.Array]:
        """Generate each shard locally under the given NamedShardings."""
        host = self.batch_at(step)

        def place(x, s):
            def cb(index):
                return np.asarray(x)[index]
            return jax.make_array_from_callback(x.shape, s, cb)

        return {k: place(v, sharding_tree[k]) for k, v in host.items()}


def make_batch(cfg, cell, step: int = 0, seed: int = 0) -> Dict[str, jax.Array]:
    """Convenience: a full batch for an (arch config, shape cell) pair,
    including modality-stub inputs."""
    ds = SyntheticLM(cfg.vocab_size, cell.seq_len, cell.global_batch, seed)
    batch = ds.batch_at(step)
    if cfg.encdec is not None:
        key = jax.random.fold_in(jax.random.PRNGKey(seed + 7), step)
        batch["frames"] = jax.random.normal(
            key, (cell.global_batch, cfg.encdec.encoder_seq_len, cfg.d_model),
            jnp.bfloat16)
    if cfg.pos_type == "mrope":
        S = cell.seq_len + 1
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, None],
            (3, cell.global_batch, S))
    return batch
