"""Sharding plans: logical-axis → mesh-axis assignment for every array.

A `ShardingPlan` is the *compile target of the intent layer*: the intent
compiler (repro.core.compiler) produces/acts on plans, and the launchers
turn plans into concrete `PartitionSpec` trees for params, optimizer state,
caches and batches.

Baseline layout (paper-faithful conservative default):
  * batch           -> ("pod", "data") as available  (DP)
  * params          -> FSDP over "data" on one large dim + TP over "model"
  * attention heads -> "model" (XLA pads non-divisible head counts)
  * d_ff            -> "model"
  * experts         -> "model" (EP)
  * vocab           -> "model"
  * decode KV seq   -> "data" only when batch==1 (long-context cells)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Logical-axis -> mesh-axis assignment for a whole deployment, plus
    the intent layer's placement restrictions.

    Immutable; derive variants with `with_`. Two halves:

      * parallelism layout (``batch_axes`` .. ``shard_vocab``): how arrays
        shard over the mesh — materialized by `param_specs`/`cache_specs`/
        `plan_to_shardings`;
      * intent restrictions (``device_constraints``,
        ``forbidden_collective_axes``): where the arrays may live and
        which mesh axes their collectives must not cross — checked by the
        validator and by the cluster router (`plan_satisfies`).

    Attributes:
        batch_axes: mesh axes the input batch shards over (DP).
        fsdp_axes: param-storage sharding axes (ZeRO-3 style).
        tp_axis: tensor-parallel mesh axis (None disables TP).
        ep_axis: expert-parallel mesh axis for MoE layers.
        seq_axis: KV-cache sequence sharding (flash-decoding style); a
            mesh axis name, tuple of names, or None.
        sequence_parallel: Megatron-style residual-stream sharding.
        shard_attn_heads: shard attention heads over ``tp_axis``.
        shard_vocab: shard embedding/LM-head vocab over ``tp_axis``.
        device_constraints: ``(("pod", 0), ...)`` — mesh-axis coordinates
            this plan's arrays are confined to (see `restrict_mesh`).
        forbidden_collective_axes: mesh axes that tagged tensors'
            collectives must NOT cross (validated against compiled HLO).
    """

    batch_axes: Tuple[str, ...] = ("data",)
    fsdp_axes: Tuple[str, ...] = ("data",)     # param-storage sharding (ZeRO)
    tp_axis: Optional[str] = "model"           # tensor parallel
    ep_axis: Optional[str] = "model"           # expert parallel
    # KV-cache sequence sharding (flash-decoding / context parallel):
    # a mesh axis name or tuple of names
    seq_axis: Any = None
    # Megatron-style sequence parallelism for the residual stream: the
    # between-layer carry is sharded on (batch, tp) so saved scan residuals
    # shrink tp-fold; GSPMD inserts the AG/RS around attention/MLP.
    sequence_parallel: bool = False
    shard_attn_heads: bool = True
    shard_vocab: bool = True
    # restricted device placement (intent layer): mesh-axis coordinates this
    # plan's arrays are confined to, e.g. (("pod", 0),) pins to pod 0.
    device_constraints: Tuple[Tuple[str, int], ...] = ()
    # collective policy hook (intent layer): axes that tagged tensors'
    # collectives must NOT cross. Enforced/validated by repro.core.validator.
    forbidden_collective_axes: Tuple[str, ...] = ()

    def with_(self, **kw) -> "ShardingPlan":
        """Return a copy with the given fields replaced (the plan itself
        is frozen).

        Raises:
            TypeError: on a field name `ShardingPlan` does not define.
        """
        return dataclasses.replace(self, **kw)

    @property
    def fsdp(self) -> Optional[Tuple[str, ...]]:
        """FSDP axes, normalized so an empty tuple reads as None."""
        return self.fsdp_axes or None

    @property
    def tp(self) -> Optional[str]:
        """Tensor-parallel axis (alias for ``tp_axis``)."""
        return self.tp_axis


def default_plan(multi_pod: bool = False) -> ShardingPlan:
    """The paper-faithful conservative baseline layout.

    Args:
        multi_pod: also spread the batch over the ``pod`` axis (DP across
            pods) — single-pod batch sharding otherwise.

    Returns:
        An unrestricted `ShardingPlan` (no device constraints, no
        forbidden collective axes).
    """
    if multi_pod:
        return ShardingPlan(batch_axes=("pod", "data"), fsdp_axes=("data",))
    return ShardingPlan()


# ---------------------------------------------------------------------------
# param specs
# ---------------------------------------------------------------------------


def _gqa_specs(plan: ShardingPlan) -> dict:
    tp = plan.tp if plan.shard_attn_heads else None
    f = plan.fsdp
    return {
        "wq": P(f, tp), "wk": P(f, tp), "wv": P(f, tp), "wo": P(tp, f),
    }


def _mla_specs(cfg: ModelConfig, plan: ShardingPlan) -> dict:
    tp = plan.tp if plan.shard_attn_heads else None
    f = plan.fsdp
    return {
        "w_dq": P(f, None),
        "q_norm": {"scale": P(None)},
        "w_uq": P(None, tp),
        "w_dkv": P(f, None),
        "kv_norm": {"scale": P(None)},
        "w_uk": P(None, tp),
        "w_uv": P(None, tp),
        "wo": P(tp, f),
    }


def _norm_specs(cfg: ModelConfig) -> dict:
    s = {"scale": P(None)}
    if cfg.norm_type == "layernorm":
        s["bias"] = P(None)
    return s


def _mlp_specs(cfg: ModelConfig, plan: ShardingPlan) -> dict:
    f, tp = plan.fsdp, plan.tp
    s = {"w_up": P(f, tp), "w_down": P(tp, f)}
    if cfg.mlp_act == "silu":
        s["w_gate"] = P(f, tp)
    return s


def _moe_specs(cfg: ModelConfig, plan: ShardingPlan) -> dict:
    ep, f = plan.ep_axis, plan.fsdp
    s = {
        "router": P(f, None),
        "w_up": P(ep, f, None),
        "w_down": P(ep, None, f),
    }
    if cfg.mlp_act == "silu":
        s["w_gate"] = P(ep, f, None)
    if cfg.moe and cfg.moe.num_shared_experts:
        s["shared"] = _mlp_specs(cfg, plan)
    return s


def _ssm_specs(cfg: ModelConfig, plan: ShardingPlan) -> dict:
    f, tp = plan.fsdp, plan.tp
    return {
        "w_z": P(f, tp), "w_x": P(f, tp), "w_B": P(f, None), "w_C": P(f, None),
        "w_dt": P(f, tp),
        "conv_x_w": P(None, tp), "conv_x_b": P(tp),
        "conv_B_w": P(None, None), "conv_B_b": P(None),
        "conv_C_w": P(None, None), "conv_C_b": P(None),
        "dt_bias": P(tp), "A_log": P(tp), "D": P(tp),
        "norm_scale": P(tp),
        "out_proj": P(tp, f),
    }


def _sublayer_specs(cfg: ModelConfig, plan: ShardingPlan, mixer: str, f: str) -> dict:
    s: dict = {"mixer_norm": _norm_specs(cfg)}
    if mixer == "attn":
        s["mixer"] = _gqa_specs(plan)
    elif mixer == "mla":
        s["mixer"] = _mla_specs(cfg, plan)
    else:
        s["mixer"] = _ssm_specs(cfg, plan)
    if f != "none":
        s["ffn_norm"] = _norm_specs(cfg)
        s["ffn"] = _moe_specs(cfg, plan) if f == "moe" else _mlp_specs(cfg, plan)
    return s


def _prepend(spec_tree: PyTree, axis=None) -> PyTree:
    """Add a leading (scan/layer) dim to every PartitionSpec."""
    return jax.tree.map(
        lambda s: P(axis, *s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: ModelConfig, plan: ShardingPlan) -> PyTree:
    """PartitionSpec tree matching `Model.init_params` output structure.

    Args:
        cfg: the model config (architecture decides the tree layout:
            enc-dec, hybrid, MoE, ...).
        plan: the layout to realize.

    Returns:
        A pytree of `PartitionSpec` congruent with the param tree.
    """
    from repro.models.lm import layer_kinds  # avoid cycle

    f, tp = plan.fsdp, plan.tp
    vocab_tp = tp if plan.shard_vocab else None

    if cfg.encdec is not None:
        enc_layer = {
            "attn_norm": _norm_specs(cfg), "attn": _gqa_specs(plan),
            "mlp_norm": _norm_specs(cfg), "mlp": _mlp_specs(cfg, plan),
        }
        dec_layer = {
            "self_norm": _norm_specs(cfg), "self_attn": _gqa_specs(plan),
            "cross_norm": _norm_specs(cfg), "cross_attn": _gqa_specs(plan),
            "mlp_norm": _norm_specs(cfg), "mlp": _mlp_specs(cfg, plan),
        }
        return {
            "embed": P(vocab_tp, f),
            "pos_embed": P(None, None),
            "enc_layers": _prepend(enc_layer),
            "enc_norm": _norm_specs(cfg),
            "dec_layers": _prepend(dec_layer),
            "dec_norm": _norm_specs(cfg),
        }

    kinds = layer_kinds(cfg)
    if cfg.hybrid_period:
        layer = {f"pos{off}": _sublayer_specs(cfg, plan, *kinds[off])
                 for off in range(len(kinds))}
    else:
        layer = _sublayer_specs(cfg, plan, *kinds[0])

    specs = {
        "embed": P(vocab_tp, f),
        "layers": _prepend(layer),
        "final_norm": _norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(f, vocab_tp)
    return specs


def opt_state_specs(pspecs: PyTree) -> PyTree:
    """Adam-state PartitionSpecs: moments shard like the params they
    track; the step counter is replicated.

    Args:
        pspecs: the `param_specs` output for the same model/plan.
    """
    return {
        "m": pspecs,
        "v": pspecs,
        "count": P(),
    }


# ---------------------------------------------------------------------------
# cache + batch specs
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, plan: ShardingPlan, *, batch: int) -> PyTree:
    """PartitionSpec tree matching `Model.init_cache` output structure.

    Decode caches shard the SEQUENCE dim (flash-decoding / context-parallel
    style) rather than the few-KV-head dim: KV-head counts (2..8) don't
    divide the 16-wide model axis, while 32k+ contexts always do. Distributed
    softmax (max/sum all-reduce) is inserted by GSPMD automatically.

    Args:
        cfg: the model config (decides GQA/MLA/SSM cache layouts).
        plan: the layout to realize.
        batch: the KV pool's batch size (``n_slots``); with ``batch == 1``
            the batch dim is left unsharded.

    Returns:
        A pytree of `PartitionSpec` congruent with the cache tree.
    """
    from repro.models.lm import layer_kinds

    b_ax = plan.batch_axes if batch > 1 else None
    seq = plan.seq_axis

    def gqa_cache(seq_ax=seq):
        return {"k": P(None, b_ax, seq_ax, None, None),
                "v": P(None, b_ax, seq_ax, None, None)}

    def mla_cache():
        return {"ckv": P(None, b_ax, seq, None),
                "kpe": P(None, b_ax, seq, None)}

    def ssm_cache():
        return {"conv_x": P(None, b_ax, None, plan.tp),
                "conv_B": P(None, b_ax, None, None),
                "conv_C": P(None, b_ax, None, None),
                "ssm": P(None, b_ax, plan.tp, None, None)}

    if cfg.encdec is not None:
        # cross K/V seq = encoder frames (1500 — not shardable); replicate seq
        return {"self": gqa_cache(), "cross": gqa_cache(seq_ax=None)}

    kinds = layer_kinds(cfg)
    if cfg.hybrid_period:
        out = {}
        for off, (mixer, _) in enumerate(kinds):
            if mixer == "attn":
                out[f"pos{off}"] = gqa_cache()
            elif mixer == "mla":
                out[f"pos{off}"] = mla_cache()
            else:
                out[f"pos{off}"] = ssm_cache()
        return out
    mixer = kinds[0][0]
    return {"attn": gqa_cache, "mla": mla_cache, "ssm": ssm_cache}[mixer]()


# ---------------------------------------------------------------------------
# plan -> concrete shardings (the intent layer's materialization step)
# ---------------------------------------------------------------------------


def prune_spec(spec: "jax.sharding.PartitionSpec",
               axis_names: Tuple[str, ...]) -> "jax.sharding.PartitionSpec":
    """Drop mesh-axis references a mesh does not carry (reduced runs build
    smaller meshes than the full production topology).

    Args:
        spec: the spec to prune (tuple entries are pruned element-wise).
        axis_names: the axes the target mesh actually has.

    Returns:
        A spec referencing only ``axis_names`` (dropped entries become
        None, i.e. replicated).
    """
    parts = []
    for entry in spec:
        if entry is None:
            parts.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in axis_names)
            parts.append(kept if kept else None)
        else:
            parts.append(entry if entry in axis_names else None)
    return P(*parts)


def restrict_mesh(mesh: "jax.sharding.Mesh",
                  device_constraints: Tuple[Tuple[str, int], ...]
                  ) -> "jax.sharding.Mesh":
    """Slice a mesh down to the coordinates a plan is confined to.

    Logical coordinates fold onto the available hardware by modulo, so a
    plan pinned to ``("pod", 1)`` still resolves on a single-pod (or
    single-device) reduced mesh.

    Args:
        mesh: the full mesh.
        device_constraints: ``((axis, coord), ...)`` pins; axes the mesh
            does not carry are ignored.

    Returns:
        A mesh restricted to one coordinate per pinned axis (the input
        mesh unchanged when there are no constraints).
    """
    if not device_constraints:
        return mesh
    devs = mesh.devices
    idx: list = [slice(None)] * devs.ndim
    for axis, coord in device_constraints:
        if axis in mesh.axis_names:
            ax = mesh.axis_names.index(axis)
            c = coord % devs.shape[ax]
            idx[ax] = slice(c, c + 1)
    return jax.sharding.Mesh(devs[tuple(idx)], mesh.axis_names)


def plan_to_shardings(cfg: ModelConfig, plan: ShardingPlan,
                      mesh: "jax.sharding.Mesh", *, n_slots: int) -> dict:
    """Materialize a ShardingPlan into NamedSharding trees for a serving
    engine's params and KV-cache pool.

    This is the bridge the orchestrator uses: a validated intent compiles to
    a (restricted) plan, and this function turns that plan into the concrete
    device assignment honoring ``device_constraints`` (via `restrict_mesh`).

    Args:
        cfg: the served model's config.
        plan: the plan to materialize.
        mesh: the cluster mesh (restricted per the plan's constraints).
        n_slots: the engine's KV pool batch size.

    Returns:
        ``{"params": NamedSharding tree, "cache": NamedSharding tree}`` in
        the shape `ServingEngine.swap_plan` / `aot_executables` accept.
    """
    sub = restrict_mesh(mesh, plan.device_constraints)
    is_p = lambda x: isinstance(x, P)  # noqa: E731

    def to_sharding(spec: P) -> jax.sharding.NamedSharding:
        return jax.sharding.NamedSharding(sub, prune_spec(spec, sub.axis_names))

    return {
        "params": jax.tree.map(to_sharding, param_specs(cfg, plan),
                               is_leaf=is_p),
        "cache": jax.tree.map(to_sharding,
                              cache_specs(cfg, plan, batch=n_slots),
                              is_leaf=is_p),
    }


def plan_satisfies(plan: ShardingPlan, required: ShardingPlan) -> bool:
    """Does `plan` meet the placement/routing requirements of `required`?

    Used by the cluster router (fail-closed): a labeled request may only be
    served by an engine whose plan satisfies the constraint plan compiled
    from the matching intent.

    * every required forbidden collective axis must either be forbidden by
      `plan` or pinned by a device constraint (a single coordinate on an
      axis means no collective can cross it);
    * every required device pin must be pinned identically by `plan`.

    Args:
        plan: the candidate engine's plan.
        required: the constraint plan compiled from an intent (only its
            restriction fields matter).

    Returns:
        True iff `plan` meets every restriction in `required`.
    """
    pinned = dict(plan.device_constraints)
    for axis in required.forbidden_collective_axes:
        if (axis not in plan.forbidden_collective_axes
                and axis not in pinned):
            return False
    for axis, coord in required.device_constraints:
        if pinned.get(axis) != coord:
            return False
    return True


def merge_restrictions(base: ShardingPlan,
                       *required: ShardingPlan) -> ShardingPlan:
    """Merge the restriction fields of `required` plans into `base`.

    The single source of the merge semantics used everywhere a plan must
    be made to satisfy intent constraints (cluster `apply_policy` swaps,
    autoscaler spawn/rebalance targets): forbidden collective axes union;
    device pins accumulate, and a pin that CONFLICTS (same axis, different
    coordinate — whether with `base` or between two required plans)
    degrades to forbidding that axis with no pin. That keeps the result
    fail-closed: an engine asked to be in two places at once satisfies
    neither pinned constraint and the affected labels are rejected at
    routing time rather than silently mis-placed.

    Args:
        base: the plan whose parallelism layout is kept.
        required: constraint plans (only their restriction fields matter).

    Returns:
        `base` with merged ``device_constraints`` and
        ``forbidden_collective_axes``.
    """
    pins = dict(base.device_constraints)
    axes = set(base.forbidden_collective_axes)
    conflicts: set = set()
    for req in required:
        axes.update(req.forbidden_collective_axes)
        for axis, coord in req.device_constraints:
            if axis in pins and pins[axis] != coord:
                conflicts.add(axis)
            else:
                pins[axis] = coord
    for axis in conflicts:
        pins.pop(axis, None)
        axes.add(axis)
    return base.with_(device_constraints=tuple(sorted(pins.items())),
                      forbidden_collective_axes=tuple(sorted(axes)))


def batch_specs(cfg: ModelConfig, plan: ShardingPlan, cell: ShapeCell) -> dict:
    """Input-batch PartitionSpecs per shape cell kind.

    Args:
        cfg: the model config (adds frames/positions entries as needed).
        plan: the layout to realize.
        cell: the shape cell being launched; ``global_batch == 1`` leaves
            the batch dim unsharded, train cells add a loss mask.

    Returns:
        ``{"tokens": P, ...}`` matching the batch dict the model consumes.
    """
    b_ax = plan.batch_axes if cell.global_batch > 1 else None
    specs = {"tokens": P(b_ax, None)}
    if cell.kind == "train":
        specs["loss_mask"] = P(b_ax, None)
    if cfg.encdec is not None:
        specs["frames"] = P(b_ax, None, None)
    if cfg.pos_type == "mrope":
        specs["positions"] = P(None, b_ax, None)
    return specs
