"""Trace-time activation-sharding context.

Model code is plan-agnostic; launchers enter `activation_sharding(mesh,
plan)` around tracing so strategic `constrain(x, ...)` calls inside the
model pin activations (batch dim on the data axes, expert dim on the EP
axis, ...) without threading mesh/plan through every function signature.

Outside any context, `constrain` is the identity — single-device smoke
tests and kernels are unaffected.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_ACTIVE: list = []


@contextlib.contextmanager
def activation_sharding(mesh: jax.sharding.Mesh, plan: Any):
    _ACTIVE.append((mesh, plan))
    try:
        yield
    finally:
        _ACTIVE.pop()


def current() -> Optional[Tuple[jax.sharding.Mesh, Any]]:
    return _ACTIVE[-1] if _ACTIVE else None


def _resolve(plan: Any, logical: Optional[str]):
    if logical is None:
        return None
    if logical == "batch":
        return plan.batch_axes
    if logical == "tp":
        return plan.tp_axis
    if logical == "ep":
        return plan.ep_axis
    if logical == "seq":
        return plan.seq_axis
    if logical == "sp":   # sequence-parallel residual stream (train)
        return plan.tp_axis if getattr(plan, "sequence_parallel", False) else None
    raise ValueError(f"unknown logical axis {logical!r}")


def constrain(x: jax.Array, *logical_dims: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical dim names, e.g.
    constrain(x, "batch", None, None)."""
    ctx = current()
    if ctx is None:
        return x
    mesh, plan = ctx
    assert len(logical_dims) == x.ndim, (logical_dims, x.shape)
    spec = P(*[_resolve(plan, d) for d in logical_dims])
    # skip constraints that do not divide evenly (XLA pads internally for
    # intermediates, but clean division is required for good layouts)
    names = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, entry in zip(x.shape, spec):
        axes = entry if isinstance(entry, tuple) else (entry,) if entry else ()
        size = 1
        for a in axes:
            size *= names.get(a, 1)
        if size > 1 and dim % size:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
