from repro.sharding.plan import (  # noqa: F401
    ShardingPlan,
    batch_specs,
    cache_specs,
    default_plan,
    merge_restrictions,
    opt_state_specs,
    param_specs,
    plan_satisfies,
    plan_to_shardings,
    prune_spec,
    restrict_mesh,
)
