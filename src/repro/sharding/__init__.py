from repro.sharding.plan import (  # noqa: F401
    ShardingPlan,
    batch_specs,
    cache_specs,
    default_plan,
    opt_state_specs,
    param_specs,
)
