"""Analytical serving-cost estimator: compiled-HLO cost features through a
device roofline.

The trip-count-aware cost model in `repro.core.hlo_cost` already extracts
exactly what a roofline needs from a compiled SPMD module — FLOPs, bytes
accessed, collective wire bytes per device. This module closes the loop
the ROADMAP left open ("the roofline sits unused at serving time"): it
turns those features plus a `DeviceProfile` and a traffic mix into
TTFT / TPOT / throughput / memory estimates the configuration search can
rank candidates by.

Model (every approximation is deliberate and documented):

  * decode step time  = max(flops/peak, bytes/hbm_bw, wire/link_bw)
    — the classic three-ceiling roofline over the POOLED profile;
  * TPOT              = decode step time (each step emits one token per
    occupied slot; a request's tokens arrive one step apart);
  * prefill time      = roofline over (flops_per_token x prompt_len,
    one weight-stream of bytes, one step of wire) — weights-dominated
    short-prompt regime; the attention-quadratic term is ignored (small
    against the matmul term at serving prompt lengths);
  * TTFT under load   = queue amplification ``prefill / (1 - rho)`` with
    utilization ``rho = demand_tok_rate / capacity`` — an M/D/1-shaped
    penalty that makes the estimate demand-sensitive, which is what lets
    the planner trade engine count against latency targets;
  * memory            = param bytes + KV-pool bytes, checked against the
    pooled capacity (this is where an 80 GB A100 and a 48 GB L40s give
    genuinely different answers for the same plan).

Rankings produced by this model are validated against measured step
latencies on the calibrated host profile (tests/test_planner.py) —
ranking, not absolute values, so the contract is hardware-robust.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

from repro.planner.catalog import DeviceProfile


@dataclasses.dataclass(frozen=True)
class CostFeatures:
    """Per-decode-step cost features of one engine configuration, as
    extracted from its COMPILED decode module (per device).

    Attributes:
        flops: FLOPs per decode step.
        bytes: bytes accessed per decode step (HBM traffic).
        wire_bytes: collective wire bytes per device per step.
        n_slots: the engine's decode batch width.
        s_max: the engine's KV sequence capacity.
        param_bytes: resident parameter bytes.
        kv_bytes: resident KV-pool bytes.
        kv_tokens: the engine's KV token capacity (a paged pool's
            admission budget). 0 means "slot-granular, one full extent
            per slot" (``n_slots * s_max``) — the pre-paging default, so
            existing feature tuples keep their meaning.
    """

    flops: float
    bytes: float
    wire_bytes: float
    n_slots: int
    s_max: int
    param_bytes: int
    kv_bytes: int
    kv_tokens: int = 0

    def concurrency(self, prompt_len: float, new_tokens: float) -> int:
        """Decode slots this engine can actually keep occupied under a
        traffic mix: the decode width, capped by how many mean-sized
        requests the KV token budget admits (token-granular memory-fit —
        a paged engine with a small ``kv_tokens`` budget runs a wide
        batch of short requests but throttles on long ones)."""
        cap = self.kv_tokens if self.kv_tokens > 0 \
            else self.n_slots * self.s_max
        per_req = min(max(prompt_len + new_tokens, 1.0), float(self.s_max))
        return max(min(self.n_slots, int(cap / per_req)), 1)

    @property
    def flops_per_token(self) -> float:
        """FLOPs attributable to one generated token (a decode step
        advances every occupied slot by one token)."""
        return self.flops / max(self.n_slots, 1)

    @property
    def resident_bytes(self) -> int:
        """Memory footprint of the engine (params + KV pool)."""
        return self.param_bytes + self.kv_bytes


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """The workload shape an estimate is conditioned on.

    Attributes:
        prompt_len: mean prompt length, tokens.
        new_tokens: mean generation length, tokens.
        rate: request arrival rate, requests per second (0.0 == estimate
            the unloaded latencies only).
    """

    prompt_len: float = 64.0
    new_tokens: float = 16.0
    rate: float = 0.0

    @property
    def tok_rate(self) -> float:
        """Demanded decode throughput, tokens/s."""
        return self.rate * self.new_tokens


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """The estimator's output for one (features, profile, mix) triple.

    Attributes:
        step_s: decode step time (the roofline maximum).
        tpot_s: time per output token (== step_s).
        prefill_s: unloaded prefill time for the mix's prompt length.
        ttft_s: prefill under queue amplification at the mix's load
            (``inf`` when demand exceeds capacity).
        throughput_tok_s: peak decode tokens/s at full slot occupancy.
        utilization: demanded / available decode throughput.
        mem_bytes: resident footprint (params + KV pool).
        fits: footprint <= the profile's pooled capacity.
        bottleneck: ``"compute" | "memory" | "network"`` — which roofline
            ceiling binds the decode step.
        breakdown: the three ceiling times, seconds.
    """

    step_s: float
    tpot_s: float
    prefill_s: float
    ttft_s: float
    throughput_tok_s: float
    utilization: float
    mem_bytes: int
    fits: bool
    bottleneck: str
    breakdown: Dict[str, float]

    def meets(self, max_ttft_s: Optional[float],
              max_tpot_s: Optional[float]) -> bool:
        """Does this estimate satisfy a service-level target?  A missing
        (None) target is vacuously met; an infeasible placement
        (``fits=False``) never meets anything."""
        if not self.fits:
            return False
        if max_ttft_s is not None and not self.ttft_s <= max_ttft_s:
            return False
        if max_tpot_s is not None and not self.tpot_s <= max_tpot_s:
            return False
        return True


def roofline_times(flops: float, bytes_: float, wire: float,
                   profile: DeviceProfile) -> Dict[str, float]:
    """The three ceiling times of one kernel invocation on a profile."""
    return {
        "compute_s": flops / profile.total_flops,
        "memory_s": bytes_ / profile.total_hbm_bw,
        "network_s": wire / profile.link_bw if wire else 0.0,
    }


_CEILING_NAME = {"compute_s": "compute", "memory_s": "memory",
                 "network_s": "network"}


def estimate(features: CostFeatures, profile: DeviceProfile,
             mix: TrafficMix = TrafficMix(), *,
             engines: int = 1) -> CostEstimate:
    """Estimate serving behaviour of ``engines`` identical engines with
    ``features`` on ``profile`` under ``mix``.

    Args:
        features: compiled-module cost features (see `features_from_engine`).
        profile: the device (slice) each engine runs on.
        mix: the traffic the estimate is conditioned on; ``mix.rate`` is
            the TOTAL arrival rate shared by all ``engines``.
        engines: how many identical engines split the load.

    Returns:
        The `CostEstimate`; ``ttft_s`` is ``inf`` when the demanded token
        rate meets or exceeds the pool's capacity (an overloaded queue
        has no stationary waiting time).
    """
    if engines < 1:
        raise ValueError(f"engines must be >= 1, got {engines}")
    bd = roofline_times(features.flops, features.bytes,
                        features.wire_bytes, profile)
    step_s = max(bd.values())
    bottleneck = _CEILING_NAME[max(bd, key=bd.get)]

    # prefill: prompt_len tokens of matmul work, one weight stream, one
    # step of collective wire (short-prompt weights-dominated regime)
    pf = roofline_times(features.flops_per_token * mix.prompt_len,
                        features.bytes, features.wire_bytes, profile)
    prefill_s = max(pf.values())

    conc = features.concurrency(mix.prompt_len, mix.new_tokens)
    throughput = conc / step_s * engines
    rho = mix.tok_rate / throughput if throughput > 0 else math.inf
    if rho < 1.0:
        ttft_s = prefill_s / (1.0 - rho)
    else:
        ttft_s = math.inf

    mem = features.resident_bytes
    return CostEstimate(
        step_s=step_s, tpot_s=step_s, prefill_s=prefill_s, ttft_s=ttft_s,
        throughput_tok_s=throughput, utilization=rho, mem_bytes=mem,
        fits=mem <= profile.total_mem_bytes, bottleneck=bottleneck,
        breakdown=bd)


def prefill_interference(est: CostEstimate, mix: TrafficMix, *,
                         engines: int = 1) -> CostEstimate:
    """Inflate a UNIFIED estimate with prefill/decode interference.

    `estimate` prices decode capacity as if prefill were free: on a
    unified engine every arriving prompt actually steals ``prefill_s``
    of decode time, stalling the whole decode batch (continuous batching
    admits at step boundaries). The engine spends a prefill *duty
    fraction* ``d = rate × prefill_s / engines`` of its time not
    decoding, so both served latencies stretch by ``1/(1-d)`` —
    infinitely at ``d >= 1`` (prefill alone saturates the engine).

    Applied by the search ONLY when role-split candidates are in play —
    comparing unified against disaggregated configurations with the
    interference the disaggregation removes priced in on one side only
    would rig the comparison; with no disaggregated candidate the legacy
    numbers are left untouched (bitwise — this function is not called).
    """
    duty = mix.rate * est.prefill_s / max(engines, 1)
    if duty <= 0.0:
        return est
    factor = 1.0 / (1.0 - duty) if duty < 1.0 else math.inf
    return dataclasses.replace(
        est, tpot_s=est.tpot_s * factor, ttft_s=est.ttft_s * factor,
        utilization=max(est.utilization, duty))


def estimate_disagg(prefill_features: CostFeatures,
                    decode_features: CostFeatures,
                    mix: TrafficMix, *,
                    prefill_profile: DeviceProfile,
                    decode_profile: DeviceProfile,
                    prefill_engines: int = 1,
                    decode_engines: int = 1,
                    handoff_s: float = 0.0) -> CostEstimate:
    """Estimate a DISAGGREGATED configuration: ``prefill_engines``
    role=prefill engines own TTFT, ``decode_engines`` role=decode
    engines own TPOT, every request handed off at its first token.

    The split is exactly what the ceilings become independent of each
    other for: the prefill tier is an M/D/c-style queue on whole-prompt
    prefills (``rho_p = rate × prefill_s / n_p``; TTFT =
    ``prefill_s / (1 - rho_p) + handoff_s``, inf at saturation — no
    decode interference, because the tier never decodes past token one),
    and the decode tier prices TPOT exactly as `estimate` does
    (``tpot = step_s``, ``rho_d`` over decode token throughput) with no
    prefill stalls.

    Args:
        prefill_features / decode_features: per-role engine features
            (different specs — e.g. prefill-heavy A100 vs decode L40S —
            are the point).
        mix: total traffic over the whole label (both tiers see it all).
        prefill_profile / decode_profile: the device each tier runs on.
        prefill_engines / decode_engines: tier sizes (>= 1 each — a
            disaggregated config without both tiers is not one).
        handoff_s: per-request first-token handoff pause added to TTFT
            (the measured <50 ms budget; 0 ignores it).

    Returns:
        A `CostEstimate` for the joint config: ``ttft_s``/``prefill_s``
        from the prefill tier, ``tpot_s``/``step_s``/``throughput`` from
        the decode tier, ``utilization`` the max of the two tier loads,
        ``fits`` only when BOTH tiers fit their profiles, ``mem_bytes``
        the larger single-engine footprint, and ``bottleneck``/
        ``breakdown`` from whichever tier is more loaded.
    """
    if prefill_engines < 1 or decode_engines < 1:
        raise ValueError(
            f"a disaggregated config needs >= 1 engine per role, got "
            f"prefill={prefill_engines}, decode={decode_engines}")
    # ---- prefill tier: whole-prompt service, no decode duty ----
    pf = roofline_times(
        prefill_features.flops_per_token * mix.prompt_len,
        prefill_features.bytes, prefill_features.wire_bytes,
        prefill_profile)
    prefill_s = max(pf.values())
    rho_p = mix.rate * prefill_s / prefill_engines
    if rho_p < 1.0:
        ttft_s = prefill_s / (1.0 - rho_p) + handoff_s
    else:
        ttft_s = math.inf
    # ---- decode tier: pure decode, no prefill stalls ----
    bd = roofline_times(decode_features.flops, decode_features.bytes,
                        decode_features.wire_bytes, decode_profile)
    step_s = max(bd.values())
    conc = decode_features.concurrency(mix.prompt_len, mix.new_tokens)
    throughput = conc / step_s * decode_engines
    rho_d = mix.tok_rate / throughput if throughput > 0 else math.inf
    # ---- joint view ----
    loaded_pf = rho_p >= rho_d
    bneck = (_CEILING_NAME[max(pf, key=pf.get)] if loaded_pf
             else _CEILING_NAME[max(bd, key=bd.get)])
    fits = (prefill_features.resident_bytes
            <= prefill_profile.total_mem_bytes
            and decode_features.resident_bytes
            <= decode_profile.total_mem_bytes)
    return CostEstimate(
        step_s=step_s, tpot_s=step_s, prefill_s=prefill_s, ttft_s=ttft_s,
        throughput_tok_s=throughput, utilization=max(rho_p, rho_d),
        mem_bytes=max(prefill_features.resident_bytes,
                      decode_features.resident_bytes),
        fits=fits, bottleneck=bneck, breakdown=dict(pf if loaded_pf
                                                    else bd))


# ---------------------------------------------------------------------------
# online calibration (observed TTFT/TPOT -> EWMA residual correction)
# ---------------------------------------------------------------------------


class ResidualCalibration:
    """Online EWMA residual correction closing the predicted-vs-measured
    loop on the analytical roofline.

    The roofline is a *shape* model: it ranks configurations correctly
    but its absolute TTFT/TPOT numbers carry a systematic residual on
    any real host (interpreter overhead, cache effects, an optimistic
    datasheet profile). This class learns that residual per workload
    label as an EWMA of observed/predicted ratios and multiplies it back
    into later estimates.

    FAIL-CLOSED COLD START: with zero observations for a label the
    correction factor is exactly 1.0 — `apply` returns the analytical
    estimate unchanged, bit for bit. The calibrated path can therefore
    be wired in unconditionally; it only deviates from the roofline once
    real measurements exist.

    Observations are guarded: non-finite or non-positive predicted or
    measured values are ignored (an overloaded queue predicts
    ``ttft=inf``; a ratio against it is meaningless), and each ratio is
    clipped to ``[1/ratio_cap, ratio_cap]`` so one pathological window
    cannot poison the EWMA.

    Args:
        alpha: EWMA smoothing factor in (0, 1]; the first observation
            seeds the EWMA directly.
        ratio_cap: clip bound for a single observed/predicted ratio.
    """

    def __init__(self, alpha: float = 0.25, ratio_cap: float = 50.0):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if ratio_cap <= 1.0:
            raise ValueError(f"ratio_cap must exceed 1, got {ratio_cap}")
        self.alpha = alpha
        self.ratio_cap = ratio_cap
        self._ttft: Dict[str, float] = {}
        self._tpot: Dict[str, float] = {}
        self._n: Dict[str, int] = {}

    def _fold(self, store: Dict[str, float], label: str,
              predicted: float, measured: float) -> bool:
        if not (math.isfinite(predicted) and predicted > 0.0
                and math.isfinite(measured) and measured > 0.0):
            return False
        ratio = min(max(measured / predicted, 1.0 / self.ratio_cap),
                    self.ratio_cap)
        if label in store:
            store[label] += self.alpha * (ratio - store[label])
        else:
            store[label] = ratio
        return True

    def observe(self, label: str, *, predicted_ttft_s: float,
                predicted_tpot_s: float, measured_ttft_s: float,
                measured_tpot_s: float) -> None:
        """Fold one measurement window into the label's EWMAs. Invalid
        pairs (non-finite / non-positive on either side) are skipped
        per-metric; the observation count rises if either folded."""
        folded = self._fold(self._ttft, label, predicted_ttft_s,
                            measured_ttft_s)
        folded |= self._fold(self._tpot, label, predicted_tpot_s,
                             measured_tpot_s)
        if folded:
            self._n[label] = self._n.get(label, 0) + 1

    def n_observations(self, label: str) -> int:
        """Windows folded for ``label`` (0 == cold: identity factors)."""
        return self._n.get(label, 0)

    def factors(self, label: str) -> Tuple[float, float]:
        """The ``(ttft_factor, tpot_factor)`` multipliers for ``label``;
        exactly ``(1.0, 1.0)`` when nothing was observed."""
        return (self._ttft.get(label, 1.0), self._tpot.get(label, 1.0))

    def apply(self, label: str, est: CostEstimate) -> CostEstimate:
        """The calibrated estimate: latency predictions (``ttft_s``,
        ``tpot_s``) scaled by the learned residual factors. The
        analytical ceilings (``step_s``, ``breakdown``, throughput,
        memory) are left untouched — the correction models what the
        roofline abstracts away, it does not rewrite the roofline.
        With zero observations this returns ``est`` unchanged."""
        f_ttft, f_tpot = self.factors(label)
        if f_ttft == 1.0 and f_tpot == 1.0:
            return est
        return dataclasses.replace(
            est, ttft_s=est.ttft_s * f_ttft, tpot_s=est.tpot_s * f_tpot)

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        """Telemetry snapshot: per-label factors + observation counts."""
        labels = sorted(set(self._ttft) | set(self._tpot) | set(self._n))
        return {label: {"ttft_factor": self._ttft.get(label, 1.0),
                        "tpot_factor": self._tpot.get(label, 1.0),
                        "observations": self._n.get(label, 0)}
                for label in labels}


def calibrated_estimate(features: CostFeatures, profile: DeviceProfile,
                        mix: TrafficMix = TrafficMix(), *,
                        engines: int = 1,
                        calibration: Optional[ResidualCalibration] = None,
                        label: str = "*") -> CostEstimate:
    """`estimate` with an optional residual correction applied. With no
    ``calibration`` (or a cold one) this is EXACTLY the analytical
    estimate — the fail-closed contract tests pin."""
    est = estimate(features, profile, mix, engines=engines)
    if calibration is None:
        return est
    return calibration.apply(label, est)


# ---------------------------------------------------------------------------
# feature extraction (compiled HLO -> CostFeatures)
# ---------------------------------------------------------------------------


def features_from_hlo(hlo_text: str, *,
                      mesh_shape: Sequence[int] = (1, 1, 1),
                      axis_names: Sequence[str] = ("pod", "data", "model"),
                      n_slots: int, s_max: int,
                      param_bytes: int, kv_bytes: int,
                      kv_tokens: int = 0) -> CostFeatures:
    """Build `CostFeatures` from a compiled decode module's text via the
    trip-count-aware `repro.core.hlo_cost` walker (the artifact-level
    source of truth — declared plans are claims, compiled HLO is proof)."""
    from repro.core import hlo_cost

    a = hlo_cost.analyze(hlo_text, tuple(mesh_shape), tuple(axis_names))
    return CostFeatures(
        flops=float(a["flops"]), bytes=float(a["bytes"]),
        wire_bytes=float(a["wire_bytes_per_device"]),
        n_slots=n_slots, s_max=s_max,
        param_bytes=param_bytes, kv_bytes=kv_bytes, kv_tokens=kv_tokens)


def features_from_engine(engine, mesh=None) -> CostFeatures:
    """Extract `CostFeatures` from a live (or probe) `ServingEngine`.

    Uses the engine's compiled decode HLO (`decode_hlo_text` reuses the
    installed AOT executable, so a live engine pays nothing; a fresh
    probe engine pays one compile) and its resident param/KV trees.

    Args:
        engine: the `repro.serving.ServingEngine` to profile.
        mesh: the mesh the module was compiled against (defaults to a
            single-device ``(1, 1, 1)`` pod/data/model mesh, matching
            `ServingCluster`'s default).
    """
    import jax

    def tree_bytes(tree) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))

    mesh_shape: Tuple[int, ...] = (1, 1, 1)
    axis_names: Tuple[str, ...] = ("pod", "data", "model")
    if mesh is not None:
        mesh_shape = tuple(mesh.devices.shape)
        axis_names = tuple(mesh.axis_names)
    return features_from_hlo(
        engine.decode_hlo_text(),
        mesh_shape=mesh_shape, axis_names=axis_names,
        n_slots=engine.n_slots, s_max=engine.s_max,
        param_bytes=tree_bytes(engine.params),
        kv_bytes=tree_bytes(engine.cache),
        kv_tokens=getattr(engine, "kv_token_capacity",
                          engine.n_slots * engine.s_max))
