"""`WorkloadPlanner`: demand forecast -> typed `PlanAction` sequence,
executed through the cluster's ticketed async machinery.

This is the piece that makes the repo *choose* configurations instead of
only executing them: the threshold `ElasticPolicy` reacts to queue depth
and is blind to hardware heterogeneity and latency targets; the planner
runs the `search` over (engine count x plan variant x device profile)
candidates, scored by the compiled-HLO `estimator`, against the
`LoadTracker`'s demand forecast and the intent-compiled service-level
targets (Φ_L) and scale bounds (Φ_S).

Switching discipline (the planner must not flap):

  * DWELL — after executing any action, no further plan changes for
    ``dwell`` planning rounds (floor violations and infeasibility are
    exempt: a mandatory floor is enforced immediately);
  * AMORTIZATION — a switch that only saves cost (no violation fixed)
    must pay for itself: predicted engine-cost saving over ``horizon_s``
    must exceed the estimated switching cost (observed PREPARE times
    from the cluster's own `DowntimeReport` history, plus a migration
    estimate), times a safety ``switch_margin``;
  * TICKET-AWARENESS — capacity whose background PREPARE is already in
    flight (`ServingCluster.pending_spawn_labels`) counts as existing,
    so a slow compile never triggers duplicate spawns.

Execution maps actions onto the existing state machines — nothing new
runs in a blocking window: spawn -> `spawn_engine_async`, reconfigure ->
`reconfigure_async`, retire -> `retire_engine` (migrate mode when peers
can hold the in-flight work), migrate -> `migrate_requests`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.obs import events as obs_events
from repro.planner.catalog import DeviceProfile, calibrate_host_profile
from repro.planner.estimator import (
    CostEstimate,
    CostFeatures,
    ResidualCalibration,
    estimate,
    estimate_disagg,
    features_from_engine,
)
from repro.planner.search import (
    Bounds,
    EngineSpec,
    LabelDemand,
    ScoredCandidate,
    best_candidate,
    demand_from_tracker,
    score_current,
)
from repro.serving.clock import SYSTEM_CLOCK
from repro.serving.cluster import ServingCluster
from repro.sharding.plan import plan_satisfies

SLOTargets = Dict[str, Tuple[Optional[float], Optional[float]]]


@dataclasses.dataclass(frozen=True)
class PlanAction:
    """One typed reconfiguration step emitted by the planner.

    Attributes:
        kind: ``"spawn" | "retire" | "reconfigure" | "migrate"``.
        label: the workload label the action serves.
        engine: target engine name (source engine for ``migrate``;
            empty for a spawn — the planner names spawned engines).
        target: destination engine for ``migrate``.
        spec: the `EngineSpec` to instantiate (spawn / reconfigure).
        profile: the device profile the engine is placed on.
        mode: retirement mode (``"drain"`` / ``"migrate"``).
        reason: human-readable justification (telemetry).
        role: the serving role the action targets (``"unified"`` /
            ``"prefill"`` / ``"decode"`` — disaggregated configurations
            spawn and retire per role-tier).
    """

    kind: str
    label: str
    engine: str = ""
    target: str = ""
    spec: Optional[EngineSpec] = None
    profile: Optional[DeviceProfile] = None
    mode: str = "drain"
    reason: str = ""
    role: str = "unified"


class WorkloadPlanner:
    """Cost-model-driven configuration planner over a `ServingCluster`.

    Args:
        cluster: the cluster to plan for.
        engine_factory: ``factory(spec, label) -> ServingEngine`` building
            a fresh engine shaped by ``spec`` (the planner installs the
            label and the spec's merged plan itself).
        specs: candidate `EngineSpec` variants (plan variants from the
            compiler x slot sizings).
        profiles: the heterogeneous device pool (catalog profiles); the
            first entry is the default assumed for engines the planner
            did not place (see `attach_profile`).
        slo_targets: initial per-label ``(max_ttft_s, max_tpot_s)``
            targets; extended by intent application (`apply_policy`).
        tick_s: duration of one control-loop tick in seconds (converts
            the tracker's per-tick EWMA rates into per-second demand).
        new_tokens: generation-length prior for the forecast.
        min_rate: forecast rates at or below this floor (req/s) count as
            zero demand (see `search.demand_from_tracker`).
        rho_max: utilization ceiling (see `search.best_candidate`).
        dwell: planning rounds to hold still after executing actions.
        dwell_s: optional SECONDS-based dwell measured on the injected
            ``clock`` (None == rounds only): after executing actions, no
            non-mandatory plan change until ``dwell_s`` clock seconds
            have elapsed. With a simulated clock this makes the
            hysteresis a property of the replayed trace, not of how
            fast the host runs it.
        horizon_s: amortization horizon for pure cost-saving switches.
        switch_margin: safety multiplier on the switching cost.
        max_engines_per_label: enumeration cap for unbounded labels.
        calibration: an optional `ResidualCalibration` closing the
            predicted-vs-measured loop: fed by `observe_measurement` /
            `ingest_observations`, applied to every estimate the search
            scores. Cold calibration is the identity — wiring it in
            changes nothing until measurements arrive (fail-closed).
        clock: time source for ``dwell_s`` and round timestamps (default
            the real `SYSTEM_CLOCK`; inject a `FakeClock` to make the
            dwell follow simulated time).
    """

    def __init__(self, cluster: ServingCluster,
                 engine_factory: Callable[[EngineSpec, str], object], *,
                 specs: Sequence[EngineSpec],
                 profiles: Sequence[DeviceProfile],
                 slo_targets: Optional[SLOTargets] = None,
                 tick_s: float = 1.0,
                 new_tokens: float = 16.0,
                 min_rate: float = 0.0,
                 rho_max: float = 0.85,
                 dwell: int = 2,
                 dwell_s: Optional[float] = None,
                 horizon_s: float = 60.0,
                 switch_margin: float = 1.5,
                 max_engines_per_label: int = 4,
                 calibration: Optional[ResidualCalibration] = None,
                 clock=None):
        if not specs:
            raise ValueError("WorkloadPlanner needs at least one EngineSpec")
        if not profiles:
            raise ValueError("WorkloadPlanner needs at least one profile")
        self.cluster = cluster
        self.engine_factory = engine_factory
        self.specs = list(specs)
        self.profiles = list(profiles)
        self.slo_targets: SLOTargets = dict(slo_targets or {})
        self.bounds: Dict[str, Bounds] = {}
        self.tick_s = tick_s
        self.new_tokens = new_tokens
        self.min_rate = min_rate
        self.rho_max = rho_max
        self.dwell = max(0, dwell)
        self.dwell_s = dwell_s
        self.horizon_s = horizon_s
        self.switch_margin = switch_margin
        self.max_engines_per_label = max_engines_per_label
        self.calibration = calibration
        self.clock = clock if clock is not None else SYSTEM_CLOCK
        # clock stamp of the last executed action (dwell_s reference);
        # None until something executes
        self._last_exec_t: Optional[float] = None
        # label -> completed count at the last metrics ingest (so
        # cumulative means are only folded when new completions exist)
        self._last_completed: Dict[str, float] = {}
        # engine name -> the profile it runs on (heterogeneity attachment)
        self._engine_profile: Dict[str, DeviceProfile] = {}
        # engine name -> the spec it was spawned/reconfigured with
        self._engine_spec: Dict[str, EngineSpec] = {}
        self._features: Dict[Tuple, CostFeatures] = {}
        self._since_exec = self.dwell       # first plan() may act at once
        self._seq = 0
        # every (action, result) ever executed, in order (telemetry)
        self.log: List[Tuple[PlanAction, object]] = []

    # ------------------------------------------------------------------
    # intent application (Orchestrator.submit(apply_to=planner))
    # ------------------------------------------------------------------
    def set_slo_target(self, label: str, max_ttft_s: Optional[float],
                       max_tpot_s: Optional[float]) -> None:
        """Pin a service-level target; repeated pins INTERSECT (the
        tighter target wins, mirroring scale-bound merge semantics)."""
        from repro.core.intents import tighten_bound
        old_ttft, old_tpot = self.slo_targets.get(label, (None, None))
        self.slo_targets[label] = (
            tighten_bound(old_ttft, max_ttft_s),
            tighten_bound(old_tpot, max_tpot_s))

    def apply_policy(self, policy, components: Sequence = (), *,
                     async_prepare: bool = False) -> Dict[str, object]:
        """Intent hook: `Orchestrator.submit(text, apply_to=planner)`.

        Installs the compiled policy's service-level targets
        (``policy.slo_targets`` — the Φ_L objective) and per-label scale
        bounds (Φ_S), then delegates route-constraint installation and
        engine reconfiguration to the cluster's `apply_policy`.
        """
        for label, (ttft_s, tpot_s) in getattr(policy, "slo_targets",
                                               {}).items():
            self.set_slo_target(label, ttft_s, tpot_s)
        for label, (lo, hi) in getattr(policy, "scale_bounds", {}).items():
            self.bounds[label] = (lo, hi)
        return self.cluster.apply_policy(policy, components=components,
                                         async_prepare=async_prepare)

    def attach_profile(self, engine: str, profile: DeviceProfile) -> None:
        """Declare which device class ``engine`` runs on (engines the
        planner spawns are attached automatically)."""
        self._engine_profile[engine] = profile

    def attach_calibrated_profiles(self,
                                   names: Optional[Sequence[str]] = None
                                   ) -> DeviceProfile:
        """Attach the MEASURED profile of this host
        (`calibrate_host_profile`) to ``names`` (default: every
        registered engine), so estimates are made against the machine
        the engines actually run on instead of a datasheet. Returns the
        host profile (process-cached — one probe per process)."""
        profile = calibrate_host_profile()
        for name in (names if names is not None
                     else self.cluster.engines()):
            self._engine_profile[name] = profile
        return profile

    # ------------------------------------------------------------------
    # cost features (cached per spec shape)
    # ------------------------------------------------------------------
    def features_for(self, spec: EngineSpec) -> CostFeatures:
        """Compiled-HLO cost features for a spec, cached by its SHAPE
        (n_slots, s_max, parallelism layout). Restriction fields are
        normalized out of the key: pins move arrays, they do not change
        the single-host probe module the features are read from. The
        first call per shape compiles one probe decode module."""
        key = (spec.n_slots, spec.s_max,
               spec.plan.with_(device_constraints=(),
                               forbidden_collective_axes=()))
        if key not in self._features:
            probe = self.engine_factory(spec, "*")
            self._features[key] = features_from_engine(probe,
                                                       self.cluster.mesh)
        return self._features[key]

    # ------------------------------------------------------------------
    # calibration (predicted-vs-measured feedback)
    # ------------------------------------------------------------------
    def _estimate_fn(self, label: str, feats: CostFeatures,
                     profile: DeviceProfile, mix, engines: int
                     ) -> CostEstimate:
        """The search's scoring estimator: analytical roofline, with the
        label's learned residual factors applied when a calibration is
        installed (identity while cold — fail-closed)."""
        est = estimate(feats, profile, mix, engines=engines)
        if self.calibration is not None:
            est = self.calibration.apply(label, est)
        return est

    def _disagg_estimate_fn(self, label: str, pf_feats: CostFeatures,
                            de_feats: CostFeatures,
                            pf_profile: DeviceProfile,
                            de_profile: DeviceProfile, mix,
                            n_prefill: int, n_decode: int) -> CostEstimate:
        """The search's scorer for disaggregated (prefill-tier +
        decode-tier) candidates — same calibration hook as the unified
        estimator so both candidate families see corrected costs."""
        est = estimate_disagg(pf_feats, de_feats, mix,
                              prefill_profile=pf_profile,
                              decode_profile=de_profile,
                              prefill_engines=n_prefill,
                              decode_engines=n_decode)
        if self.calibration is not None:
            est = self.calibration.apply(label, est)
        return est

    @property
    def _disagg_specs(self) -> bool:
        """True when the catalog can express disaggregation (at least
        one prefill-role AND one decode-role spec) — gates the unified
        interference pricing in the hysteresis comparison so legacy
        catalogs score exactly as before."""
        roles = {s.role for s in self.specs}
        return "prefill" in roles and "decode" in roles

    def predicted_for(self, label: str, demand: LabelDemand, *,
                      calibrated: bool = True) -> Optional[CostEstimate]:
        """The planner's prediction for ``label``'s CURRENTLY deployed
        configuration under ``demand`` — the number the calibration loop
        compares against measurements. ``calibrated=False`` gives the
        raw analytical roofline (the baseline the calibrated estimator
        must beat). None when nothing serves the label."""
        spec_prof_n = self.current_config().get(label)
        if spec_prof_n is None or spec_prof_n[2] == 0:
            return None
        spec, profile, count = spec_prof_n
        est = estimate(self.features_for(spec), profile, demand.mix(),
                       engines=count)
        if calibrated and self.calibration is not None:
            est = self.calibration.apply(label, est)
        return est

    def observe_measurement(self, label: str, demand: LabelDemand, *,
                            measured_ttft_s: float,
                            measured_tpot_s: float) -> None:
        """Fold one measured TTFT/TPOT window into the calibration,
        paired with the ANALYTICAL prediction for the label's deployed
        configuration under ``demand`` (the residual is always learned
        against the uncorrected roofline, so repeated folding does not
        compound the correction). No-op without a calibration or when
        nothing serves the label."""
        if self.calibration is None:
            return
        predicted = self.predicted_for(label, demand, calibrated=False)
        if predicted is None:
            return
        self.calibration.observe(
            label, predicted_ttft_s=predicted.ttft_s,
            predicted_tpot_s=predicted.tpot_s,
            measured_ttft_s=measured_ttft_s,
            measured_tpot_s=measured_tpot_s)

    def ingest_observations(self, demand: Mapping[str, LabelDemand]
                            ) -> int:
        """Pull the cluster's cumulative per-label metrics and fold
        every label that COMPLETED NEW REQUESTS since the last ingest
        into the calibration. Returns the number of labels folded.
        (A replay harness with windowed metrics should prefer
        `observe_measurement` — cumulative means lag shifts in load.)"""
        if self.calibration is None:
            return 0
        folded = 0
        for label, m in self.cluster.metrics_by_label().items():
            if label == "*" or label not in demand:
                continue
            done = m.get("completed", 0)
            if done <= self._last_completed.get(label, 0):
                continue
            self._last_completed[label] = done
            self.observe_measurement(
                label, demand[label],
                measured_ttft_s=m.get("ttft_mean_s", 0.0),
                measured_tpot_s=m.get("tpot_mean_s", 0.0))
            folded += 1
        return folded

    # ------------------------------------------------------------------
    # observation
    # ------------------------------------------------------------------
    def forecast(self, tracker) -> Dict[str, LabelDemand]:
        """The demand forecast from a `LoadTracker` (see
        `search.demand_from_tracker`)."""
        return demand_from_tracker(tracker, self.cluster,
                                   tick_s=self.tick_s,
                                   new_tokens=self.new_tokens,
                                   min_rate=self.min_rate)

    def _dedicated(self, label: str) -> List[str]:
        """Non-draining engines dedicated to ``label``."""
        out = []
        for name in self.cluster.engines():
            try:
                eng = self.cluster.engine(name)
            except KeyError:
                continue
            if (eng.labels.get(self.cluster.ROUTE_KEY) == label
                    and name not in self.cluster.draining()):
                out.append(name)
        return out

    def _spec_of(self, name: str) -> EngineSpec:
        if name in self._engine_spec:
            return self._engine_spec[name]
        eng = self.cluster.engine(name)
        return EngineSpec(plan=eng.plan, n_slots=eng.n_slots,
                          s_max=eng.s_max,
                          role=getattr(eng, "role", "unified"))

    def _profile_of(self, name: str) -> DeviceProfile:
        return self._engine_profile.get(name, self.profiles[0])

    def current_config(self) -> Dict[str, Tuple[EngineSpec, DeviceProfile,
                                                int]]:
        """The deployed per-label configuration: (spec, profile, count)
        over dedicated engines, with capacity whose background PREPARE is
        in flight COUNTED AS DEPLOYED (ticket-awareness: a compiling
        spawn must suppress duplicate spawns)."""
        pending = self.cluster.pending_spawn_labels()
        out: Dict[str, Tuple[EngineSpec, DeviceProfile, int]] = {}
        labels = set(pending)
        for name in self.cluster.engines():
            lbl = self.cluster.engine(name).labels.get(
                self.cluster.ROUTE_KEY)
            if lbl:
                labels.add(lbl)
        for label in labels:
            names = self._dedicated(label)
            count = len(names) + pending.get(label, 0)
            spec = self._spec_of(names[0]) if names else self.specs[0]
            profile = self._profile_of(names[0]) if names \
                else self.profiles[0]
            out[label] = (spec, profile, count)
        return out

    def current_role_config(self) -> Dict[str, object]:
        """The deployed configuration in `score_current`'s role-aware
        shape: the legacy ``(spec, profile, count)`` triple for a label
        whose engines are all unified, a role dict
        ``{role: (spec, profile, count)}`` otherwise — with in-flight
        spawn tickets counted per role (`pending_spawn_roles`)."""
        pending = getattr(self.cluster, "pending_spawn_roles",
                          lambda: {})()
        out: Dict[str, object] = {}
        labels = set(pending)
        for name in self.cluster.engines():
            lbl = self.cluster.engine(name).labels.get(
                self.cluster.ROUTE_KEY)
            if lbl:
                labels.add(lbl)
        for label in labels:
            by_role: Dict[str, List[str]] = {}
            for name in self._dedicated(label):
                role = getattr(self.cluster.engine(name), "role",
                               "unified")
                by_role.setdefault(role, []).append(name)
            counts: Dict[str, int] = {
                r: len(names) for r, names in by_role.items()}
            for role, n in pending.get(label, {}).items():
                counts[role] = counts.get(role, 0) + n
            if set(counts) <= {"unified"}:
                names = by_role.get("unified", [])
                spec = self._spec_of(names[0]) if names else self.specs[0]
                profile = self._profile_of(names[0]) if names \
                    else self.profiles[0]
                out[label] = (spec, profile, counts.get("unified", 0))
                continue
            roles: Dict[str, Tuple[EngineSpec, DeviceProfile, int]] = {}
            for role, n in counts.items():
                names = by_role.get(role, [])
                spec = self._spec_of(names[0]) if names else next(
                    (s for s in self.specs if s.role == role),
                    self.specs[0])
                profile = self._profile_of(names[0]) if names \
                    else self.profiles[0]
                roles[role] = (spec, profile, n)
            out[label] = roles
        return out

    # ------------------------------------------------------------------
    # planning
    # ------------------------------------------------------------------
    def propose(self, demand: Mapping[str, LabelDemand],
                bounds: Optional[Mapping[str, Bounds]] = None
                ) -> ScoredCandidate:
        """Run the configuration search for ``demand`` (no hysteresis —
        the raw optimum; `plan` wraps this with the switching
        discipline)."""
        merged_bounds = dict(self.bounds)
        merged_bounds.update(bounds or {})
        route_required = {
            label: self.cluster.required_for(
                {self.cluster.ROUTE_KEY: label})
            for label in set(demand) | set(merged_bounds)}
        return best_candidate(
            demand, self.slo_targets, specs=self.specs,
            profiles=self.profiles, features_fn=self.features_for,
            bounds=merged_bounds, route_required=route_required,
            rho_max=self.rho_max,
            max_engines_per_label=self.max_engines_per_label,
            estimate_fn=self._estimate_fn,
            disagg_estimate_fn=self._disagg_estimate_fn)

    def _switch_cost_s(self, n_events: int) -> float:
        """Estimated cost of executing ``n_events`` reconfigurations:
        the cluster's own observed PREPARE times (mean over history,
        1 s prior when none observed yet) per event."""
        prepares = [r.prepare_s for r in self.cluster.history
                    if r.prepare_s > 0]
        per = (sum(prepares) / len(prepares)) if prepares else 1.0
        return per * n_events

    def plan(self, demand: Mapping[str, LabelDemand],
             bounds: Optional[Mapping[str, Bounds]] = None
             ) -> List[PlanAction]:
        """Turn a demand forecast into the action sequence that moves the
        cluster to the best configuration — or an empty list when
        hysteresis says hold still.

        Pure decision logic: nothing is executed (see `execute`).
        """
        self._since_exec += 1
        merged_bounds = dict(self.bounds)
        merged_bounds.update(bounds or {})
        best = self.propose(demand, merged_bounds)
        current = self.current_role_config()
        cur_score = score_current(
            current, demand, self.slo_targets,
            features_fn=self.features_for, rho_max=self.rho_max,
            estimate_fn=self._estimate_fn,
            disagg_estimate_fn=self._disagg_estimate_fn,
            interference=self._disagg_specs)
        actions = self._diff(best, current, demand, merged_bounds)
        if not actions:
            self._emit_decision(demand, best, cur_score, [], "no-op")
            return []

        mandatory = best.violations < cur_score.violations \
            or any("floor" in a.reason or "infeasible" in a.reason
                   or "constraint" in a.reason for a in actions)
        if not mandatory:
            if self._since_exec <= self.dwell:
                # dwell: recently acted
                self._emit_decision(demand, best, cur_score, actions,
                                    "dwell-rounds")
                return []
            if (self.dwell_s is not None and self._last_exec_t is not None
                    and self.clock.time() - self._last_exec_t
                    < self.dwell_s):
                # dwell: clock says too soon
                self._emit_decision(demand, best, cur_score, actions,
                                    "dwell-clock")
                return []
            # pure cost-saving switch must amortize its switching cost
            saving = (cur_score.cost - best.cost) * self.horizon_s
            if saving <= self._switch_cost_s(len(actions)) \
                    * self.switch_margin:
                self._emit_decision(demand, best, cur_score, actions,
                                    "not-amortized")
                return []
        self._emit_decision(demand, best, cur_score, actions, "")
        return actions

    def _emit_decision(self, demand: Mapping[str, LabelDemand],
                       best: ScoredCandidate, cur_score: ScoredCandidate,
                       actions: Sequence[PlanAction], held: str) -> None:
        """Flight-recorder hook: one ``planner.decision`` record per
        planning round — the winning candidate's scores vs the current
        configuration's, the learned calibration residuals, and either
        the chosen actions or the hysteresis reason they were held."""
        rec = obs_events.RECORDER
        if rec is None:
            return
        residuals = {}
        if self.calibration is not None:
            residuals = {label: list(self.calibration.factors(label))
                         for label in sorted(demand)}
        rec.emit(
            "planner.decision",
            demand={lb: d.rate for lb, d in sorted(demand.items())},
            best_score=[best.violations, best.cost, best.headroom],
            best_config={lb: [a.count, a.profile.name]
                         for lb, a in sorted(best.config.items())},
            current_score=[cur_score.violations, cur_score.cost,
                           cur_score.headroom],
            residuals=residuals,
            infeasible=list(best.infeasible),
            held=held,
            actions=[{"kind": a.kind, "label": a.label, "engine": a.engine,
                      "mode": a.mode, "reason": a.reason}
                     for a in actions])

    def _diff(self, best: ScoredCandidate,
              current: Mapping[str, object],
              demand: Mapping[str, LabelDemand],
              bounds: Optional[Mapping[str, Bounds]] = None
              ) -> List[PlanAction]:
        """Per-(label, role) diff between the winning candidate and the
        deployed configuration. ``current`` values are either the legacy
        unified triple or a role dict (see `current_role_config`); a
        unified -> disaggregated transition therefore diffs as: spawn
        the prefill tier, spawn the decode tier, retire the unified
        engines — with spawns emitted BEFORE retires so new capacity is
        in flight before old capacity starts draining."""
        bounds = dict(self.bounds if bounds is None else bounds)
        spawns: List[PlanAction] = []
        others: List[PlanAction] = []
        pending = self.cluster.pending_spawn_labels()
        labels = sorted(set(best.config) | set(current))
        for label in labels:
            want = best.config.get(label)
            want_roles = want.by_role() if want is not None else {}
            cur_value = current.get(label)
            if cur_value is None:
                cur_roles: Dict[str, Tuple] = {}
            elif isinstance(cur_value, Mapping):
                cur_roles = {r: tuple(v) for r, v in cur_value.items()}
            else:
                cur_roles = {"unified": tuple(cur_value)}
            live_by_role: Dict[str, List[str]] = {}
            live_all = self._dedicated(label)
            for name in live_all:
                r = getattr(self.cluster.engine(name), "role", "unified")
                live_by_role.setdefault(r, []).append(name)
            cur_total = sum(v[2] for v in cur_roles.values())
            # counts include pending spawns; only live engines can be
            # retired or reconfigured
            for role in sorted(set(want_roles) | set(cur_roles)):
                wa = want_roles.get(role)
                want_n = wa.count if wa is not None else 0
                cur_n = cur_roles[role][2] if role in cur_roles else 0
                live = live_by_role.get(role, [])
                if want_n > cur_n:
                    lo, _ = bounds.get(label, (0, None))
                    for _ in range(want_n - cur_n):
                        why = (f"below floor: {cur_total} < min {lo}"
                               if cur_total < lo else
                               f"demand {demand.get(label, LabelDemand(0.0)).rate:.2f} req/s "
                               f"needs {want_n} x {wa.profile.name}"
                               + ("" if role == "unified"
                                  else f" ({role} tier)"))
                        spawns.append(PlanAction(
                            "spawn", label, spec=wa.spec,
                            profile=wa.profile, reason=why, role=role))
                elif want_n < cur_n:
                    excess = cur_n - want_n
                    # retire live engines only (pending tickets expire
                    # into capacity the next round re-evaluates)
                    for name in self._retire_order(live)[:excess]:
                        mode = "migrate" \
                            if self._can_migrate(name, live_all) \
                            else "drain"
                        others.append(PlanAction(
                            "retire", label, engine=name, mode=mode,
                            role=role,
                            reason=f"demand needs only {want_n} "
                                   f"{role} engine(s)"))
                elif wa is not None and live \
                        and pending.get(label, 0) == 0:
                    # same count: reconfigure engines whose plan no
                    # longer matches the chosen spec. An engine whose
                    # DEPLOYED plan fails the label's route constraint
                    # is unroutable (fail-closed) — that reconfigure is
                    # mandatory, not a cost optimization.
                    required = self.cluster.required_for(
                        {self.cluster.ROUTE_KEY: label})
                    for name in live:
                        deployed = self.cluster.engine(name).plan
                        if self._spec_of(name).plan == wa.spec.plan \
                                and (required is None
                                     or plan_satisfies(deployed,
                                                       required)):
                            continue
                        stale = required is not None \
                            and not plan_satisfies(deployed, required)
                        others.append(PlanAction(
                            "reconfigure", label, engine=name,
                            spec=wa.spec, profile=wa.profile, role=role,
                            reason="route constraint no longer satisfied"
                                   if stale else "plan variant changed"))
        actions = spawns + others
        for label in best.infeasible:
            actions.append(PlanAction(
                "hold", label,
                reason="infeasible: no spec satisfies the route "
                       "constraint (fail-closed)"))
        return actions

    def _retire_order(self, names: List[str]) -> List[str]:
        """Retire the least-loaded engines first (cheapest to move)."""
        return sorted(names, key=lambda n: self.cluster.engine(n).load)

    def _can_migrate(self, name: str, peers: List[str]) -> bool:
        """Can ``name``'s in-flight work fit its peers' free slots?  If
        yes, a migrate-mode retirement reaps immediately instead of
        waiting out the longest decode."""
        eng = self.cluster.engine(name)
        resident = sum(r is not None for r in eng.slot_req)
        if resident == 0 and not eng.queue:
            return False               # drain is already instant
        free = sum(self.cluster.engine(p).free_slots
                   for p in peers if p != name
                   and not self.cluster.engine(p).paused)
        return free >= resident

    # ------------------------------------------------------------------
    # execution (through the ticketed async machinery)
    # ------------------------------------------------------------------
    def _spawn_name(self, label: str) -> str:
        taken = set(self.cluster.engines()) \
            | set(self.cluster.pending_spawns())
        name = f"{label}-pl{self._seq}"
        while name in taken:
            self._seq += 1
            name = f"{label}-pl{self._seq}"
        self._seq += 1
        return name

    def execute(self, actions: Sequence[PlanAction], *,
                async_spawn: bool = True) -> List[Tuple[PlanAction, object]]:
        """Execute a `plan` through the cluster's existing machinery.

        spawn -> `spawn_engine_async` (sync `spawn_engine` when
        ``async_spawn=False``), reconfigure -> `reconfigure_async`,
        retire -> `retire_engine`, migrate -> `migrate_requests`;
        ``"hold"`` actions (fail-closed infeasibility surfacing) execute
        nothing.

        Returns:
            ``[(action, result), ...]`` where result is a
            `PrepareTicket` for async spawns/reconfigures, a
            `DowntimeReport` for sync events, or ``None`` for holds.
            Also appended to ``self.log``.
        """
        out: List[Tuple[PlanAction, object]] = []
        for a in actions:
            if a.kind == "spawn":
                engine = self.engine_factory(a.spec, a.label)
                name = self._spawn_name(a.label)
                kw = dict(
                    plan=a.spec.plan,
                    labels={self.cluster.ROUTE_KEY: a.label},
                    # decode-role engines never prefill a prompt — no
                    # point AOT-compiling prefill lengths for them
                    prefill_lengths=(
                        () if a.spec.role == "decode"
                        else self.cluster.label_prompt_lengths(a.label)))
                if a.spec.role != "unified":
                    kw["role"] = a.spec.role
                if async_spawn:
                    res = self.cluster.spawn_engine_async(name, engine,
                                                          **kw)
                else:
                    res = self.cluster.spawn_engine(name, engine, **kw)
                self._engine_spec[name] = a.spec
                if a.profile is not None:
                    self._engine_profile[name] = a.profile
            elif a.kind == "retire":
                res = self.cluster.retire_engine(a.engine, mode=a.mode)
                self._engine_spec.pop(a.engine, None)
                self._engine_profile.pop(a.engine, None)
            elif a.kind == "reconfigure":
                res = self.cluster.reconfigure_async(a.engine, a.spec.plan)
                self._engine_spec[a.engine] = a.spec
                if a.profile is not None:
                    self._engine_profile[a.engine] = a.profile
            elif a.kind == "migrate":
                res = self.cluster.migrate_requests(a.engine, a.target)
            elif a.kind == "hold":
                res = None
            else:
                raise ValueError(f"unknown PlanAction kind {a.kind!r}")
            out.append((a, res))
            self.log.append((a, res))
            rec = obs_events.RECORDER
            if rec is not None:
                rec.emit("planner.execute", engine=a.engine, label=a.label,
                         action=a.kind, mode=a.mode, reason=a.reason,
                         role=a.role)
        if any(a.kind != "hold" for a in actions):
            self._since_exec = 0
            self._last_exec_t = self.clock.time()
        return out

    def step(self, tracker, *, async_spawn: bool = True
             ) -> List[Tuple[PlanAction, object]]:
        """One standalone planning round: forecast -> plan -> execute.
        (The `Autoscaler`'s planner mode drives the same three calls from
        its tick loop so events/trajectory are recorded uniformly.)"""
        return self.execute(self.plan(self.forecast(tracker)),
                            async_spawn=async_spawn)

    def mandatory_fix(self, label: str, reason: str = "") -> None:
        """Watchtower hook: a fired alert (SLO burn, estimator drift,
        starvation) overrides hold-still hysteresis so the NEXT planning
        round may act immediately — the dwell-round and dwell-clock
        gates are cleared. The plan itself is unchanged: if the search
        already considers the current configuration best, nothing
        executes (an alert is evidence the envelope broke, not an order
        to thrash)."""
        self._since_exec = max(self._since_exec, self.dwell + 1)
        self._last_exec_t = None
        rec = obs_events.RECORDER
        if rec is not None:
            rec.emit("planner.mandatory_fix", label=label, reason=reason)
