"""Workload-aware configuration planner: cost-model-driven selection of
the optimal serving configuration on heterogeneous device pools.

    catalog    DeviceProfile roofline descriptions (A100 / L40S /
               host-calibrated) attachable per engine or mesh slice;
    estimator  compiled-HLO cost features through the device roofline ->
               TTFT / TPOT / throughput / memory estimates;
    search     candidate enumeration (count x plan variant x profile),
               fail-closed pruning, demand-forecast scoring;
    planner    WorkloadPlanner: typed PlanAction sequences with dwell +
               switching-cost hysteresis, executed through the cluster's
               ticketed async machinery.

See docs/planner.md for the cost model and a worked intent -> plan
example.
"""
from repro.planner.catalog import (  # noqa: F401
    A100,
    DEVICE_CATALOG,
    L40S,
    DeviceProfile,
    calibrate_host_profile,
    get_profile,
    register_profile,
)
from repro.planner.estimator import (  # noqa: F401
    CostEstimate,
    CostFeatures,
    ResidualCalibration,
    TrafficMix,
    calibrated_estimate,
    estimate,
    estimate_disagg,
    features_from_engine,
    features_from_hlo,
    prefill_interference,
)
from repro.planner.search import (  # noqa: F401
    Assignment,
    EngineSpec,
    LabelAssignment,
    LabelDemand,
    ScoredCandidate,
    best_candidate,
    demand_from_tracker,
    eligible_specs,
    score_current,
)
from repro.planner.planner import (  # noqa: F401
    PlanAction,
    WorkloadPlanner,
)
