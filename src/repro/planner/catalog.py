"""Heterogeneous device catalog: the roofline parameters of the pools a
serving configuration can be placed on.

The paper's testbed mixes A100s and L40s-class accelerators; choosing "the
optimal pipeline configuration" requires knowing how the SAME compiled
module costs differently on each. A `DeviceProfile` is the minimal
roofline description of one device class — peak FLOP/s, HBM bandwidth,
memory capacity, interconnect bandwidth — attachable per engine or mesh
slice (`pool(n)` scales a profile to an n-device slice under the ideal-
scaling approximation the estimator documents).

Three profile sources:

  * shipped datasheet profiles (`A100`, `L40S`) — dense-BF16 peak, HBM
    stream bandwidth, per-device capacity, per-device interconnect;
  * `calibrate_host_profile()` — a measured profile of THIS host, from a
    tiny probe matmul (FLOP/s) and a probe elementwise stream (bytes/s),
    so estimator rankings can be validated against wall-clock latencies
    on whatever machine the tests run on;
  * `scaled()` variants — same roofline SHAPE, scaled magnitudes, so
    benchmarks can make a tiny test model "heavy" relative to the device
    without distorting the A100:L40s ratios that drive configuration
    choices.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Roofline description of one device class (per single device).

    Attributes:
        name: catalog key (``"a100"``, ``"l40s"``, ``"host"``, ...).
        peak_flops: dense peak FLOP/s in the serving dtype.
        hbm_bw: HBM/DRAM stream bandwidth, bytes/s.
        mem_bytes: on-device memory capacity, bytes.
        link_bw: per-device interconnect bandwidth, bytes/s — the wire
            collectives cross (NVLink / PCIe / host loopback).
        n_devices: devices in the attached mesh slice (see `pool`).
        cost_rate: relative cost of running one device for one second —
            the search objective's engine-seconds weight (an L40s hour is
            cheaper than an A100 hour).
    """

    name: str
    peak_flops: float
    hbm_bw: float
    mem_bytes: float
    link_bw: float
    n_devices: int = 1
    cost_rate: float = 1.0

    def pool(self, n: int) -> "DeviceProfile":
        """An ``n``-device mesh slice of this device class.

        Ideal-scaling approximation (documented, deliberate): compute and
        HBM bandwidth scale by ``n``; ``link_bw`` stays per-device (the
        wire is the non-scaling resource — that is exactly why the
        estimator routes collective bytes through it separately).

        Raises:
            ValueError: ``n`` < 1.
        """
        if n < 1:
            raise ValueError(f"pool size must be >= 1, got {n}")
        if n == self.n_devices:
            return self
        base = self.per_device()
        return dataclasses.replace(base, n_devices=n)

    def per_device(self) -> "DeviceProfile":
        """This profile normalized back to a single device."""
        if self.n_devices == 1:
            return self
        return dataclasses.replace(self, n_devices=1)

    # pooled totals (what the estimator divides by) -------------------
    @property
    def total_flops(self) -> float:
        """Pooled peak FLOP/s over the slice."""
        return self.peak_flops * self.n_devices

    @property
    def total_hbm_bw(self) -> float:
        """Pooled HBM bandwidth over the slice."""
        return self.hbm_bw * self.n_devices

    @property
    def total_mem_bytes(self) -> float:
        """Pooled memory capacity over the slice."""
        return self.mem_bytes * self.n_devices

    def scaled(self, factor: float) -> "DeviceProfile":
        """Same roofline shape, magnitudes scaled by ``factor`` — for
        benchmarks that must make a tiny CI model saturate a "device"
        without distorting inter-profile ratios. Capacity and cost are
        NOT scaled (they are not rates).

        Raises:
            ValueError: ``factor`` is not positive.
        """
        if factor <= 0:
            raise ValueError(f"scale factor must be positive, got {factor}")
        return dataclasses.replace(
            self, name=f"{self.name}@{factor:g}",
            peak_flops=self.peak_flops * factor,
            hbm_bw=self.hbm_bw * factor,
            link_bw=self.link_bw * factor)


# ---------------------------------------------------------------------------
# shipped datasheet profiles (dense BF16, per device)
# ---------------------------------------------------------------------------

A100 = DeviceProfile(
    name="a100",
    peak_flops=312e12,       # dense BF16
    hbm_bw=2.039e12,         # HBM2e, 80 GB SXM
    mem_bytes=80e9,
    link_bw=600e9,           # NVLink 3
    cost_rate=1.0,
)

L40S = DeviceProfile(
    name="l40s",
    peak_flops=181e12,       # dense BF16 (no sparsity)
    hbm_bw=0.864e12,         # GDDR6
    mem_bytes=48e9,
    link_bw=64e9,            # PCIe Gen4 x16
    cost_rate=0.45,
)

DEVICE_CATALOG: Dict[str, DeviceProfile] = {p.name: p for p in (A100, L40S)}


def get_profile(name: str) -> DeviceProfile:
    """Look up a catalog profile by name.

    Raises:
        KeyError: unknown profile name (lists the known ones).
    """
    try:
        return DEVICE_CATALOG[name]
    except KeyError:
        raise KeyError(f"unknown device profile {name!r} "
                       f"(catalog: {sorted(DEVICE_CATALOG)})") from None


def register_profile(profile: DeviceProfile) -> None:
    """Add/replace a catalog entry (deployments register their own
    measured fleets)."""
    DEVICE_CATALOG[profile.name] = profile


# ---------------------------------------------------------------------------
# host calibration (measured profile of THIS machine)
# ---------------------------------------------------------------------------

_HOST_CACHE: Optional[DeviceProfile] = None


def calibrate_host_profile(*, probe_dim: int = 384,
                           stream_mib: int = 32,
                           repeats: int = 5,
                           force: bool = False) -> DeviceProfile:
    """Measure a `DeviceProfile` for the local default device.

    Two probes, each timed over the median of ``repeats`` runs after a
    warm-up call (compile time never pollutes the measurement):

      * FLOP/s: a ``(d, d) x (d, d)`` matmul — ``2 d^3`` FLOPs;
      * bytes/s: an elementwise ``x + 1`` over a ``stream_mib`` MiB
        array — reads + writes the buffer once each.

    ``link_bw`` is set to the measured stream bandwidth (a single-host
    "interconnect" is memory), and ``mem_bytes`` comes from the device's
    memory stats when the backend reports them (8 GiB fallback).

    The result is cached for the process (``force=True`` re-measures).
    """
    global _HOST_CACHE
    if _HOST_CACHE is not None and not force:
        return _HOST_CACHE
    import jax
    import jax.numpy as jnp

    dev = jax.devices()[0]
    key = jax.random.PRNGKey(0)

    a = jax.random.normal(key, (probe_dim, probe_dim), jnp.float32)
    matmul = jax.jit(lambda x: x @ x)
    jax.block_until_ready(matmul(a))            # compile outside the clock
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(matmul(a))
        times.append(time.perf_counter() - t0)
    flops = 2.0 * probe_dim**3 / max(_median(times), 1e-9)

    n = (stream_mib << 20) // 4
    x = jnp.zeros((n,), jnp.float32)
    bump = jax.jit(lambda v: v + 1.0)
    jax.block_until_ready(bump(x))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(bump(x))
        times.append(time.perf_counter() - t0)
    bw = 2.0 * n * 4 / max(_median(times), 1e-9)

    mem = 8 << 30
    stats = getattr(dev, "memory_stats", lambda: None)()
    if stats and stats.get("bytes_limit"):
        mem = int(stats["bytes_limit"])

    _HOST_CACHE = DeviceProfile(
        name="host", peak_flops=flops, hbm_bw=bw,
        mem_bytes=float(mem), link_bw=bw)
    return _HOST_CACHE


def _median(xs) -> float:
    s = sorted(xs)
    return s[len(s) // 2]
