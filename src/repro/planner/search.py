"""Configuration search: enumerate candidate cluster configurations and
score them against a demand forecast.

A candidate assigns, per workload label: an `EngineSpec` (plan variant +
slot/pool sizing), a `DeviceProfile` (which hardware class serves it —
this is where heterogeneity enters), and an engine count. The search

  * prunes FAIL-CLOSED: a spec whose plan cannot be made to satisfy the
    label's route constraint (same `merge_restrictions` semantics the
    autoscaler uses for spawns) is never a candidate, and engine counts
    outside the intent-pinned scale bounds are never enumerated;
  * scores each surviving candidate with the `estimator`: service-level
    violations first (TTFT/TPOT targets missed, memory that does not fit,
    utilization above the headroom ceiling), then engine cost
    (count x devices x the profile's ``cost_rate`` — the engine-seconds
    objective), then spare headroom as the tie-break;
  * exploits that the score is separable per label (no cross-label
    resource coupling in the current model), so the joint optimum is the
    per-label optimum — documented, and revisited when a shared device
    pool cap lands.

The demand forecast comes from the `LoadTracker`'s per-label EWMAs
(`demand_from_tracker`): observed arrival rates and live prompt lengths,
converted to requests/second by the control loop's tick duration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.planner.catalog import DeviceProfile
from repro.planner.estimator import (
    CostEstimate,
    CostFeatures,
    TrafficMix,
    estimate,
    estimate_disagg,
    prefill_interference,
)
from repro.sharding.plan import (
    ShardingPlan,
    merge_restrictions,
    plan_satisfies,
)

Bounds = Tuple[int, Optional[int]]

# (label, features, profile, mix, engines) -> CostEstimate. The label
# argument is what lets a `ResidualCalibration`-backed estimator apply
# per-label residual factors inside the search.
EstimateFn = Callable[[str, CostFeatures, DeviceProfile, TrafficMix, int],
                      CostEstimate]

# (label, prefill_features, decode_features, prefill_profile,
#  decode_profile, mix, prefill_engines, decode_engines) -> CostEstimate,
# the disaggregated-configuration scorer (see `estimate_disagg`).
DisaggEstimateFn = Callable[
    [str, CostFeatures, CostFeatures, DeviceProfile, DeviceProfile,
     TrafficMix, int, int], CostEstimate]


def _analytical(label: str, feats: CostFeatures, profile: DeviceProfile,
                mix: TrafficMix, engines: int) -> CostEstimate:
    """The default `EstimateFn`: the pure roofline, label-blind."""
    return estimate(feats, profile, mix, engines=engines)


def _analytical_disagg(label: str, pf_feats: CostFeatures,
                       de_feats: CostFeatures, pf_profile: DeviceProfile,
                       de_profile: DeviceProfile, mix: TrafficMix,
                       n_prefill: int, n_decode: int) -> CostEstimate:
    """The default `DisaggEstimateFn`: `estimate_disagg`, label-blind,
    no handoff surcharge (the measured pause is < 50 ms — negligible
    against second-scale TTFT targets; a calibrated planner can price
    it via its own closure)."""
    return estimate_disagg(pf_feats, de_feats, mix,
                           prefill_profile=pf_profile,
                           decode_profile=de_profile,
                           prefill_engines=n_prefill,
                           decode_engines=n_decode)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One engine shape a candidate may instantiate: a plan variant plus
    the KV-pool sizing and its serving role. Hashable — the planner
    caches compiled-HLO cost features per spec.

    ``role``: ``"unified"`` specs are complete configurations on their
    own; ``"prefill"``/``"decode"`` specs only ever appear PAIRED in a
    disaggregated candidate (one tier each) — the search never proposes
    a bare prefill or decode tier.
    """

    plan: ShardingPlan
    n_slots: int = 4
    s_max: int = 128
    role: str = "unified"

    def __post_init__(self):
        if self.role not in ("unified", "prefill", "decode"):
            raise ValueError(f"unknown spec role {self.role!r}")


@dataclasses.dataclass(frozen=True)
class LabelDemand:
    """Forecast demand for one label.

    Attributes:
        rate: arrivals per second (the steady inflow).
        prompt_len: mean prompt length, tokens.
        new_tokens: mean generation length, tokens.
        queued: backlog — requests already waiting (queued or resident)
            that the rate forecast cannot see. During a flash crowd the
            EWMA rate converges to the arrival rate only after the burst;
            the backlog is what must ALSO drain through the capacity the
            planner sizes, or it drains at SLO-violating latency.
        drain_s: the horizon over which the planner wants the backlog
            gone; the backlog enters the effective rate as
            ``queued / drain_s`` extra arrivals per second.
    """

    rate: float
    prompt_len: float = 64.0
    new_tokens: float = 16.0
    queued: float = 0.0
    drain_s: float = 10.0

    @property
    def effective_rate(self) -> float:
        """Arrivals/s the capacity must actually absorb: the steady rate
        plus the backlog amortized over the drain horizon."""
        return self.rate + self.queued / max(self.drain_s, 1e-9)

    def mix(self) -> TrafficMix:
        return TrafficMix(prompt_len=self.prompt_len,
                          new_tokens=self.new_tokens,
                          rate=self.effective_rate)


@dataclasses.dataclass(frozen=True)
class Assignment:
    """One (label, role)-slice of a candidate configuration."""

    spec: EngineSpec
    profile: DeviceProfile
    count: int


@dataclasses.dataclass(frozen=True)
class LabelAssignment:
    """One label's full slice of a candidate configuration: one
    `Assignment` for a unified label, one per role (prefill + decode)
    for a disaggregated one.

    Compatibility surface: ``count`` (total engines), ``spec`` /
    ``profile`` (the first — only, when unified — assignment's), so
    every pre-disaggregation consumer of ``config[label].count`` /
    ``.profile.name`` keeps reading the numbers it always did.
    """

    assignments: Tuple[Assignment, ...]

    @property
    def count(self) -> int:
        return sum(a.count for a in self.assignments)

    @property
    def spec(self) -> EngineSpec:
        return self.assignments[0].spec

    @property
    def profile(self) -> DeviceProfile:
        return self.assignments[0].profile

    @property
    def disaggregated(self) -> bool:
        return any(a.spec.role != "unified" for a in self.assignments)

    def by_role(self) -> Dict[str, Assignment]:
        """Role -> assignment (``{"unified": a}`` or
        ``{"prefill": ap, "decode": ad}``)."""
        return {a.spec.role: a for a in self.assignments}


@dataclasses.dataclass
class ScoredCandidate:
    """A fully scored candidate configuration.

    Ordering key (minimize, lexicographic): ``violations`` (graded: SLO
    misses / misfits count 1 each, overload counts 1 + the excess
    utilization — see `_violation`), then ``cost`` (engine-seconds
    weight), then ``-headroom`` (prefer spare capacity among equals).
    """

    config: Dict[str, LabelAssignment]
    violations: float
    cost: float
    headroom: float
    per_label: Dict[str, CostEstimate]
    infeasible: List[str]        # labels no candidate could legally serve

    def sort_key(self) -> Tuple[float, float, float]:
        return (self.violations, self.cost, -self.headroom)


def demand_from_tracker(tracker, cluster, *, tick_s: float = 1.0,
                        new_tokens: float = 16.0,
                        default_prompt_len: float = 64.0,
                        min_rate: float = 0.0,
                        min_depth: float = 0.5,
                        drain_s: float = 10.0
                        ) -> Dict[str, LabelDemand]:
    """Derive the per-label demand forecast from a `LoadTracker`.

    The tracker's EWMAs are per control-loop tick; ``tick_s`` converts
    them to per-second rates (virtual-time loops pass their virtual tick
    duration). Prompt lengths come from the cluster's recently seen
    per-label lengths; generation length is the caller's prior (the
    runtime does not observe a request's budget until it completes).
    The ``"*"`` unlabeled bucket never owns capacity and is excluded,
    matching the autoscaler's convention.

    The forecast is rate AND backlog: the tracker's queue-depth EWMA
    (`LoadTracker.depth` — queued + resident requests) feeds
    ``LabelDemand.queued``, so during a flash crowd the planner sizes
    for the arrival rate PLUS the backlog draining over ``drain_s``
    seconds, instead of sizing for the steady rate while the queue
    drains at whatever latency the old capacity produces.

    ``min_rate``: rates at or below this floor (per second) forecast as
    ZERO demand — an EWMA decays geometrically and never quite reaches
    0, so without a floor a burst's tail would hold its last engine
    forever (the planner's analogue of `ElasticPolicy.retire_rate`).
    ``min_depth`` is the same floor for the backlog EWMA (requests).
    """
    if tick_s <= 0:
        raise ValueError(f"tick_s must be positive, got {tick_s}")
    depth_fn = getattr(tracker, "depth", None)
    out: Dict[str, LabelDemand] = {}
    for label in tracker.labels():
        if label == "*":
            continue
        lengths = cluster.label_prompt_lengths(label)
        prompt = (sum(lengths) / len(lengths)) if lengths \
            else default_prompt_len
        rate = tracker.rate(label) / tick_s
        if rate <= min_rate:
            rate = 0.0
        queued = float(depth_fn(label)) if depth_fn is not None else 0.0
        if queued <= min_depth:
            queued = 0.0
        out[label] = LabelDemand(rate=rate, prompt_len=prompt,
                                 new_tokens=new_tokens,
                                 queued=queued, drain_s=drain_s)
    return out


def eligible_specs(specs: Sequence[EngineSpec],
                   required: Optional[ShardingPlan]
                   ) -> List[EngineSpec]:
    """Fail-closed pruning: keep only specs whose plan, merged with the
    label's route constraint, actually satisfies it (a spec whose device
    pins conflict with the constraint degrades to unroutable under
    `merge_restrictions` — it must never be proposed). The surviving
    specs carry the MERGED plan, so a spawned engine is immediately
    routing-eligible."""
    if required is None:
        return list(specs)
    out = []
    for spec in specs:
        merged = merge_restrictions(spec.plan, required)
        if plan_satisfies(merged, required):
            out.append(dataclasses.replace(spec, plan=merged))
    return out


def _count_range(bounds: Bounds, max_engines: int) -> range:
    """Counts to enumerate: ``max_engines`` caps only UNBOUNDED labels —
    an explicit intent-pinned max is honored as stated."""
    lo, hi = bounds
    if hi is None:
        hi = max_engines
    return range(max(lo, 0), max(hi, lo) + 1)


def _violation(est: CostEstimate,
               targets: Tuple[Optional[float], Optional[float]],
               rho_max: float) -> float:
    """Graded violation score for one label's estimate. Zero when the
    SLO targets hold and utilization stays under the headroom ceiling.
    Overload contributes 1 PLUS the (clipped) excess utilization, so
    when every enumerable count violates, the search still prefers the
    configuration that covers the MOST demand — a binary score would
    tie all violators and let the cost term scale capacity DOWN exactly
    when demand spikes past the ceiling."""
    viol = 0.0
    if not est.meets(*targets):
        viol += 1.0
    if est.utilization > rho_max:
        viol += 1.0 + min(est.utilization - rho_max, 9.0)
    return viol


def best_candidate(
    demand: Mapping[str, LabelDemand],
    targets: Mapping[str, Tuple[Optional[float], Optional[float]]],
    *,
    specs: Sequence[EngineSpec],
    profiles: Sequence[DeviceProfile],
    features_fn: Callable[[EngineSpec], CostFeatures],
    bounds: Optional[Mapping[str, Bounds]] = None,
    default_bounds: Bounds = (0, 4),
    route_required: Optional[Mapping[str, ShardingPlan]] = None,
    rho_max: float = 0.85,
    max_engines_per_label: int = 4,
    estimate_fn: Optional[EstimateFn] = None,
    disagg_estimate_fn: Optional[DisaggEstimateFn] = None,
) -> ScoredCandidate:
    """Pick the best configuration for the forecast demand.

    Args:
        demand: per-label `LabelDemand` (the forecast).
        targets: per-label ``(max_ttft_s, max_tpot_s)`` service-level
            targets (missing label / None entry == no target).
        specs: candidate `EngineSpec` plan/sizing variants. When BOTH a
            ``role="prefill"`` and a ``role="decode"`` spec survive a
            label's route pruning, disaggregated candidates (one tier
            each, every prefill×decode pairing over the profile catalog)
            are enumerated alongside the unified ones — and the unified
            ones are then priced WITH prefill/decode interference
            (`prefill_interference`), since that is exactly the cost
            disaggregation removes. With no role-tagged specs (the
            default catalogs) the enumeration and every number are
            unchanged.
        profiles: candidate `DeviceProfile`s (the heterogeneous pool).
        features_fn: spec -> `CostFeatures` (the planner's cached
            compiled-HLO extraction; the search itself never compiles).
        bounds: per-label intent-pinned (min, max) engine counts — a
            disaggregated candidate's TOTAL engine count (both tiers)
            honors them.
        default_bounds: bounds for labels not pinned.
        route_required: per-label route-constraint plans (fail-closed
            spec pruning).
        rho_max: utilization ceiling — demand above it counts as a
            violation even without an explicit SLO, so the search sizes
            capacity to demand like the threshold policy does, but
            model-driven.
        max_engines_per_label: enumeration cap when a label's max bound
            is unbounded.
        estimate_fn: the scoring estimator (default: the analytical
            roofline). A calibrated planner passes a closure applying
            its per-label `ResidualCalibration` factors, so learned
            residuals move the SAME lexicographic objective the
            analytical search uses.
        disagg_estimate_fn: the disaggregated-configuration scorer
            (default: `estimate_disagg`, no handoff surcharge).

    Returns:
        The best `ScoredCandidate`; ``config`` values are
        `LabelAssignment`s (one assignment for a unified label, a
        prefill + decode pair for a disaggregated one). Labels with
        demand but no legally servable spec are listed in
        ``infeasible`` (fail-closed: the planner surfaces them instead
        of proposing a non-compliant engine) and receive no assignment.
    """
    bounds = dict(bounds or {})
    route_required = dict(route_required or {})
    est_fn = estimate_fn or _analytical
    dis_fn = disagg_estimate_fn or _analytical_disagg
    labels = sorted(set(demand) | set(bounds))

    config: Dict[str, LabelAssignment] = {}
    per_label: Dict[str, CostEstimate] = {}
    infeasible: List[str] = []
    violations = 0
    cost = 0.0
    headroom = 0.0

    for label in labels:
        d = demand.get(label, LabelDemand(rate=0.0))
        lo_hi = bounds.get(label, default_bounds)
        cands = eligible_specs(specs, route_required.get(label))
        unified = [s for s in cands if s.role == "unified"]
        prefills = [s for s in cands if s.role == "prefill"]
        decodes = [s for s in cands if s.role == "decode"]
        # disaggregation is only on the table when both tiers survived
        # pruning; only then do unified candidates pay the interference
        # they actually suffer (pricing it in with nothing to compare
        # against would silently shift every legacy number)
        disagg = bool(prefills and decodes)
        if not unified and not disagg:
            if d.effective_rate > 0 or lo_hi[0] > 0:
                infeasible.append(label)
            continue
        ttft_t, tpot_t = targets.get(label, (None, None))
        best: Optional[Tuple[Tuple[float, float, float],
                             LabelAssignment, CostEstimate]] = None
        for spec in unified:
            feats = features_fn(spec)
            for profile in profiles:
                for count in _count_range(lo_hi, max_engines_per_label):
                    if count == 0:
                        # legal only when nothing demands capacity
                        if d.effective_rate > 0:
                            continue
                        a = LabelAssignment(
                            (Assignment(spec, profile, 0),))
                        key = (0.0, 0.0, 0.0)
                        if best is None or key < best[0]:
                            best = (key, a, est_fn(label, feats, profile,
                                                   d.mix(), 1))
                        continue
                    est = est_fn(label, feats, profile, d.mix(), count)
                    if disagg:
                        est = prefill_interference(est, d.mix(),
                                                   engines=count)
                    viol = _violation(est, (ttft_t, tpot_t), rho_max)
                    c = count * profile.cost_rate * profile.n_devices
                    hr = max(0.0, 1.0 - est.utilization)
                    key = (viol, c, -hr)
                    if best is None or key < best[0]:
                        best = (key, LabelAssignment(
                            (Assignment(spec, profile, count),)), est)
        if disagg and d.effective_rate > 0:
            counts = _count_range(lo_hi, max_engines_per_label)
            total_max = max(counts) if len(counts) else 0
            total_min = max(lo_hi[0], 2)   # one engine per tier, minimum
            for sp in prefills:
                pf_feats = features_fn(sp)
                for sd in decodes:
                    de_feats = features_fn(sd)
                    for pp in profiles:
                        for pd in profiles:
                            for n_p in range(1, total_max):
                                for n_d in range(1, total_max - n_p + 1):
                                    if n_p + n_d < total_min:
                                        continue
                                    est = dis_fn(label, pf_feats, de_feats,
                                                 pp, pd, d.mix(), n_p, n_d)
                                    viol = _violation(
                                        est, (ttft_t, tpot_t), rho_max)
                                    c = (n_p * pp.cost_rate * pp.n_devices
                                         + n_d * pd.cost_rate
                                         * pd.n_devices)
                                    hr = max(0.0, 1.0 - est.utilization)
                                    key = (viol, c, -hr)
                                    if best is None or key < best[0]:
                                        best = (key, LabelAssignment((
                                            Assignment(sp, pp, n_p),
                                            Assignment(sd, pd, n_d))), est)
        if best is None:
            infeasible.append(label)
            continue
        key, assignment, est = best
        config[label] = assignment
        per_label[label] = est
        violations += key[0]
        cost += key[1]
        headroom += -key[2]

    return ScoredCandidate(config=config, violations=violations, cost=cost,
                           headroom=headroom, per_label=per_label,
                           infeasible=infeasible)


def score_current(
    current: Mapping[str, object],
    demand: Mapping[str, LabelDemand],
    targets: Mapping[str, Tuple[Optional[float], Optional[float]]],
    *,
    features_fn: Callable[[EngineSpec], CostFeatures],
    rho_max: float = 0.85,
    estimate_fn: Optional[EstimateFn] = None,
    disagg_estimate_fn: Optional[DisaggEstimateFn] = None,
    interference: bool = False,
) -> ScoredCandidate:
    """Score the configuration that is ALREADY deployed, with the same
    objective `best_candidate` uses — the hysteresis comparison's other
    half (pass the same ``estimate_fn`` so both sides see the same
    calibrated costs).

    ``current`` values are either the legacy unified triple
    ``(spec, profile, count)`` or — for a disaggregated deployment — a
    role dict ``{"prefill": (spec, profile, count),
    "decode": (spec, profile, count)}`` (either role may be absent; a
    lone tier is graded like missing capacity since it cannot serve
    alone). Pass ``interference=True`` when the proposal side enumerated
    disaggregated candidates, so unified deployments pay the same
    prefill/decode interference `best_candidate` priced in — the
    hysteresis comparison must not compare an interference-free current
    against an interference-priced proposal.
    """
    est_fn = estimate_fn or _analytical
    dis_fn = disagg_estimate_fn or _analytical_disagg
    config: Dict[str, LabelAssignment] = {}
    per_label: Dict[str, CostEstimate] = {}
    violations = 0.0
    cost = 0.0
    headroom = 0.0
    # labels with demand but NO deployed capacity at all are violations
    # of the deployed config (demand.effective_rate > 0 and nothing
    # serves it); graded like total overload so the comparison scale
    # matches best_candidate's
    for label, d in demand.items():
        if label not in current and d.effective_rate > 0:
            violations += 2.0 + 9.0
    for label, value in current.items():
        d = demand.get(label, LabelDemand(rate=0.0))
        if isinstance(value, Mapping):
            roles = {r: tuple(v) for r, v in value.items()}
            pf = roles.get("prefill")
            de = roles.get("decode")
            config[label] = LabelAssignment(tuple(
                Assignment(s, p, n) for s, p, n in roles.values()))
            if (pf is None or de is None or pf[2] == 0 or de[2] == 0):
                # a lone tier can't serve: prefill-only never decodes,
                # decode-only never admits — missing capacity
                if d.effective_rate > 0:
                    violations += 2.0 + 9.0
                cost += sum(n * p.cost_rate * p.n_devices
                            for _, p, n in roles.values())
                continue
            est = dis_fn(label, features_fn(pf[0]), features_fn(de[0]),
                         pf[1], de[1], d.mix(), pf[2], de[2])
            per_label[label] = est
            violations += _violation(est, targets.get(label, (None, None)),
                                     rho_max)
            cost += (pf[2] * pf[1].cost_rate * pf[1].n_devices
                     + de[2] * de[1].cost_rate * de[1].n_devices)
            headroom += max(0.0, 1.0 - est.utilization)
            continue
        spec, profile, count = value
        a = LabelAssignment((Assignment(spec, profile, count),))
        config[label] = a
        if count == 0:
            if d.effective_rate > 0:
                violations += 2.0 + 9.0
            continue
        est = est_fn(label, features_fn(spec), profile, d.mix(), count)
        if interference:
            est = prefill_interference(est, d.mix(), engines=count)
        per_label[label] = est
        violations += _violation(est, targets.get(label, (None, None)),
                                 rho_max)
        cost += count * profile.cost_rate * profile.n_devices
        headroom += max(0.0, 1.0 - est.utilization)
    return ScoredCandidate(config=config, violations=violations, cost=cost,
                           headroom=headroom, per_label=per_label,
                           infeasible=[])
