"""Configuration search: enumerate candidate cluster configurations and
score them against a demand forecast.

A candidate assigns, per workload label: an `EngineSpec` (plan variant +
slot/pool sizing), a `DeviceProfile` (which hardware class serves it —
this is where heterogeneity enters), and an engine count. The search

  * prunes FAIL-CLOSED: a spec whose plan cannot be made to satisfy the
    label's route constraint (same `merge_restrictions` semantics the
    autoscaler uses for spawns) is never a candidate, and engine counts
    outside the intent-pinned scale bounds are never enumerated;
  * scores each surviving candidate with the `estimator`: service-level
    violations first (TTFT/TPOT targets missed, memory that does not fit,
    utilization above the headroom ceiling), then engine cost
    (count x devices x the profile's ``cost_rate`` — the engine-seconds
    objective), then spare headroom as the tie-break;
  * exploits that the score is separable per label (no cross-label
    resource coupling in the current model), so the joint optimum is the
    per-label optimum — documented, and revisited when a shared device
    pool cap lands.

The demand forecast comes from the `LoadTracker`'s per-label EWMAs
(`demand_from_tracker`): observed arrival rates and live prompt lengths,
converted to requests/second by the control loop's tick duration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.planner.catalog import DeviceProfile
from repro.planner.estimator import (
    CostEstimate,
    CostFeatures,
    TrafficMix,
    estimate,
)
from repro.sharding.plan import (
    ShardingPlan,
    merge_restrictions,
    plan_satisfies,
)

Bounds = Tuple[int, Optional[int]]

# (label, features, profile, mix, engines) -> CostEstimate. The label
# argument is what lets a `ResidualCalibration`-backed estimator apply
# per-label residual factors inside the search.
EstimateFn = Callable[[str, CostFeatures, DeviceProfile, TrafficMix, int],
                      CostEstimate]


def _analytical(label: str, feats: CostFeatures, profile: DeviceProfile,
                mix: TrafficMix, engines: int) -> CostEstimate:
    """The default `EstimateFn`: the pure roofline, label-blind."""
    return estimate(feats, profile, mix, engines=engines)


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One engine shape a candidate may instantiate: a plan variant plus
    the KV-pool sizing. Hashable — the planner caches compiled-HLO cost
    features per spec."""

    plan: ShardingPlan
    n_slots: int = 4
    s_max: int = 128


@dataclasses.dataclass(frozen=True)
class LabelDemand:
    """Forecast demand for one label.

    Attributes:
        rate: arrivals per second.
        prompt_len: mean prompt length, tokens.
        new_tokens: mean generation length, tokens.
    """

    rate: float
    prompt_len: float = 64.0
    new_tokens: float = 16.0

    def mix(self) -> TrafficMix:
        return TrafficMix(prompt_len=self.prompt_len,
                          new_tokens=self.new_tokens, rate=self.rate)


@dataclasses.dataclass(frozen=True)
class Assignment:
    """One label's slice of a candidate configuration."""

    spec: EngineSpec
    profile: DeviceProfile
    count: int


@dataclasses.dataclass
class ScoredCandidate:
    """A fully scored candidate configuration.

    Ordering key (minimize, lexicographic): ``violations`` (graded: SLO
    misses / misfits count 1 each, overload counts 1 + the excess
    utilization — see `_violation`), then ``cost`` (engine-seconds
    weight), then ``-headroom`` (prefer spare capacity among equals).
    """

    config: Dict[str, Assignment]
    violations: float
    cost: float
    headroom: float
    per_label: Dict[str, CostEstimate]
    infeasible: List[str]        # labels no candidate could legally serve

    def sort_key(self) -> Tuple[float, float, float]:
        return (self.violations, self.cost, -self.headroom)


def demand_from_tracker(tracker, cluster, *, tick_s: float = 1.0,
                        new_tokens: float = 16.0,
                        default_prompt_len: float = 64.0,
                        min_rate: float = 0.0
                        ) -> Dict[str, LabelDemand]:
    """Derive the per-label demand forecast from a `LoadTracker`.

    The tracker's EWMAs are per control-loop tick; ``tick_s`` converts
    them to per-second rates (virtual-time loops pass their virtual tick
    duration). Prompt lengths come from the cluster's recently seen
    per-label lengths; generation length is the caller's prior (the
    runtime does not observe a request's budget until it completes).
    The ``"*"`` unlabeled bucket never owns capacity and is excluded,
    matching the autoscaler's convention.

    ``min_rate``: rates at or below this floor (per second) forecast as
    ZERO demand — an EWMA decays geometrically and never quite reaches
    0, so without a floor a burst's tail would hold its last engine
    forever (the planner's analogue of `ElasticPolicy.retire_rate`).
    """
    if tick_s <= 0:
        raise ValueError(f"tick_s must be positive, got {tick_s}")
    out: Dict[str, LabelDemand] = {}
    for label in tracker.labels():
        if label == "*":
            continue
        lengths = cluster.label_prompt_lengths(label)
        prompt = (sum(lengths) / len(lengths)) if lengths \
            else default_prompt_len
        rate = tracker.rate(label) / tick_s
        if rate <= min_rate:
            rate = 0.0
        out[label] = LabelDemand(rate=rate, prompt_len=prompt,
                                 new_tokens=new_tokens)
    return out


def eligible_specs(specs: Sequence[EngineSpec],
                   required: Optional[ShardingPlan]
                   ) -> List[EngineSpec]:
    """Fail-closed pruning: keep only specs whose plan, merged with the
    label's route constraint, actually satisfies it (a spec whose device
    pins conflict with the constraint degrades to unroutable under
    `merge_restrictions` — it must never be proposed). The surviving
    specs carry the MERGED plan, so a spawned engine is immediately
    routing-eligible."""
    if required is None:
        return list(specs)
    out = []
    for spec in specs:
        merged = merge_restrictions(spec.plan, required)
        if plan_satisfies(merged, required):
            out.append(dataclasses.replace(spec, plan=merged))
    return out


def _count_range(bounds: Bounds, max_engines: int) -> range:
    """Counts to enumerate: ``max_engines`` caps only UNBOUNDED labels —
    an explicit intent-pinned max is honored as stated."""
    lo, hi = bounds
    if hi is None:
        hi = max_engines
    return range(max(lo, 0), max(hi, lo) + 1)


def _violation(est: CostEstimate,
               targets: Tuple[Optional[float], Optional[float]],
               rho_max: float) -> float:
    """Graded violation score for one label's estimate. Zero when the
    SLO targets hold and utilization stays under the headroom ceiling.
    Overload contributes 1 PLUS the (clipped) excess utilization, so
    when every enumerable count violates, the search still prefers the
    configuration that covers the MOST demand — a binary score would
    tie all violators and let the cost term scale capacity DOWN exactly
    when demand spikes past the ceiling."""
    viol = 0.0
    if not est.meets(*targets):
        viol += 1.0
    if est.utilization > rho_max:
        viol += 1.0 + min(est.utilization - rho_max, 9.0)
    return viol


def best_candidate(
    demand: Mapping[str, LabelDemand],
    targets: Mapping[str, Tuple[Optional[float], Optional[float]]],
    *,
    specs: Sequence[EngineSpec],
    profiles: Sequence[DeviceProfile],
    features_fn: Callable[[EngineSpec], CostFeatures],
    bounds: Optional[Mapping[str, Bounds]] = None,
    default_bounds: Bounds = (0, 4),
    route_required: Optional[Mapping[str, ShardingPlan]] = None,
    rho_max: float = 0.85,
    max_engines_per_label: int = 4,
    estimate_fn: Optional[EstimateFn] = None,
) -> ScoredCandidate:
    """Pick the best configuration for the forecast demand.

    Args:
        demand: per-label `LabelDemand` (the forecast).
        targets: per-label ``(max_ttft_s, max_tpot_s)`` service-level
            targets (missing label / None entry == no target).
        specs: candidate `EngineSpec` plan/sizing variants.
        profiles: candidate `DeviceProfile`s (the heterogeneous pool).
        features_fn: spec -> `CostFeatures` (the planner's cached
            compiled-HLO extraction; the search itself never compiles).
        bounds: per-label intent-pinned (min, max) engine counts.
        default_bounds: bounds for labels not pinned.
        route_required: per-label route-constraint plans (fail-closed
            spec pruning).
        rho_max: utilization ceiling — demand above it counts as a
            violation even without an explicit SLO, so the search sizes
            capacity to demand like the threshold policy does, but
            model-driven.
        max_engines_per_label: enumeration cap when a label's max bound
            is unbounded.
        estimate_fn: the scoring estimator (default: the analytical
            roofline). A calibrated planner passes a closure applying
            its per-label `ResidualCalibration` factors, so learned
            residuals move the SAME lexicographic objective the
            analytical search uses.

    Returns:
        The best `ScoredCandidate`. Labels with demand but no legally
        servable spec are listed in ``infeasible`` (fail-closed: the
        planner surfaces them instead of proposing a non-compliant
        engine) and receive no assignment.
    """
    bounds = dict(bounds or {})
    route_required = dict(route_required or {})
    est_fn = estimate_fn or _analytical
    labels = sorted(set(demand) | set(bounds))

    config: Dict[str, Assignment] = {}
    per_label: Dict[str, CostEstimate] = {}
    infeasible: List[str] = []
    violations = 0
    cost = 0.0
    headroom = 0.0

    for label in labels:
        d = demand.get(label, LabelDemand(rate=0.0))
        lo_hi = bounds.get(label, default_bounds)
        cands = eligible_specs(specs, route_required.get(label))
        if not cands:
            if d.rate > 0 or lo_hi[0] > 0:
                infeasible.append(label)
            continue
        ttft_t, tpot_t = targets.get(label, (None, None))
        best: Optional[Tuple[Tuple[float, float, float],
                             Assignment, CostEstimate]] = None
        for spec in cands:
            feats = features_fn(spec)
            for profile in profiles:
                for count in _count_range(lo_hi, max_engines_per_label):
                    if count == 0:
                        # legal only when nothing demands capacity
                        if d.rate > 0:
                            continue
                        a = Assignment(spec, profile, 0)
                        key = (0.0, 0.0, 0.0)
                        if best is None or key < best[0]:
                            best = (key, a, est_fn(label, feats, profile,
                                                   d.mix(), 1))
                        continue
                    est = est_fn(label, feats, profile, d.mix(), count)
                    viol = _violation(est, (ttft_t, tpot_t), rho_max)
                    c = count * profile.cost_rate * profile.n_devices
                    hr = max(0.0, 1.0 - est.utilization)
                    key = (viol, c, -hr)
                    if best is None or key < best[0]:
                        best = (key, Assignment(spec, profile, count), est)
        if best is None:
            infeasible.append(label)
            continue
        key, assignment, est = best
        config[label] = assignment
        per_label[label] = est
        violations += key[0]
        cost += key[1]
        headroom += -key[2]

    return ScoredCandidate(config=config, violations=violations, cost=cost,
                           headroom=headroom, per_label=per_label,
                           infeasible=infeasible)


def score_current(
    current: Mapping[str, Tuple[EngineSpec, DeviceProfile, int]],
    demand: Mapping[str, LabelDemand],
    targets: Mapping[str, Tuple[Optional[float], Optional[float]]],
    *,
    features_fn: Callable[[EngineSpec], CostFeatures],
    rho_max: float = 0.85,
    estimate_fn: Optional[EstimateFn] = None,
) -> ScoredCandidate:
    """Score the configuration that is ALREADY deployed, with the same
    objective `best_candidate` uses — the hysteresis comparison's other
    half (pass the same ``estimate_fn`` so both sides see the same
    calibrated costs)."""
    est_fn = estimate_fn or _analytical
    config: Dict[str, Assignment] = {}
    per_label: Dict[str, CostEstimate] = {}
    violations = 0.0
    cost = 0.0
    headroom = 0.0
    # labels with demand but NO deployed capacity at all are violations
    # of the deployed config (demand.rate > 0 and nothing serves it);
    # graded like total overload so the comparison scale matches
    # best_candidate's
    for label, d in demand.items():
        if label not in current and d.rate > 0:
            violations += 2.0 + 9.0
    for label, (spec, profile, count) in current.items():
        d = demand.get(label, LabelDemand(rate=0.0))
        a = Assignment(spec, profile, count)
        config[label] = a
        if count == 0:
            if d.rate > 0:
                violations += 2.0 + 9.0
            continue
        est = est_fn(label, features_fn(spec), profile, d.mix(), count)
        per_label[label] = est
        violations += _violation(est, targets.get(label, (None, None)),
                                 rho_max)
        cost += count * profile.cost_rate * profile.n_devices
        headroom += max(0.0, 1.0 - est.utilization)
    return ScoredCandidate(config=config, violations=violations, cost=cost,
                           headroom=headroom, per_label=per_label,
                           infeasible=[])
