from repro.optim.adamw import AdamW, adamw  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim.compress import compress_grads_int8, decompress_grads_int8  # noqa: F401
