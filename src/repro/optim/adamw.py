"""AdamW with global-norm clipping and optional reduced-precision state.

Pure-pytree implementation (no optax dependency). Optimizer state mirrors
the parameter tree, so a parameter `ShardingPlan` applies verbatim to m/v —
states are fully sharded alongside FSDP params (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Optional[str] = None   # None -> fp32; "bfloat16" halves memory

    def _sdtype(self):
        return jnp.dtype(self.state_dtype) if self.state_dtype else jnp.float32

    def init(self, params: PyTree) -> PyTree:
        sd = self._sdtype()
        zeros = lambda p: jnp.zeros(p.shape, dtype=sd)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(
        self,
        grads: PyTree,
        state: PyTree,
        params: PyTree,
    ) -> Tuple[PyTree, PyTree]:
        """Returns (new_params, new_state)."""
        count = state["count"] + 1
        lr = self.lr(count) if callable(self.lr) else self.lr

        if self.clip_norm is not None:
            leaves = jax.tree.leaves(grads)
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in leaves))
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        else:
            scale = jnp.float32(1.0)

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)
        sd = self._sdtype()

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
            mhat = m32 / bc1
            vhat = v32 / bc2
            step = mhat * jax.lax.rsqrt(vhat + self.eps * self.eps)
            # decoupled weight decay (skip 1-D params: norms, biases)
            wd = self.weight_decay if p.ndim > 1 else 0.0
            new_p = p.astype(jnp.float32) - lr * (step + wd * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m32.astype(sd), v32.astype(sd)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_params, {"m": new_m, "v": new_v, "count": count}


def adamw(**kw) -> AdamW:
    return AdamW(**kw)
