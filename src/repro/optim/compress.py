"""Int8 error-feedback gradient compression for cross-pod all-reduce.

Beyond-paper distributed-optimization trick: gradients crossing the slow
`pod` (DCN) axis are quantized to int8 with a per-tensor fp32 scale before
the cross-pod mean, and the quantization residual is carried to the next
step (error feedback keeps the scheme unbiased over time).

Used by `launch/train.py --grad-compress`; the cross-pod reduction then
moves 4x fewer bytes over DCN.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def compress_grads_int8(grads: PyTree, residual: PyTree) -> Tuple[PyTree, PyTree, PyTree]:
    """Quantize (grads + residual) to int8. Returns (q, scales, new_residual)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_r = g32 - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat, flat_r)]
    q = treedef.unflatten([o[0] for o in out])
    scales = treedef.unflatten([o[1] for o in out])
    new_res = treedef.unflatten([o[2] for o in out])
    return q, scales, new_res


def decompress_grads_int8(q: PyTree, scales: PyTree, dtype=jnp.float32) -> PyTree:
    return jax.tree.map(lambda qq, s: (qq.astype(jnp.float32) * s).astype(dtype), q, scales)


def init_residual(grads_shape: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
