"""minicpm3-4b [dense]: MLA attention.

62L, d_model=2560, 40 heads (kv=40 at the MLA latent level), d_ff=6400,
vocab=73448. [hf:openbmb/MiniCPM3-4B; hf]
"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73_448,
    attn_type="mla",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    pos_type="rope",
    rope_theta=10_000.0,
    mlp_act="silu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    source="[hf:openbmb/MiniCPM3-4B; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        attn_type="mla",
        mla=MLAConfig(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=8,
            qk_rope_head_dim=8,
            v_head_dim=8,
        ),
        pos_type="rope",
        mlp_act="silu",
        norm_type="rmsnorm",
        tie_embeddings=True,
        max_seq_len=128,
        source=CONFIG.source,
    )
