"""jamba-v0.1-52b [hybrid]: Mamba+attention 1:7 interleave + MoE 16e top-2.

32L, d_model=4096, attention layers 32 heads (GQA kv=8), d_ff=14336,
vocab=65536. Period-8 layout with attention at in-period offset 4; MoE
replaces the MLP on every second layer (16 experts, top-2).
[arXiv:2403.19887; hf]

Adaptation note (DESIGN.md §4): Jamba v0.1 uses Mamba-1 internally; we use
the Mamba-2 SSD block so the hybrid shares the `ssd_scan` Pallas kernel.
State width follows Jamba (d_state=16).
"""
from repro.configs.base import MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=65_536,
    attn_type="gqa",
    pos_type="rope",
    mlp_act="silu",
    norm_type="rmsnorm",
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_expert=14_336,
        num_shared_experts=0,
        d_shared=0,
        every_k_layers=2,
        offset=1,
        norm_topk_prob=True,
    ),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, d_conv=4, chunk_size=256),
    hybrid_period=8,
    hybrid_attn_offsets=(4,),
    source="[arXiv:2403.19887; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b-smoke",
        family="hybrid",
        num_layers=8,          # one full period
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_type="gqa",
        pos_type="rope",
        mlp_act="silu",
        norm_type="rmsnorm",
        moe=MoEConfig(
            num_experts=4,
            top_k=2,
            d_expert=128,
            every_k_layers=2,
            offset=1,
            norm_topk_prob=True,
        ),
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk_size=32),
        hybrid_period=8,
        hybrid_attn_offsets=(4,),
        max_seq_len=128,
        source=CONFIG.source,
    )
