"""Architecture config dataclasses.

Every assigned architecture is described by a single `ModelConfig`. The
model zoo (`repro.models`) consumes only these fields, so new architectures
are added by writing a config file, not new model code.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek/MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 60
    top_k: int = 4
    d_expert: int = 1408            # per-expert FFN hidden dim
    num_shared_experts: int = 0     # shared experts (always active)
    d_shared: int = 0               # shared expert FFN hidden dim (total)
    every_k_layers: int = 1         # MoE replaces MLP on layers where
    #                                 (layer_idx % every_k_layers == offset)
    offset: int = 0
    norm_topk_prob: bool = False
    capacity_factor: float = 1.25   # dense-dispatch capacity factor
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block config."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk_size: int = 256
    n_groups: int = 1               # B/C groups (GVA-style)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Encoder config for enc-dec models (Whisper)."""

    num_encoder_layers: int = 32
    encoder_seq_len: int = 1500     # nominal frame count (stubbed frontend)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- attention variant ---
    attn_type: str = "gqa"          # gqa | mla | none
    mla: Optional[MLAConfig] = None
    qk_norm: bool = False

    # --- positional encoding ---
    pos_type: str = "rope"          # rope | mrope | learned | sinusoidal
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # --- MLP ---
    mlp_act: str = "silu"           # silu (gated) | relu2 | gelu
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    norm_eps: float = 1e-5

    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None

    # --- state-space ---
    ssm: Optional[SSMConfig] = None
    # hybrid layout: period + indices of attention layers within a period
    # (Jamba: period 8, attention at offset 4, the rest Mamba).
    hybrid_period: int = 0
    hybrid_attn_offsets: Tuple[int, ...] = ()

    # --- encoder-decoder ---
    encdec: Optional[EncDecConfig] = None

    # --- embeddings ---
    tie_embeddings: bool = False

    # --- numerics ---
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"

    # --- bookkeeping ---
    max_seq_len: int = 524_288
    source: str = ""                # provenance note ([arXiv/hf; tier])

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def param_count(self) -> int:
        """Approximate total parameter count (embedding + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attn_type == "mla":
                m = self.mla or MLAConfig()
                qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * n_q * qk_head
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                p += n_q * m.v_head_dim * d
                return p
            return d * hd * (n_q + 2 * n_kv) + n_q * hd * d

        def mlp_params(ff: int) -> int:
            n_mat = 3 if self.mlp_act == "silu" else 2
            return n_mat * d * ff

        def moe_params() -> int:
            assert self.moe is not None
            m = self.moe
            p = m.num_experts * mlp_params(m.d_expert) + d * m.num_experts
            if m.num_shared_experts:
                p += mlp_params(m.d_shared)
            return p

        def ssm_params() -> int:
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
            p += conv_dim * s.d_conv                                  # conv
            p += 2 * nheads + d_in                                    # A, D, norm
            p += d_in * d                                             # out_proj
            return p

        for layer in range(self.num_layers):
            is_attn = True
            if self.family == "ssm":
                is_attn = False
            elif self.hybrid_period:
                is_attn = (layer % self.hybrid_period) in self.hybrid_attn_offsets
            if is_attn:
                total += attn_params()
            else:
                total += ssm_params()
            if self.family == "ssm":
                continue  # mamba2 has no MLP
            if self.moe is not None and (layer % self.moe.every_k_layers == self.moe.offset):
                total += moe_params()
            else:
                total += mlp_params(self.d_ff)
        if self.encdec is not None:
            e = self.encdec
            per_enc = attn_params() + mlp_params(self.d_ff)
            total += e.num_encoder_layers * per_enc
            total += self.num_layers * attn_params()  # decoder cross-attn
        return total

    def active_param_count(self) -> int:
        """Parameters active per token (for MoE archs)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_expert = self.param_count()
        # subtract inactive routed experts
        n_mat = 3 if self.mlp_act == "silu" else 2
        per_expert = n_mat * self.d_model * m.d_expert
        n_moe_layers = sum(
            1 for l in range(self.num_layers)
            if (l % m.every_k_layers == m.offset)
            and not (self.hybrid_period and (l % self.hybrid_period) in self.hybrid_attn_offsets and self.family == "ssm")
        )
        return dense_expert - n_moe_layers * (m.num_experts - m.top_k) * per_expert


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CELLS = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def get_shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(f"unknown shape cell {name!r}")
