"""Architecture config registry.

Each assigned architecture lives in its own module exposing ``CONFIG``
(the exact published configuration) and ``reduced()`` (a tiny same-family
config for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    EncDecConfig,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    SHAPE_CELLS,
    ShapeCell,
    SSMConfig,
    get_shape_cell,
)

ARCH_IDS: List[str] = [
    "whisper_large_v3",
    "minicpm3_4b",
    "nemotron_4_340b",
    "minitron_4b",
    "deepseek_coder_33b",
    "qwen2_vl_2b",
    "qwen2_moe_a2_7b",
    "moonshot_v1_16b_a3b",
    "jamba_v0_1_52b",
    "mamba2_370m",
]

# canonical dashed ids (CLI) -> module names
_ALIASES: Dict[str, str] = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "whisper-large-v3": "whisper_large_v3",
    "minicpm3-4b": "minicpm3_4b",
    "nemotron-4-340b": "nemotron_4_340b",
    "minitron-4b": "minitron_4b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "mamba2-370m": "mamba2_370m",
})


def _module(arch: str):
    key = _ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if key not in ARCH_IDS:
        raise KeyError(f"unknown architecture {arch!r}; known: {sorted(_ALIASES)}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced_config(arch: str) -> ModelConfig:
    return _module(arch).reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: importlib.import_module(f"repro.configs.{a}").CONFIG for a in ARCH_IDS}


def applicable_cells(cfg: ModelConfig) -> List[ShapeCell]:
    """Shape cells that actually run for this architecture.

    ``long_500k`` requires sub-quadratic sequence mixing and is only run for
    SSM/hybrid families (see DESIGN.md §4); it is recorded as a skip for the
    pure full-attention architectures.
    """
    cells = []
    for c in SHAPE_CELLS:
        if c.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            continue
        cells.append(c)
    return cells
