"""whisper-large-v3 [audio]: enc-dec, conv frontend stubbed.

32L decoder (+32L encoder), d_model=1280, 20 heads (MHA kv=20), d_ff=5120,
vocab=51866. [arXiv:2212.04356; unverified]

The audio frontend (log-mel + conv downsampling) is a STUB: ``input_specs``
provides precomputed (batch, frames, d_model) frame embeddings.
"""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51_866,
    attn_type="gqa",
    pos_type="learned",
    mlp_act="gelu",
    norm_type="layernorm",
    encdec=EncDecConfig(num_encoder_layers=32, encoder_seq_len=1500),
    tie_embeddings=True,
    max_seq_len=32_768,
    source="[arXiv:2212.04356; unverified]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke",
        family="encdec",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        attn_type="gqa",
        pos_type="learned",
        mlp_act="gelu",
        norm_type="layernorm",
        encdec=EncDecConfig(num_encoder_layers=2, encoder_seq_len=32),
        tie_embeddings=True,
        max_seq_len=128,
        source=CONFIG.source,
    )
