"""qwen2-vl-2b [vlm]: M-RoPE, dynamic-resolution vision frontend (stubbed).

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936,
head_dim=128, M-RoPE sections (16, 24, 24). [arXiv:2409.12191; hf]

The vision frontend is a STUB: ``input_specs`` provides token ids plus the
(3, batch, seq) M-RoPE position ids that the real ViT/patch pipeline would
emit for interleaved text+vision streams.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    attn_type="gqa",
    pos_type="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    mlp_act="silu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    source="[arXiv:2409.12191; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_type="gqa",
        pos_type="mrope",
        mrope_sections=(2, 3, 3),
        mlp_act="silu",
        norm_type="rmsnorm",
        tie_embeddings=True,
        max_seq_len=128,
        source=CONFIG.source,
    )
