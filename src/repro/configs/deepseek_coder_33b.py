"""deepseek-coder-33b [dense]: llama-arch GQA.

62L, d_model=7168, 56 heads (GQA kv=8), d_ff=19200, vocab=32256,
head_dim=128. [arXiv:2401.14196; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19_200,
    vocab_size=32_256,
    attn_type="gqa",
    pos_type="rope",
    rope_theta=100_000.0,
    mlp_act="silu",
    norm_type="rmsnorm",
    source="[arXiv:2401.14196; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-33b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=256,
        attn_type="gqa",
        pos_type="rope",
        mlp_act="silu",
        norm_type="rmsnorm",
        max_seq_len=128,
        source=CONFIG.source,
    )
