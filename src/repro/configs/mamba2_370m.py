"""mamba2-370m [ssm]: SSD (state-space duality), attention-free.

48L, d_model=1024, ssm_state=128, vocab=50280, expand=2 (d_inner=2048),
head_dim=64 (32 SSD heads), d_conv=4. [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    attn_type="none",
    pos_type="none",
    mlp_act="silu",
    norm_type="rmsnorm",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk_size=256),
    tie_embeddings=True,
    source="[arXiv:2405.21060; unverified]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=256,
        attn_type="none",
        pos_type="none",
        mlp_act="silu",
        norm_type="rmsnorm",
        ssm=SSMConfig(d_state=16, head_dim=16, expand=2, d_conv=4, chunk_size=32),
        tie_embeddings=True,
        max_seq_len=128,
        source=CONFIG.source,
    )
