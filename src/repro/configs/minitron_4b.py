"""minitron-4b [dense]: pruned nemotron (GQA + squared-ReLU).

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216, vocab=256000,
head_dim=128. [arXiv:2407.14679; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256_000,
    attn_type="gqa",
    pos_type="rope",
    mlp_act="relu2",
    norm_type="layernorm",
    source="[arXiv:2407.14679; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
        attn_type="gqa",
        pos_type="rope",
        mlp_act="relu2",
        norm_type="layernorm",
        max_seq_len=128,
        source=CONFIG.source,
    )
