"""nemotron-4-340b [dense]: GQA + squared-ReLU MLP.

96L, d_model=18432, 96 heads (GQA kv=8), d_ff=73728, vocab=256000.
head_dim = 18432/96 = 192. [arXiv:2402.16819; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18_432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab_size=256_000,
    attn_type="gqa",
    pos_type="rope",
    mlp_act="relu2",
    norm_type="layernorm",
    source="[arXiv:2402.16819; unverified]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b-smoke",
        family="dense",
        num_layers=2,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=256,
        attn_type="gqa",
        pos_type="rope",
        mlp_act="relu2",
        norm_type="layernorm",
        max_seq_len=128,
        source=CONFIG.source,
    )
