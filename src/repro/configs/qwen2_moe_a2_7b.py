"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + 4 shared experts.

24L, d_model=2048, 16 heads (MHA kv=16), expert d_ff=1408, vocab=151936,
shared-expert hidden 5632 (= 4x1408). [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151_936,
    attn_type="gqa",
    pos_type="rope",
    rope_theta=1_000_000.0,
    mlp_act="silu",
    norm_type="rmsnorm",
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        d_expert=1408,
        num_shared_experts=4,
        d_shared=5632,
        every_k_layers=1,
        norm_topk_prob=False,
    ),
    source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        attn_type="gqa",
        pos_type="rope",
        mlp_act="silu",
        norm_type="rmsnorm",
        moe=MoEConfig(
            num_experts=8,
            top_k=2,
            d_expert=96,
            num_shared_experts=2,
            d_shared=192,
            every_k_layers=1,
            norm_topk_prob=False,
        ),
        max_seq_len=128,
        source=CONFIG.source,
    )
