"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 routed experts top-6.

48L, d_model=2048, 16 heads (MHA kv=16), expert d_ff=1408, vocab=163840,
plus 2 shared experts (moonlight-style). [hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163_840,
    attn_type="gqa",
    pos_type="rope",
    rope_theta=50_000.0,
    mlp_act="silu",
    norm_type="rmsnorm",
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_expert=1408,
        num_shared_experts=2,
        d_shared=2816,
        every_k_layers=1,
        norm_topk_prob=True,
    ),
    source="[hf:moonshotai/Moonlight-16B-A3B; hf]",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=96,
        vocab_size=256,
        attn_type="gqa",
        pos_type="rope",
        mlp_act="silu",
        norm_type="rmsnorm",
        moe=MoEConfig(
            num_experts=8,
            top_k=3,
            d_expert=96,
            num_shared_experts=1,
            d_shared=96,
            every_k_layers=1,
            norm_topk_prob=True,
        ),
        max_seq_len=128,
        source=CONFIG.source,
    )
