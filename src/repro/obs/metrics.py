"""Metrics registry for the flight recorder: counters / gauges /
histograms with label sets, quantile sketches, and JSON snapshots.

The histogram is a log-bucketed sketch (geometric bucket edges, factor
``growth``): ``observe`` is O(1), memory is O(log(max/min)), and any
quantile is recovered to within ``sqrt(growth) - 1`` relative error
(~5% at the default growth of 1.1) — plenty for TTFT/TPOT p99 tracking,
and the reason `ServingCluster.metrics_by_label` can drop its
O(total-completions) rescans for O(1)-per-completion accounting
(`RequestAggregate`).

Everything here is lock-safe and import-clean (no serving imports), so
the recorder can be threaded through any layer without cycles.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Mapping, Optional, Tuple, Union

Number = Union[int, float]


class Counter:
    """Monotone counter."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, by: Number = 1) -> None:
        if by < 0:
            raise ValueError(f"counters only go up, got {by}")
        with self._lock:
            self._value += by

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: Number) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, by: Number) -> None:
        with self._lock:
            self._value += by

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Log-bucketed quantile sketch.

    Positive observations land in bucket ``ceil(log(v) / log(growth))``;
    a quantile is reported as the geometric midpoint of its bucket, so
    the relative error is bounded by ``sqrt(growth) - 1``. Non-positive
    and sub-``min_value`` observations share an underflow bucket
    (reported as 0.0); non-finite observations are counted separately so
    an inf-contaminated tail surfaces as inf instead of silently
    vanishing — matching what ``np.percentile`` would have said.
    """

    __slots__ = ("growth", "min_value", "_log_growth", "_buckets",
                 "_under", "_n_inf", "_n_nan", "count", "sum",
                 "min", "max", "_lock")

    def __init__(self, growth: float = 1.1, min_value: float = 1e-9):
        if growth <= 1.0:
            raise ValueError(f"growth must exceed 1, got {growth}")
        self.growth = float(growth)
        self.min_value = float(min_value)
        self._log_growth = math.log(self.growth)
        self._buckets: Dict[int, int] = {}
        self._under = 0
        self._n_inf = 0
        self._n_nan = 0
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    def observe(self, value: Number) -> None:
        v = float(value)
        with self._lock:
            self.count += 1
            self.sum += v
            if math.isnan(v):
                self._n_nan += 1
                return
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            if math.isinf(v):
                self._n_inf += 1
            elif v < self.min_value:
                self._under += 1
            else:
                idx = math.ceil(math.log(v) / self._log_growth)
                self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def quantile(self, q: float) -> float:
        """The q-quantile (q in [0, 1]); NaN on an empty sketch."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        with self._lock:
            if self.count == 0:
                return math.nan
            if self._n_nan:
                return math.nan          # np.percentile propagates NaN too
            rank = q * (self.count - 1) + 1      # 1-based target rank
            seen = self._under
            if seen >= rank:
                return 0.0
            for idx in sorted(self._buckets):
                seen += self._buckets[idx]
                if seen >= rank:
                    # geometric bucket midpoint: (edge/growth, edge]
                    return self.growth ** (idx - 0.5)
            return math.inf if self._n_inf else self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def snapshot(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self.min if self.count else math.nan,
                "max": self.max if self.count else math.nan,
                "p50": self.quantile(0.5), "p99": self.quantile(0.99)}


def _key(name: str, labels: Mapping[str, str]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create instrument families keyed by (name, label set).

    >>> reg = MetricsRegistry()
    >>> reg.counter("requests_completed", label="phi").inc()
    >>> reg.histogram("ttft_s", label="phi").observe(0.012)
    >>> snap = reg.snapshot()
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._lock = threading.Lock()

    def counter(self, name: str, **labels: str) -> Counter:
        key = _key(name, labels)
        with self._lock:
            inst = self._counters.get(key)
            if inst is None:
                inst = self._counters[key] = Counter()
        return inst

    def gauge(self, name: str, **labels: str) -> Gauge:
        key = _key(name, labels)
        with self._lock:
            inst = self._gauges.get(key)
            if inst is None:
                inst = self._gauges[key] = Gauge()
        return inst

    def histogram(self, name: str, growth: float = 1.1,
                  **labels: str) -> Histogram:
        key = _key(name, labels)
        with self._lock:
            inst = self._histograms.get(key)
            if inst is None:
                inst = self._histograms[key] = Histogram(growth=growth)
        return inst

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """JSON-able view of every instrument (NaN/inf survive as floats;
        serialize with a NaN-tolerant encoder or scrub downstream)."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(histograms.items())},
        }


class RequestAggregate:
    """Incremental `METRIC_KEYS`-shaped accounting for one label.

    The O(1)-per-completion replacement for rescanning every completed
    request on each `ServingCluster.metrics_by_label` call: means are
    exact running sums (non-finite TTFT/TPOT fold in exactly as
    ``np.mean`` would), p99 comes from the log-bucketed sketch (~5%
    relative error).
    """

    __slots__ = ("completed", "_ttft_sum", "_tpot_sum",
                 "_ttft_hist", "_tpot_hist")

    def __init__(self):
        self.completed = 0
        self._ttft_sum = 0.0
        self._tpot_sum = 0.0
        self._ttft_hist = Histogram()
        self._tpot_hist = Histogram()

    def observe(self, ttft_s: float, tpot_s: float) -> None:
        self.completed += 1
        self._ttft_sum += ttft_s
        self._tpot_sum += tpot_s
        self._ttft_hist.observe(ttft_s)
        self._tpot_hist.observe(tpot_s)

    def metrics(self) -> Dict[str, float]:
        """The `repro.serving.engine.METRIC_KEYS` dict (NaN-filled when
        nothing completed, like ``compute_metrics([])``)."""
        if self.completed == 0:
            return {"completed": 0,
                    "ttft_mean_s": math.nan, "ttft_p99_s": math.nan,
                    "tpot_mean_s": math.nan, "tpot_p99_s": math.nan}
        return {"completed": self.completed,
                "ttft_mean_s": self._ttft_sum / self.completed,
                "ttft_p99_s": self._ttft_hist.quantile(0.99),
                "tpot_mean_s": self._tpot_sum / self.completed,
                "tpot_p99_s": self._tpot_hist.quantile(0.99)}
