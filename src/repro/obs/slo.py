"""SLO / downtime ledger: turn the recorded event stream into windowed
per-label SLO attainment and an exact accounting of every pause.

The ledger consumes the same Φ_L targets the planner optimizes against
(`CompiledPolicy.slo_targets` — per-label ``(max_ttft_s, max_tpot_s)``)
and scores ``request.complete`` events with EXACTLY the replay harness's
semantics (`repro.traffic.replay`): a request attains its SLO iff its
TTFT is finite and within target (when a TTFT target exists) and its
TPOT, when finite, is within target (a TPOT target never fails on a
non-finite TPOT — single-token requests have no decode interval). That
equivalence is what lets tests cross-check the ledger's attainment
against `ReplayStats.attainment` from the very same run.

Downtime accounting answers "who paid for every pause": migration
pauses (``migration.pause``), swap windows (``cluster.swap``), spawn
and retire windows (``cluster.spawn`` / ``cluster.retire``), and
admission queueing (``request.admit`` queue waits) are each summed and
counted per cause, with per-engine breakdown for the reconfiguration
causes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

from repro.obs.events import Event

SLOTargets = Mapping[str, Tuple[Optional[float], Optional[float]]]


def meets_slo(ttft_s: float, tpot_s: float,
              targets: Tuple[Optional[float], Optional[float]]) -> bool:
    """The replay harness's attainment predicate, verbatim semantics."""
    ok = True
    if targets[0] is not None and not (math.isfinite(ttft_s)
                                       and ttft_s <= targets[0]):
        ok = False
    if targets[1] is not None and math.isfinite(tpot_s) \
            and tpot_s > targets[1]:
        ok = False
    return ok


@dataclasses.dataclass
class WindowAttainment:
    """Per-label attainment over one ledger window."""

    window: int          # window index: floor((ts - t0) / window_s)
    t_end: float         # window end, recording-clock seconds
    label: str
    ok: int
    scored: int

    @property
    def attainment(self) -> float:
        return self.ok / self.scored if self.scored else math.nan


@dataclasses.dataclass
class PauseAccount:
    """Who paid a pause: totals + counts for one cause."""

    total_s: float = 0.0
    count: int = 0
    max_s: float = 0.0
    by_engine: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, seconds: float, engine: str = "") -> None:
        self.total_s += seconds
        self.count += 1
        self.max_s = max(self.max_s, seconds)
        if engine:
            self.by_engine[engine] = self.by_engine.get(engine, 0.0) \
                + seconds

    def as_dict(self) -> Dict[str, object]:
        return {"total_s": self.total_s, "count": self.count,
                "max_s": self.max_s, "by_engine": dict(self.by_engine)}


class SLOLedger:
    """Fold a recorded event stream into attainment + pause accounting.

    Args:
        targets: per-label ``(max_ttft_s, max_tpot_s)``; labels absent
            from the mapping are observed but not scored (mirroring the
            replay harness).
        window_s: attainment window width, recording-clock seconds.
        t0: window epoch; defaults to the first consumed event's
            timestamp.
    """

    #: pause causes the ledger accounts for, in reporting order; a
    #: ``migration.pause`` event with ``reason="handoff"`` (the
    #: disaggregated first-token prefill→decode handoff) is accounted
    #: under "handoff", every other migration pause under "migration" —
    #: the two never double count
    CAUSES = ("migration", "handoff", "swap", "spawn", "retire", "queueing")

    def __init__(self, targets: Optional[SLOTargets] = None,
                 window_s: float = 1.0, t0: Optional[float] = None):
        if window_s <= 0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        self.targets: Dict[str, Tuple[Optional[float], Optional[float]]] \
            = dict(targets or {})
        self.window_s = float(window_s)
        self.t0 = t0
        self._win: Dict[Tuple[int, str], WindowAttainment] = {}
        self._ok: Dict[str, int] = {}
        self._scored: Dict[str, int] = {}
        self._completed: Dict[str, int] = {}
        # completions by serving role at completion time (disaggregated
        # serving: handoff requests complete on their decode engine)
        self._by_role: Dict[str, int] = {}
        self.pauses: Dict[str, PauseAccount] = {
            c: PauseAccount() for c in self.CAUSES}

    @classmethod
    def from_policy(cls, policy, **kw) -> "SLOLedger":
        """Build a ledger from an intent-compiled policy's Φ_L targets
        (`CompiledPolicy.slo_targets`) — or anything exposing a
        ``slo_targets`` mapping, e.g. a `WorkloadPlanner`."""
        return cls(dict(getattr(policy, "slo_targets", {}) or {}), **kw)

    # -- consumption ---------------------------------------------------
    def consume(self, events: Iterable[Event]) -> "SLOLedger":
        """Fold events (any order-preserving slice of the bus) into the
        ledger; returns self for chaining."""
        for ev in events:
            self.observe(ev)
        return self

    def observe(self, ev: Event) -> None:
        if self.t0 is None:
            self.t0 = ev.ts
        kind = ev.kind
        if kind == "request.complete":
            self._score(ev)
        elif kind == "migration.pause":
            cause = ("handoff" if ev.data.get("reason") == "handoff"
                     else "migration")
            self.pauses[cause].add(float(ev.data.get("pause_s", 0.0)),
                                   ev.engine)
        elif kind == "cluster.swap":
            self.pauses["swap"].add(float(ev.data.get("downtime_s", 0.0)),
                                    ev.engine)
        elif kind == "cluster.spawn":
            self.pauses["spawn"].add(float(ev.data.get("downtime_s", 0.0)),
                                     ev.engine)
        elif kind == "cluster.retire":
            self.pauses["retire"].add(float(ev.data.get("downtime_s", 0.0)),
                                      ev.engine)
        elif kind == "request.admit":
            wait = ev.data.get("queue_wait_s")
            if wait is not None:
                self.pauses["queueing"].add(float(wait), ev.engine)

    def _score(self, ev: Event) -> None:
        label = ev.label or "*"
        self._completed[label] = self._completed.get(label, 0) + 1
        role = str(ev.data.get("role", "unified") or "unified")
        self._by_role[role] = self._by_role.get(role, 0) + 1
        targets = self.targets.get(label)
        if targets is None or (targets[0] is None and targets[1] is None):
            return
        ok = meets_slo(float(ev.data.get("ttft_s", math.inf)),
                       float(ev.data.get("tpot_s", math.nan)), targets)
        self._scored[label] = self._scored.get(label, 0) + 1
        self._ok[label] = self._ok.get(label, 0) + ok
        w = int((ev.ts - self.t0) // self.window_s)
        key = (w, label)
        rec = self._win.get(key)
        if rec is None:
            rec = self._win[key] = WindowAttainment(
                w, self.t0 + (w + 1) * self.window_s, label, 0, 0)
        rec.scored += 1
        rec.ok += ok

    # -- results -------------------------------------------------------
    def attainment(self) -> Dict[str, float]:
        """Aggregate per-label attainment over everything consumed."""
        return {label: self._ok.get(label, 0) / scored
                for label, scored in sorted(self._scored.items()) if scored}

    def attainment_overall(self) -> Optional[float]:
        scored = sum(self._scored.values())
        return sum(self._ok.values()) / scored if scored else None

    def completed(self) -> Dict[str, int]:
        return dict(self._completed)

    def completed_by_role(self) -> Dict[str, int]:
        """Completions by the serving role of the completing engine
        (``"unified"`` unless disaggregated serving is active; a
        handed-off request counts under ``"decode"`` — where it
        finished)."""
        return dict(self._by_role)

    def windows(self, label: Optional[str] = None) -> List[WindowAttainment]:
        """The windowed attainment series, time-ordered."""
        out = sorted(self._win.values(), key=lambda w: (w.window, w.label))
        if label is not None:
            out = [w for w in out if w.label == label]
        return out

    def pause_accounting(self) -> Dict[str, Dict[str, object]]:
        """Every pause, attributed: cause -> totals/counts/per-engine."""
        return {c: self.pauses[c].as_dict() for c in self.CAUSES}

    def as_dict(self) -> Dict[str, object]:
        return {
            "targets": {k: list(v) for k, v in sorted(self.targets.items())},
            "window_s": self.window_s,
            "attainment": self.attainment(),
            "attainment_overall": self.attainment_overall(),
            "completed": self.completed(),
            "completed_by_role": self.completed_by_role(),
            "windows": [dataclasses.asdict(w) for w in self.windows()],
            "pauses": self.pause_accounting(),
        }
