"""Per-request critical-path attribution over the flight recorder.

`RequestLineage` assembles the rid-keyed event stream (PR 8's taxonomy)
into one `RequestTimeline` per completed request, decomposing the
measured latency into named components:

  * **TTFT** = queue wait + admission overhead + prefill compute
    + PREPARE/compile wait + (pre-admission) handoff pause.
  * **decode span** (= TPOT x decode steps) = decode compute
    + migration pauses + first-token handoff pause
    + prefill-interference stalls.

The decomposition is *conserved by construction* against the event
stream (queue wait and decode compute are residuals), and *checked*
against an independent measurement path: the engine-side ``t_submit`` /
``t_first`` / ``t_done`` stamps carried on ``request.complete``
(``ttft_s`` / ``tpot_s``). Under a `FakeClock` the two paths agree
exactly; under the wall clock they differ by the emit-site skew, which
`conservation()` bounds. A decomposition whose parts do not sum to the
independently measured value within ε means dropped events, a wall-clock
leak, or an unaccounted pause — exactly the corruption the Watchtower
exists to catch.

Component semantics (simulated vs wall clock): under a `FakeClock` only
*advancing* reads move time, so ``admission`` / ``prefill`` are ~0 and
queue wait + pauses carry the whole story — which is the truth of the
simulation. Under the wall clock the same fields carry real compute
durations measured with non-advancing reads in the engine.

Chrome flow events (`chrome_flows`) stitch a request's path across
engines through handoff/migration: pass them to
`repro.obs.trace.export_chrome(..., flows=...)` and Perfetto draws
arrows from the source engine's lane to the destination's across each
pause.
"""
from __future__ import annotations

import dataclasses
import math
import time  # swapped for the installed clock by install_clock
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.obs.events import Event, Recorder

#: TTFT decomposition keys, reporting order.
TTFT_COMPONENTS = ("queue_wait", "admission", "prefill", "prepare_wait",
                   "handoff_pause")
#: decode-span decomposition keys, reporting order.
TPOT_COMPONENTS = ("decode", "migration_pause", "handoff_pause",
                   "interference")


def _now() -> float:
    """Non-advancing read of the recording clock (same contract as
    `repro.obs.events.now`): assembling a lineage never perturbs a
    simulated run."""
    t = getattr(time, "now", None)
    return time.time() if t is None else t


def _pctl(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an ascending sequence (q in [0, 1])."""
    if not sorted_vals:
        return math.nan
    idx = max(0, min(len(sorted_vals) - 1,
                     math.ceil(q * len(sorted_vals)) - 1))
    return sorted_vals[idx]


@dataclasses.dataclass(frozen=True)
class RequestTimeline:
    """One completed request's attributed latency.

    Attributes:
        rid: request id.
        label: ``data-type`` label.
        role: serving role of the completing engine.
        engines: engine path, submission order (handoff/migration hops).
        t_submit / t_admit / t_complete: event-bus timestamps.
        ttft_s / tpot_s / tokens_out: the engine-stamped measurements
            from ``request.complete`` (the independent check path).
        ttft_parts: `TTFT_COMPONENTS` -> seconds.
        tpot_parts: `TPOT_COMPONENTS` -> seconds (decode-span units).
    """

    rid: int
    label: str
    role: str
    engines: Tuple[str, ...]
    t_submit: float
    t_admit: float
    t_complete: float
    ttft_s: float
    tpot_s: float
    tokens_out: int
    ttft_parts: Mapping[str, float]
    tpot_parts: Mapping[str, float]
    #: cross-engine moves: (pause_start, pause_end, src, dst, reason)
    hops: Tuple[Tuple[float, float, str, str, str], ...] = ()

    @property
    def decode_steps(self) -> int:
        """Decode intervals the measured TPOT averages over."""
        return max(self.tokens_out - 1, 1)

    @property
    def decode_span_s(self) -> float:
        """Measured decode span: ``tpot_s`` x decode steps (equals
        ``t_done - t_first`` by the engine's TPOT definition)."""
        return self.tpot_s * self.decode_steps

    def ttft_error(self) -> float:
        """Relative conservation error: |sum(parts) - measured| / measured."""
        total = sum(self.ttft_parts.values())
        return abs(total - self.ttft_s) / max(abs(self.ttft_s), 1e-12)

    def tpot_error(self) -> float:
        total = sum(self.tpot_parts.values())
        return abs(total - self.decode_span_s) \
            / max(abs(self.decode_span_s), 1e-12)

    def critical(self, which: str = "ttft") -> str:
        """The dominant component name of one decomposition."""
        parts = self.ttft_parts if which == "ttft" else self.tpot_parts
        return max(parts, key=lambda k: parts[k])


def _reconfig_windows(events: Iterable[Event]) -> Dict[str, List[Tuple[float, float]]]:
    """Per-engine [start, end] pause windows from committed swap/spawn
    events (``downtime_s`` backdates the window from the emit stamp)."""
    out: Dict[str, List[Tuple[float, float]]] = {}
    for ev in events:
        if ev.kind in ("cluster.swap", "cluster.spawn"):
            dur = float(ev.data.get("downtime_s", 0.0))
            out.setdefault(ev.engine, []).append((ev.ts - dur, ev.ts))
    return out


def _overlap(a0: float, a1: float, b0: float, b1: float) -> float:
    return max(0.0, min(a1, b1) - max(a0, b0))


class RequestLineage:
    """Assembled per-request timelines plus aggregate views.

    Build with `from_recorder` (live `Recorder`) or `from_events`
    (e.g. events reloaded from a debug bundle). Requests whose
    submit/admit events fell off the bounded event ring are counted in
    ``partial_rids`` and excluded — attribution never guesses.
    """

    def __init__(self, timelines: Sequence[RequestTimeline],
                 partial_rids: Sequence[int] = ()):
        self.timelines = sorted(timelines, key=lambda tl: tl.rid)
        self.partial_rids = sorted(partial_rids)
        self.built_at = _now()
        self._by_rid = {tl.rid: tl for tl in self.timelines}

    def __len__(self) -> int:
        return len(self.timelines)

    def get(self, rid: int) -> Optional[RequestTimeline]:
        return self._by_rid.get(rid)

    # -- assembly ------------------------------------------------------
    @classmethod
    def from_recorder(cls, rec: Recorder) -> "RequestLineage":
        return cls.from_events(rec.events())

    @classmethod
    def from_events(cls, events: Sequence[Event]) -> "RequestLineage":
        submits: Dict[int, Event] = {}
        admits: Dict[int, Event] = {}
        pauses: Dict[int, List[Event]] = {}
        for ev in events:
            if ev.kind == "request.submit":
                submits[ev.rid] = ev
            elif ev.kind == "request.admit":
                admits[ev.rid] = ev
            elif ev.kind == "migration.pause" and ev.rid >= 0:
                pauses.setdefault(ev.rid, []).append(ev)
        reconfig = _reconfig_windows(events)

        timelines: List[RequestTimeline] = []
        partial: List[int] = []
        for ev in events:
            if ev.kind != "request.complete":
                continue
            sub = submits.get(ev.rid)
            adm = admits.get(ev.rid)
            if sub is None or adm is None:
                partial.append(ev.rid)
                continue
            timelines.append(cls._assemble(
                sub, adm, ev, pauses.get(ev.rid, []), reconfig))
        return cls(timelines, partial)

    @staticmethod
    def _assemble(sub: Event, adm: Event, done: Event,
                  pauses: Sequence[Event],
                  reconfig: Mapping[str, Sequence[Tuple[float, float]]]
                  ) -> RequestTimeline:
        ttft_s = float(done.data.get("ttft_s", math.nan))
        tpot_s = float(done.data.get("tpot_s", 0.0))
        if not math.isfinite(tpot_s):
            tpot_s = 0.0
        tokens_out = int(done.data.get("tokens_out", 1))

        # TTFT side: components measured in the engine (non-advancing
        # reads), prepare windows overlapped from swap/spawn commits on
        # the admitting engine, pre-admission handoff pauses, and queue
        # wait as the conserved residual.
        prefill = float(adm.data.get("prefill_s", 0.0))
        admission = float(adm.data.get("admit_s", 0.0))
        prepare_wait = sum(
            _overlap(sub.ts, adm.ts, w0, w1)
            for w0, w1 in reconfig.get(adm.engine, ()))
        ttft_handoff = sum(float(p.data.get("pause_s", 0.0))
                           for p in pauses
                           if p.ts <= adm.ts
                           and p.data.get("reason") == "handoff")
        ttft_ev = adm.ts - sub.ts
        queue_wait = ttft_ev - prefill - admission - prepare_wait \
            - ttft_handoff
        ttft_parts = {"queue_wait": queue_wait, "admission": admission,
                      "prefill": prefill, "prepare_wait": prepare_wait,
                      "handoff_pause": ttft_handoff}

        # decode side: pauses after admission split handoff vs migration
        # (never double counted — keyed on the event's reason, like the
        # SLO ledger), interference stalls when the engine reports them,
        # decode compute as the conserved residual.
        mig = hand = 0.0
        for p in pauses:
            if p.ts <= adm.ts:
                continue
            pause_s = float(p.data.get("pause_s", 0.0))
            if p.data.get("reason") == "handoff":
                hand += pause_s
            else:
                mig += pause_s
        interference = float(done.data.get("interference_s", 0.0))
        span_ev = done.ts - adm.ts
        decode = span_ev - mig - hand - interference
        tpot_parts = {"decode": decode, "migration_pause": mig,
                      "handoff_pause": hand, "interference": interference}

        engines: List[str] = [sub.engine]
        hops: List[Tuple[float, float, str, str, str]] = []
        for p in sorted(pauses, key=lambda p: (p.ts, p.seq)):
            dst = str(p.data.get("dst", ""))
            if dst and dst != engines[-1]:
                pause_s = float(p.data.get("pause_s", 0.0))
                hops.append((p.ts - pause_s, p.ts, engines[-1], dst,
                             str(p.data.get("reason", "migration"))))
                engines.append(dst)
        if done.engine and done.engine != engines[-1]:
            engines.append(done.engine)

        return RequestTimeline(
            rid=done.rid, label=done.label,
            role=str(done.data.get("role", "unified") or "unified"),
            engines=tuple(engines),
            t_submit=sub.ts, t_admit=adm.ts, t_complete=done.ts,
            ttft_s=ttft_s, tpot_s=tpot_s, tokens_out=tokens_out,
            ttft_parts=ttft_parts, tpot_parts=tpot_parts,
            hops=tuple(hops))

    # -- conservation --------------------------------------------------
    def conservation(self, eps: float = 0.01) -> Dict[str, Any]:
        """Check every timeline's components against the independently
        measured TTFT / decode span; returns max/mean relative error and
        the rids violating ``eps``."""
        ttft_errs = [tl.ttft_error() for tl in self.timelines
                     if math.isfinite(tl.ttft_s)]
        tpot_errs = [tl.tpot_error() for tl in self.timelines
                     if tl.decode_span_s > 0]
        bad = [tl.rid for tl in self.timelines
               if (math.isfinite(tl.ttft_s) and tl.ttft_error() > eps)
               or (tl.decode_span_s > 0 and tl.tpot_error() > eps)]
        return {
            "n": len(self.timelines),
            "n_partial": len(self.partial_rids),
            "eps": eps,
            "ttft_max_rel_err": max(ttft_errs) if ttft_errs else 0.0,
            "ttft_mean_rel_err": (sum(ttft_errs) / len(ttft_errs))
            if ttft_errs else 0.0,
            "tpot_max_rel_err": max(tpot_errs) if tpot_errs else 0.0,
            "tpot_mean_rel_err": (sum(tpot_errs) / len(tpot_errs))
            if tpot_errs else 0.0,
            "violations": bad,
        }

    # -- aggregation ---------------------------------------------------
    def critical_path(self) -> Dict[str, Dict[str, Any]]:
        """Per-label component percentiles and the dominant component.

        For each label and each decomposition, reports every component's
        p50/p99 over that label's requests plus ``dominant_p50`` /
        ``dominant_p99`` — the component with the largest value at that
        percentile of its own distribution (ties break on
        `TTFT_COMPONENTS` / `TPOT_COMPONENTS` order).
        """
        by_label: Dict[str, List[RequestTimeline]] = {}
        for tl in self.timelines:
            by_label.setdefault(tl.label or "*", []).append(tl)
        out: Dict[str, Dict[str, Any]] = {}
        for label in sorted(by_label):
            tls = by_label[label]
            entry: Dict[str, Any] = {"n": len(tls)}
            for which, comps in (("ttft", TTFT_COMPONENTS),
                                 ("tpot", TPOT_COMPONENTS)):
                parts = {c: sorted(
                    (tl.ttft_parts if which == "ttft"
                     else tl.tpot_parts)[c] for tl in tls)
                    for c in comps}
                view: Dict[str, Any] = {}
                for q, name in ((0.50, "p50"), (0.99, "p99")):
                    vals = {c: _pctl(parts[c], q) for c in comps}
                    view[name] = vals
                    view[f"dominant_{name}"] = max(
                        comps, key=lambda c: vals[c])
                entry[which] = view
            out[label] = entry
        return out

    # -- Chrome flow events --------------------------------------------
    def chrome_flows(self) -> List[Dict[str, Any]]:
        """Flow-event specs stitching multi-engine requests across
        handoff/migration pauses, for
        `repro.obs.trace.export_chrome(..., flows=...)`: one ``"s"`` on
        the source lane at pause start, one ``"f"`` on the destination
        lane at pause end, keyed by rid."""
        flows: List[Dict[str, Any]] = []
        for tl in self.timelines:
            for hop, (t0, t1, src, dst, reason) in enumerate(tl.hops):
                fid = tl.rid * 16 + hop   # unique per (request, hop)
                flows.append({"name": f"rid {tl.rid} {reason}",
                              "id": fid, "ph": "s",
                              "track": src, "ts": t0})
                flows.append({"name": f"rid {tl.rid} {reason}",
                              "id": fid, "ph": "f",
                              "track": dst, "ts": max(t1, t0)})
        return flows
