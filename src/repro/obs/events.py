"""The flight recorder: structured event bus + span tracing + metrics,
timestamped on the serving layer's installed clock.

Design constraints (the whole point of this module):

  * **Zero overhead when disabled.** The module-level `RECORDER` is
    ``None`` by default; every instrumentation site in the serving
    stack is one attribute read + one ``is None`` test. Nothing is
    allocated, no lock is touched, no clock is read.
  * **Clock-aware, never clock-perturbing.** This module's ``time``
    attribute is swapped by `repro.serving.clock.install_clock` exactly
    like the serving modules' (it is listed in ``CLOCKED_MODULE_NAMES``).
    `now` prefers the installed clock's NON-advancing ``.now`` property,
    so recording an event under a `FakeClock` does not advance simulated
    time — a recorded replay is bitwise-identical to an unrecorded one.
  * **Deterministic ordering.** Simulated timestamps can tie (the
    non-advancing read); every event therefore carries a monotone
    ``seq`` assigned under the bus lock.
  * **Bounded.** The bus is an overwrite-oldest ring with a drop
    counter: a 10^6-request replay cannot OOM the recorder, and the
    drops are themselves observable.

Typical use::

    from repro.obs import Recorder, recording

    with recording(Recorder()) as rec:
        ...                                   # run the serving workload
    rec.export_chrome("replay.trace.json")    # open in Perfetto
    rec.bus.events("request.complete")        # structured history
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time  # swapped for the installed clock by install_clock
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Span, TraceBuffer, export_chrome


def now() -> float:
    """Current time on the recording clock, WITHOUT advancing it.

    When a clock object is installed (`FakeClock` / `SystemClock`), its
    ``.now`` property is a non-advancing read; the raw :mod:`time`
    module (the un-swapped default) has no ``now``, so we fall back to
    ``time.time()``.
    """
    t = getattr(time, "now", None)
    return time.time() if t is None else t


@dataclasses.dataclass(frozen=True)
class Event:
    """One structured record on the bus.

    Attributes:
        seq: monotone sequence number (total order even when simulated
            timestamps tie).
        ts: recording-clock timestamp, seconds.
        kind: dotted taxonomy name (``"request.submit"``,
            ``"ticket.ready"``, ``"planner.decision"``, ...); see
            docs/observability.md for the full taxonomy.
        engine: engine name the event concerns ("" when n/a).
        rid: request id (-1 when n/a).
        label: ``data-type`` label value ("" when n/a).
        data: JSON-able payload.
    """

    seq: int
    ts: float
    kind: str
    engine: str = ""
    rid: int = -1
    label: str = ""
    data: Mapping[str, Any] = dataclasses.field(default_factory=dict)


class EventBus:
    """Lock-safe bounded ring of `Event`s (overwrite-oldest, counted)."""

    def __init__(self, capacity: int = 65536):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._buf: List[Optional[Event]] = [None] * self.capacity
        self._head = 0
        self._count = 0
        self.emitted = 0
        self.dropped = 0
        self._lock = threading.Lock()

    def emit(self, kind: str, *, engine: str = "", rid: int = -1,
             label: str = "", ts: Optional[float] = None,
             **data: Any) -> Event:
        if ts is None:
            ts = now()
        with self._lock:
            ev = Event(self.emitted, ts, kind, engine, rid, label, data)
            if self._count == self.capacity:
                self.dropped += 1
            else:
                self._count += 1
            self._buf[self._head] = ev
            self._head = (self._head + 1) % self.capacity
            self.emitted += 1
        return ev

    def __len__(self) -> int:
        with self._lock:
            return self._count

    def events(self, kind: Optional[str] = None,
               engine: Optional[str] = None) -> List[Event]:
        """Oldest-first snapshot; ``kind`` may be an exact name or a
        dotted prefix (``"request"`` matches ``"request.submit"``)."""
        with self._lock:
            start = (self._head - self._count) % self.capacity
            out = [self._buf[(start + i) % self.capacity]
                   for i in range(self._count)]
        if kind is not None:
            out = [e for e in out
                   if e.kind == kind or e.kind.startswith(kind + ".")]
        if engine is not None:
            out = [e for e in out if e.engine == engine]
        return out


class Recorder:
    """Event bus + span trace + metrics registry behind one handle.

    Args:
        capacity: event-bus ring size.
        trace_capacity: span-ring size.
        decode_stride: engines emit an ``engine.decode`` progress event
            every this-many decode steps (1 == every step; bounded
            volume is the default).
    """

    def __init__(self, capacity: int = 65536, trace_capacity: int = 65536,
                 decode_stride: int = 16):
        self.bus = EventBus(capacity)
        self.trace = TraceBuffer(trace_capacity)
        self.metrics = MetricsRegistry()
        self.decode_stride = max(1, int(decode_stride))

    # -- events --------------------------------------------------------
    def emit(self, kind: str, *, engine: str = "", rid: int = -1,
             label: str = "", **data: Any) -> Event:
        """Record one event; a few kinds also fold into the metrics
        registry so counters/sketches stay O(1)-current."""
        ev = self.bus.emit(kind, engine=engine, rid=rid, label=label,
                           **data)
        if kind == "request.complete":
            lbl = label or "*"
            self.metrics.counter("requests_completed", label=lbl).inc()
            ttft = data.get("ttft_s")
            tpot = data.get("tpot_s")
            if ttft is not None:
                self.metrics.histogram("ttft_s", label=lbl).observe(ttft)
            if tpot is not None:
                self.metrics.histogram("tpot_s", label=lbl).observe(tpot)
        elif kind == "request.submit":
            self.metrics.counter("requests_submitted",
                                 label=label or "*").inc()
        elif kind == "request.reject":
            self.metrics.counter("requests_rejected",
                                 label=label or "*").inc()
        elif kind == "request.admit":
            wait = data.get("queue_wait_s")
            if wait is not None:
                self.metrics.histogram("queue_wait_s",
                                       label=label or "*").observe(wait)
        elif kind == "migration.pause":
            pause = data.get("pause_s")
            if pause is not None:
                self.metrics.histogram("migration_pause_s").observe(pause)
        return ev

    def events(self, kind: Optional[str] = None,
               engine: Optional[str] = None) -> List[Event]:
        return self.bus.events(kind, engine)

    # -- spans ---------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, track: str = "main", cat: str = "serving",
             **args: Any) -> Iterator[Dict[str, Any]]:
        """Record the body as one span; mutate the yielded dict to add
        result args (they land in the exported trace)."""
        t0 = now()
        try:
            yield args
        finally:
            self.trace.add(Span(name, t0, max(0.0, now() - t0),
                                track, cat, args))

    def span_at(self, name: str, ts: float, dur: float,
                track: str = "main", cat: str = "serving",
                **args: Any) -> None:
        """Record an already-measured interval (e.g. a migration pause
        whose duration is a computed sum, not a wrapped region)."""
        self.trace.add(Span(name, ts, max(0.0, dur), track, cat, args))

    # -- export --------------------------------------------------------
    def export_chrome(self, path: Optional[str] = None,
                      flows: Any = ()) -> Dict[str, Any]:
        """Chrome ``trace_event`` JSON of every recorded span (load in
        Perfetto / chrome://tracing); pass `RequestLineage.chrome_flows`
        output as ``flows`` to stitch cross-engine request paths."""
        return export_chrome(self.trace.spans(), path, flows=flows)

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-able status dict: metrics + recorder health."""
        return {"metrics": self.metrics.snapshot(),
                "events_emitted": self.bus.emitted,
                "events_dropped": self.bus.dropped,
                "spans_added": self.trace.added,
                "spans_dropped": self.trace.dropped}


#: The process-wide recorder. ``None`` (the default) disables all
#: instrumentation — sites guard with ``rec = RECORDER`` + ``is None``.
RECORDER: Optional[Recorder] = None


def install_recorder(rec: Optional[Recorder]) -> Callable[[], None]:
    """Install ``rec`` as the process recorder; returns a zero-argument
    restore callable (call in a ``finally``; `recording` wraps this)."""
    global RECORDER
    previous = RECORDER
    RECORDER = rec

    def restore() -> None:
        global RECORDER
        RECORDER = previous

    return restore


def get_recorder() -> Optional[Recorder]:
    """The installed recorder, or None when recording is disabled."""
    return RECORDER


@contextlib.contextmanager
def recording(rec: Optional[Recorder] = None) -> Iterator[Recorder]:
    """Enable recording for the body; restores the previous recorder on
    exit.

    >>> with recording() as rec:
    ...     ...                       # serve
    >>> rec.bus.emitted >= 0
    True
    """
    rec = rec if rec is not None else Recorder()
    restore = install_recorder(rec)
    try:
        yield rec
    finally:
        restore()
