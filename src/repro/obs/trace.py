"""Span tracing for the flight recorder: bounded span storage plus a
Chrome ``trace_event`` JSON exporter.

Spans are closed intervals on the *recording clock* (the clock installed
into the serving layer — see `repro.serving.clock.install_clock`; the
recorder reads it non-advancing, so tracing never perturbs a simulated
run). A span belongs to a ``track`` — an engine name or a subsystem like
``"cluster"`` / ``"planner"`` — which the exporter maps onto Chrome
``tid`` lanes, so a Perfetto timeline shows one row per engine with the
swap windows, migration pauses, and routing decisions nested on it.

The export format is the Chrome ``trace_event`` "JSON object format":
phase-``"X"`` (complete) events with microsecond ``ts``/``dur``, plus
``"M"`` metadata events naming each track. Both chrome://tracing and
https://ui.perfetto.dev load it directly.
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Span:
    """One closed interval on the recording clock.

    Attributes:
        name: what happened (``"route"``, ``"swap.commit"``, ...).
        ts: start time, seconds on the recording clock.
        dur: duration, seconds (>= 0; zero-width spans are legal under a
            non-advancing simulated clock).
        track: exporter lane — engine name or subsystem.
        cat: Chrome category string (filterable in Perfetto).
        args: JSON-able payload shown in the Perfetto detail pane.
    """

    name: str
    ts: float
    dur: float
    track: str = "main"
    cat: str = "serving"
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.ts + self.dur


def overlaps(a: Span, b: Span) -> bool:
    """Strict interval overlap (exclusive bounds): touching endpoints —
    and zero-width spans sitting exactly on a boundary — do NOT count.
    This is the predicate the no-route-during-swap invariant is checked
    with: two spans serialized by the same lock may share an endpoint
    but can never strictly interleave."""
    return a.ts < b.end and b.ts < a.end


class TraceBuffer:
    """Lock-safe bounded span store: overwrite-oldest ring with a drop
    counter, same retention policy as the event bus."""

    def __init__(self, capacity: int = 65536):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._buf: List[Optional[Span]] = [None] * self.capacity
        self._head = 0          # next write slot
        self._count = 0
        self.added = 0
        self.dropped = 0
        self._lock = threading.Lock()

    def add(self, span: Span) -> None:
        with self._lock:
            if self._count == self.capacity:
                self.dropped += 1
            else:
                self._count += 1
            self._buf[self._head] = span
            self._head = (self._head + 1) % self.capacity
            self.added += 1

    def __len__(self) -> int:
        with self._lock:
            return self._count

    def spans(self, name: Optional[str] = None,
              track: Optional[str] = None) -> List[Span]:
        """Oldest-first snapshot, optionally filtered by name/track."""
        with self._lock:
            start = (self._head - self._count) % self.capacity
            out = [self._buf[(start + i) % self.capacity]
                   for i in range(self._count)]
        return [s for s in out
                if (name is None or s.name == name)
                and (track is None or s.track == track)]


def export_chrome(spans: Sequence[Span],
                  path: Optional[str] = None,
                  flows: Sequence[Dict[str, Any]] = ()) -> Dict[str, Any]:
    """Render spans as a Chrome ``trace_event`` JSON document.

    Tracks are assigned ``tid``s in sorted-name order (deterministic:
    two identical replays export byte-identical traces) and labeled via
    ``thread_name`` metadata events so Perfetto shows readable lanes.

    Args:
        spans: the spans to export (any order; emitted as-is).
        path: when given, the document is also written there.
        flows: flow-event specs — dicts with ``name``, ``id``, ``ph``
            (``"s"`` start / ``"f"`` finish), ``track``, ``ts``
            (seconds) — e.g. `repro.obs.lineage.RequestLineage
            .chrome_flows`; Perfetto draws them as arrows between
            lanes (a request's cross-engine handoff/migration path).

    Returns:
        The trace document (``{"traceEvents": [...], ...}``).
    """
    tracks = {s.track for s in spans} | {f["track"] for f in flows}
    tids = {t: i + 1 for i, t in enumerate(sorted(tracks))}
    events: List[Dict[str, Any]] = [
        {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
         "args": {"name": track}}
        for track, tid in sorted(tids.items(), key=lambda kv: kv[1])]
    for s in spans:
        events.append({"name": s.name, "cat": s.cat, "ph": "X",
                       "ts": s.ts * 1e6, "dur": s.dur * 1e6,
                       "pid": 1, "tid": tids[s.track],
                       "args": dict(s.args)})
    for f in flows:
        ev = {"name": f["name"], "cat": "flow", "ph": f["ph"],
              "id": int(f["id"]), "ts": float(f["ts"]) * 1e6,
              "pid": 1, "tid": tids[f["track"]]}
        if f["ph"] == "f":
            ev["bp"] = "e"     # bind to the enclosing slice's end
        events.append(ev)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
    return doc


def validate_chrome(doc: Dict[str, Any]) -> int:
    """Validate a trace document against the ``trace_event`` contract
    Perfetto actually enforces; returns the number of ``"X"`` events.

    Raises:
        ValueError: missing keys, non-numeric ts/dur, or negative dur.
    """
    if "traceEvents" not in doc:
        raise ValueError("trace document lacks 'traceEvents'")
    n = 0
    for ev in doc["traceEvents"]:
        for key in ("name", "ph", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"trace event missing {key!r}: {ev}")
        if ev["ph"] == "X":
            n += 1
            if not isinstance(ev.get("ts"), (int, float)) \
                    or not isinstance(ev.get("dur"), (int, float)):
                raise ValueError(f"complete event needs numeric ts/dur: {ev}")
            if ev["dur"] < 0:
                raise ValueError(f"negative duration: {ev}")
        elif ev["ph"] in ("s", "t", "f"):
            if "id" not in ev:
                raise ValueError(f"flow event missing 'id': {ev}")
            if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
                raise ValueError(f"flow event needs numeric ts: {ev}")
    return n
