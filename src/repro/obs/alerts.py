"""Watchtower alerting: SLO burn-rate rules, estimator-drift alarms,
liveness watchdogs, and auto-captured debug bundles.

`AlertEvaluator` polls the flight recorder's event bus incrementally
(by ``seq``, so re-polls never double count), folds completions into its
own `SLOLedger`, and evaluates:

  * **SLO burn rate** — the multi-window rule: the per-label error rate
    over a short AND a long trailing window must BOTH exceed
    ``factor x (1 - goal)`` before paging. The short window makes the
    alert reset fast when the incident ends; the long window keeps a
    brief blip from paging.
  * **Estimator drift** — the measured/calibrated-predicted TTFT/TPOT
    ratio leaves `ResidualCalibration`'s clipped band
    ``[1/ratio_cap, ratio_cap]`` after the calibrator has warmed up
    (fail-closed cold start: no observations, no alarm — matching the
    calibrator's own cold-start contract).
  * **Watchdogs** — event-bus/trace-ring drops (attribution corruption),
    PREPARE tickets stuck outside a terminal state, and starved labels
    (pending submissions with no admission progress).

Every fired alert optionally captures a **debug bundle** — one
deterministic JSON file with the events, spans, metrics, SLO ledger,
and planner state at detection time (`capture_bundle` / `load_bundle` /
`replay_ledger` round-trip). Alerts with a label feed
`WorkloadPlanner.mandatory_fix` / `Autoscaler.mandatory_fix` so
detection closes the loop into reconfiguration instead of waiting out
hysteresis.

Discipline: this module's ``time`` attribute is swapped by
`repro.serving.clock.install_clock` (it is listed in
``CLOCKED_MODULE_NAMES``) and every read is NON-advancing — an
evaluated replay stays bit-identical to an unevaluated one. The
evaluator itself is fail-closed: a crashing rule fires a
``watchtower.error`` alert rather than silently going blind.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time  # swapped for the installed clock by install_clock
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.obs.events import Event, Recorder
from repro.obs.slo import SLOLedger, SLOTargets


def _now() -> float:
    """Non-advancing read of the recording clock (see
    `repro.obs.events.now`)."""
    t = getattr(time, "now", None)
    return time.time() if t is None else t


@dataclasses.dataclass(frozen=True)
class Alert:
    """One fired alert.

    Attributes:
        name: taxonomy name (``"slo.burn_rate"``, ``"estimator.drift"``,
            ``"obs.drops"``, ``"prepare.stuck"``, ``"label.starved"``,
            ``"watchtower.error"``).
        severity: ``"page"`` (SLO at risk / evaluator broken) or
            ``"warn"`` (degraded observability or liveness).
        label / engine: scope ("" when n/a).
        t: detection time, recording-clock seconds.
        value: the measurement that tripped the rule.
        threshold: the rule's trip point.
        message: human-readable summary.
        bundle: debug-bundle path ("" when capture is disabled).
    """

    name: str
    severity: str
    label: str = ""
    engine: str = ""
    t: float = 0.0
    value: float = 0.0
    threshold: float = 0.0
    message: str = ""
    bundle: str = ""


@dataclasses.dataclass(frozen=True)
class BurnRateRule:
    """Multi-window SLO burn-rate rule (per label).

    ``burn = error_rate / (1 - goal)``; pages when the burn over BOTH
    trailing windows exceeds ``factor``. With the defaults a label must
    be missing its SLO >4x faster than its error budget allows, for
    long enough to fill the long window's evidence.
    """

    goal: float = 0.9
    short_s: float = 2.0
    long_s: float = 8.0
    factor: float = 4.0

    @property
    def budget(self) -> float:
        return max(1e-9, 1.0 - self.goal)


def _jsonable(obj: Any) -> Any:
    """Recursively make ``obj`` JSON-safe: non-finite floats -> None,
    mappings key-sorted (byte-deterministic bundles)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj


class AlertEvaluator:
    """Detection loop over a live `Recorder`.

    Args:
        recorder: the flight recorder to watch.
        slo_targets: per-label ``(max_ttft_s, max_tpot_s)`` for the
            internal ledger (or pass ``policy`` with a ``slo_targets``
            attribute).
        window_s: ledger window width (burn windows are multiples).
        burn: the burn-rate rule (None disables SLO burn alerts).
        calibration: the planner's `ResidualCalibration`; enables drift
            alarms (band defaults to its ``ratio_cap``).
        drift_band: override the drift band factor (> 1).
        drift_min_obs: calibration observations per label before drift
            can alarm (fail-closed cold start).
        stuck_prepare_s: seconds a PREPARE ticket may stay non-terminal.
        starve_s: seconds a label may have pending submissions with no
            admission/rejection progress.
        planner / scaler: mandatory-fix targets (optional).
        bundle_dir: when set, every fired alert writes a debug bundle
            here (created on first capture).
    """

    def __init__(self, recorder: Recorder, *,
                 slo_targets: Optional[SLOTargets] = None,
                 policy: Any = None,
                 window_s: float = 1.0,
                 burn: Optional[BurnRateRule] = BurnRateRule(),
                 calibration: Any = None,
                 drift_band: Optional[float] = None,
                 drift_min_obs: int = 3,
                 stuck_prepare_s: float = 10.0,
                 starve_s: float = 10.0,
                 planner: Any = None,
                 scaler: Any = None,
                 bundle_dir: Optional[str] = None):
        if slo_targets is None and policy is not None:
            slo_targets = dict(getattr(policy, "slo_targets", {}) or {})
        self.recorder = recorder
        self.ledger = SLOLedger(slo_targets, window_s=window_s)
        self.burn = burn
        self.calibration = calibration
        if drift_band is None:
            drift_band = float(getattr(calibration, "ratio_cap", 8.0))
        if drift_band <= 1.0:
            raise ValueError(f"drift_band must exceed 1, got {drift_band}")
        self.drift_band = drift_band
        self.drift_min_obs = int(drift_min_obs)
        self.stuck_prepare_s = float(stuck_prepare_s)
        self.starve_s = float(starve_s)
        self.planner = planner
        self.scaler = scaler
        self.bundle_dir = bundle_dir
        self.alerts: List[Alert] = []
        self._next_seq = 0
        #: conditions currently true — an alert fires once per onset
        self._firing: Dict[Tuple[str, str, str], Alert] = {}
        # watchdog state
        self._open_tickets: Dict[str, float] = {}    # engine -> since ts
        self._pending: Dict[str, int] = {}           # label -> waiting
        self._progress_ts: Dict[str, float] = {}     # label -> anchor ts

    # -- ingestion -----------------------------------------------------
    def _ingest(self) -> None:
        for ev in self.recorder.events():
            if ev.seq < self._next_seq:
                continue
            self._next_seq = ev.seq + 1
            self.ledger.observe(ev)
            kind = ev.kind
            if kind == "ticket.preparing":
                self._open_tickets.setdefault(ev.engine, ev.ts)
            elif kind in ("ticket.swapped", "ticket.cancelled",
                          "ticket.failed"):
                self._open_tickets.pop(ev.engine, None)
            elif kind == "request.submit":
                lbl = ev.label or "*"
                if self._pending.get(lbl, 0) == 0:
                    self._progress_ts[lbl] = ev.ts
                self._pending[lbl] = self._pending.get(lbl, 0) + 1
            elif kind in ("request.admit", "request.reject"):
                lbl = ev.label or "*"
                self._pending[lbl] = max(0, self._pending.get(lbl, 0) - 1)
                self._progress_ts[lbl] = ev.ts

    # -- rule evaluation ----------------------------------------------
    def poll(self, t: Optional[float] = None) -> List[Alert]:
        """Ingest new events and evaluate every rule; returns the alerts
        that fired THIS poll (all fired alerts stay in ``self.alerts``).
        Call on the control-tick cadence (the replay harness does)."""
        t = _now() if t is None else float(t)
        fired: List[Alert] = []
        active: Dict[Tuple[str, str, str], Alert] = {}
        try:
            self._ingest()
        except Exception as exc:               # fail closed, loudly
            self._error(active, t, "ingest", exc)
        for check in (self._check_burn, self._check_drops,
                      self._check_stuck_prepare, self._check_starved):
            try:
                check(active, t)
            except Exception as exc:           # fail closed, loudly
                self._error(active, t, check.__name__, exc)
        for key, alert in active.items():
            if key not in self._firing:
                fired.append(self._fire(alert))
        # conditions that cleared may fire again at their next onset;
        # drift alarms are edge-triggered in observe_prediction and
        # clear themselves there
        self._firing = {**{k: v for k, v in self._firing.items()
                           if k[0] == "estimator.drift"}, **active}
        return fired

    def _error(self, active: Dict[Tuple[str, str, str], Alert],
               t: float, where: str, exc: Exception) -> None:
        active[("watchtower.error", where, "")] = Alert(
            "watchtower.error", "page", label=where, t=t,
            message=f"{where}: {exc!r}")

    def _check_burn(self, active: Dict[Tuple[str, str, str], Alert],
                    t: float) -> None:
        if self.burn is None:
            return
        for label in sorted(self.ledger.targets):
            short = self._burn_over(label, t, self.burn.short_s)
            long_ = self._burn_over(label, t, self.burn.long_s)
            if short is None or long_ is None:
                continue
            if short > self.burn.factor and long_ > self.burn.factor:
                active[("slo.burn_rate", label, "")] = Alert(
                    "slo.burn_rate", "page", label=label, t=t,
                    value=min(short, long_), threshold=self.burn.factor,
                    message=(f"{label}: burn {short:.1f}x/"
                             f"{long_:.1f}x budget over "
                             f"{self.burn.short_s:g}s/"
                             f"{self.burn.long_s:g}s windows"))

    def _burn_over(self, label: str, t: float,
                   span_s: float) -> Optional[float]:
        """Error-budget burn multiple over the trailing ``span_s``
        seconds; None when the window scored nothing (no evidence —
        absence of traffic is not an SLO violation)."""
        ok = scored = 0
        for w in self.ledger.windows(label):
            if w.t_end > t - span_s:
                ok += w.ok
                scored += w.scored
        if scored == 0:
            return None
        return ((scored - ok) / scored) / self.burn.budget

    def _check_drops(self, active: Dict[Tuple[str, str, str], Alert],
                     t: float) -> None:
        bus, trace = self.recorder.bus, self.recorder.trace
        dropped = bus.dropped + trace.dropped
        if dropped > 0:
            active[("obs.drops", "", "")] = Alert(
                "obs.drops", "warn", t=t, value=float(dropped),
                threshold=0.0,
                message=(f"recorder dropped {bus.dropped} events + "
                         f"{trace.dropped} spans — attribution and "
                         "ledger windows are no longer complete"))

    def _check_stuck_prepare(self, active: Dict[Tuple[str, str, str], Alert],
                             t: float) -> None:
        for engine in sorted(self._open_tickets):
            age = t - self._open_tickets[engine]
            if age > self.stuck_prepare_s:
                active[("prepare.stuck", "", engine)] = Alert(
                    "prepare.stuck", "warn", engine=engine, t=t,
                    value=age, threshold=self.stuck_prepare_s,
                    message=(f"{engine}: PREPARE ticket non-terminal for "
                             f"{age:.1f}s"))

    def _check_starved(self, active: Dict[Tuple[str, str, str], Alert],
                       t: float) -> None:
        for label in sorted(self._pending):
            if self._pending[label] <= 0:
                continue
            age = t - self._progress_ts.get(label, t)
            if age > self.starve_s:
                active[("label.starved", label, "")] = Alert(
                    "label.starved", "page", label=label, t=t,
                    value=age, threshold=self.starve_s,
                    message=(f"{label}: {self._pending[label]} requests "
                             f"waiting, no admission progress for "
                             f"{age:.1f}s"))

    # -- estimator drift (event-driven: fed by the measurement loop) ---
    def observe_prediction(self, label: str, *,
                           predicted_ttft_s: float,
                           predicted_tpot_s: float,
                           measured_ttft_s: float,
                           measured_tpot_s: float,
                           t: Optional[float] = None) -> Optional[Alert]:
        """Feed one calibrated-prediction/measurement pair (the replay
        harness calls this from its measurement window). Fires
        ``estimator.drift`` when a measured/predicted ratio leaves the
        clipped band — but only after calibration warm-up."""
        t = _now() if t is None else float(t)
        try:
            if self.calibration is not None and \
                    self.calibration.n_observations(label) \
                    < self.drift_min_obs:
                return None            # fail-closed cold start
            worst = 0.0
            for pred, meas in ((predicted_ttft_s, measured_ttft_s),
                               (predicted_tpot_s, measured_tpot_s)):
                if pred is None or meas is None:
                    continue
                if not (math.isfinite(pred) and math.isfinite(meas)) \
                        or pred <= 0 or meas <= 0:
                    continue
                ratio = meas / pred
                worst = max(worst, ratio, 1.0 / ratio)
            key = ("estimator.drift", label, "")
            if worst > self.drift_band:
                if key in self._firing:
                    return None        # still in the same excursion
                alert = Alert(
                    "estimator.drift", "page", label=label, t=t,
                    value=worst, threshold=self.drift_band,
                    message=(f"{label}: measured/predicted ratio "
                             f"{worst:.2f} outside the calibration band "
                             f"[1/{self.drift_band:g}, "
                             f"{self.drift_band:g}]"))
                self._firing[key] = alert
                return self._fire(alert)
            self._firing.pop(key, None)
            return None
        except Exception as exc:       # fail closed, loudly
            active: Dict[Tuple[str, str, str], Alert] = {}
            self._error(active, t, "observe_prediction", exc)
            (key, alert), = active.items()
            if key not in self._firing:
                self._firing[key] = alert
                return self._fire(alert)
            return None

    # -- firing / bundles / mandatory fixes ----------------------------
    def _fire(self, alert: Alert) -> Alert:
        if self.bundle_dir:
            try:
                path = self.capture_bundle(alert)
                alert = dataclasses.replace(alert, bundle=path)
            except Exception as exc:
                alert = dataclasses.replace(
                    alert, message=alert.message
                    + f" [bundle capture failed: {exc!r}]")
        self.alerts.append(alert)
        if alert.label and alert.name in ("slo.burn_rate",
                                          "estimator.drift",
                                          "label.starved"):
            for target in (self.planner, self.scaler):
                if target is None:
                    continue
                try:
                    target.mandatory_fix(alert.label, reason=alert.name)
                except Exception:
                    pass               # detection must outlive actuation
        return alert

    def planner_state(self) -> Dict[str, Any]:
        """JSON-able snapshot of the planner's decision inputs."""
        p = self.planner
        if p is None:
            return {}
        state: Dict[str, Any] = {
            "slo_targets": {k: list(v) for k, v in
                            sorted(getattr(p, "slo_targets", {}).items())},
            "bounds": {k: list(v) for k, v in
                       sorted(getattr(p, "bounds", {}).items())},
        }
        cal = getattr(p, "calibration", None)
        if cal is not None:
            labels = sorted(set(state["slo_targets"])
                            | set(self.ledger.completed()))
            state["calibration"] = {
                lb: {"factors": list(cal.factors(lb)),
                     "n_observations": cal.n_observations(lb)}
                for lb in labels}
        return state

    def capture_bundle(self, alert: Alert,
                       path: Optional[str] = None) -> str:
        """Write one deterministic debug bundle; returns its path.

        The bundle is everything needed to re-derive the detection
        offline: the event stream, the span trace, the metrics
        snapshot, the live ledger's accounting, and the planner state —
        key-sorted JSON with non-finite floats nulled, so two identical
        FakeClock runs produce byte-identical bundles.
        """
        if path is None:
            if not self.bundle_dir:
                raise ValueError("no bundle_dir configured and no path "
                                 "given")
            os.makedirs(self.bundle_dir, exist_ok=True)
            stem = alert.name.replace(".", "-")
            if alert.label:
                stem += f"_{alert.label}"
            if alert.engine:
                stem += f"_{alert.engine}"
            path = os.path.join(
                self.bundle_dir, f"{len(self.alerts):04d}_{stem}.json")
        rec = self.recorder
        bundle = {
            "format": "watchtower-bundle/v1",
            "alert": dataclasses.asdict(alert),
            "events": [dataclasses.asdict(e) for e in rec.events()],
            "spans": [dataclasses.asdict(s) for s in rec.trace.spans()],
            "metrics": rec.snapshot(),
            "slo": self.ledger.as_dict(),
            "planner": self.planner_state(),
        }
        with open(path, "w") as f:
            json.dump(_jsonable(bundle), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    def as_dicts(self) -> List[Dict[str, Any]]:
        return [dataclasses.asdict(a) for a in self.alerts]


# -- bundle round-trip -------------------------------------------------
def load_bundle(path: str) -> Dict[str, Any]:
    """Load a debug bundle written by `AlertEvaluator.capture_bundle`."""
    with open(path) as f:
        bundle = json.load(f)
    if bundle.get("format") != "watchtower-bundle/v1":
        raise ValueError(f"{path}: not a watchtower debug bundle")
    return bundle


def bundle_events(bundle: Mapping[str, Any]) -> List[Event]:
    """Reconstruct the `Event` stream stored in a bundle."""
    return [Event(seq=int(e["seq"]), ts=float(e["ts"]), kind=e["kind"],
                  engine=e.get("engine", ""), rid=int(e.get("rid", -1)),
                  label=e.get("label", ""), data=dict(e.get("data", {})))
            for e in bundle["events"]]


def replay_ledger(bundle: Mapping[str, Any]) -> SLOLedger:
    """Re-derive an `SLOLedger` from a bundle's event stream with the
    bundled targets/window — the round-trip check: its attainment must
    match the bundle's live ``slo`` section."""
    slo = bundle["slo"]
    targets = {k: (v[0], v[1]) for k, v in slo["targets"].items()}
    ledger = SLOLedger(targets, window_s=float(slo["window_s"]))
    ledger.consume(bundle_events(bundle))
    return ledger
