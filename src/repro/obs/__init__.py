"""Flight-recorder observability for the serving stack.

Four pieces behind one handle (`Recorder`):

    events.py   structured event bus — typed `Event`s, timestamped on
                the INSTALLED serving clock (non-advancing reads under
                a `FakeClock`), bounded ring with drop counters;
    trace.py    nested span tracing + Chrome ``trace_event`` exporter
                (open any replay in Perfetto);
    metrics.py  counters / gauges / log-bucketed quantile sketches with
                label sets, plus the `RequestAggregate` that gives
                `ServingCluster.metrics_by_label` O(1) accounting;
    slo.py      SLO/downtime ledger — Φ_L targets + the event stream →
                windowed per-label attainment and an exact "who paid
                this pause" breakdown.

Plus the Watchtower layer on top (PR 10):

    lineage.py  per-request critical-path attribution — `RequestLineage`
                decomposes measured TTFT/TPOT into named components with
                a conservation check and Chrome flow events;
    alerts.py   `AlertEvaluator` — multi-window SLO burn-rate rules,
                estimator-drift alarms, liveness watchdogs, and
                deterministic debug bundles on every fired alert.

Recording is opt-in and zero-overhead when off: the serving stack
guards every hook with ``RECORDER is None``. Enable with::

    from repro.obs import Recorder, recording
    with recording(Recorder()) as rec:
        ...
    rec.export_chrome("run.trace.json")

See docs/observability.md for the event taxonomy and span hierarchy.
"""
from repro.obs.alerts import (
    Alert,
    AlertEvaluator,
    BurnRateRule,
    bundle_events,
    load_bundle,
    replay_ledger,
)
from repro.obs.events import (
    Event,
    EventBus,
    Recorder,
    get_recorder,
    install_recorder,
    now,
    recording,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    RequestAggregate,
)
from repro.obs.lineage import (
    TPOT_COMPONENTS,
    TTFT_COMPONENTS,
    RequestLineage,
    RequestTimeline,
)
from repro.obs.slo import PauseAccount, SLOLedger, WindowAttainment, meets_slo
from repro.obs.trace import (
    Span,
    TraceBuffer,
    export_chrome,
    overlaps,
    validate_chrome,
)

__all__ = [
    "Alert", "AlertEvaluator", "BurnRateRule", "bundle_events",
    "load_bundle", "replay_ledger",
    "Event", "EventBus", "Recorder", "get_recorder", "install_recorder",
    "now", "recording",
    "RequestLineage", "RequestTimeline",
    "TTFT_COMPONENTS", "TPOT_COMPONENTS",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "RequestAggregate",
    "PauseAccount", "SLOLedger", "WindowAttainment", "meets_slo",
    "Span", "TraceBuffer", "export_chrome", "overlaps", "validate_chrome",
]
