"""Fault-tolerant training runner.

Large-scale features, each exercisable on CPU with reduced configs:

  * step-granular async-ish checkpointing (save every k steps, atomic,
    retained history) + restart-from-latest;
  * failure injection -> automatic restart from the last checkpoint
    (optionally onto a REDUCED mesh — elastic continuation after losing a
    pod: the checkpoint loader reshards onto whatever mesh survives);
  * straggler monitor: per-step wall times -> EWMA z-score detection with a
    mitigation hook (at scale: re-balance input shards / evict the host;
    here: recorded + surfaced to the caller);
  * deterministic data restart (the pipeline is a pure function of step).
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import SyntheticLM

PyTree = Any


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time_s: float
    ewma_s: float

    @property
    def slowdown(self) -> float:
        return self.step_time_s / max(self.ewma_s, 1e-9)


class StragglerMonitor:
    """EWMA-based step-time anomaly detection."""

    def __init__(self, threshold: float = 2.0, alpha: float = 0.2):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.flagged: List[StragglerReport] = []

    def observe(self, step: int, dt: float) -> Optional[StragglerReport]:
        if self.ewma is None:
            self.ewma = dt
            return None
        report = None
        if dt > self.threshold * self.ewma:
            report = StragglerReport(step, dt, self.ewma)
            self.flagged.append(report)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return report


class TrainRunner:
    def __init__(self, *, step_fn: Callable, params: PyTree, opt_state: PyTree,
                 dataset: SyntheticLM, ckpt_dir: str | Path,
                 ckpt_every: int = 10,
                 mitigation_hook: Optional[Callable] = None):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.dataset = dataset
        self.ckpt_dir = Path(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.monitor = StragglerMonitor()
        self.mitigation_hook = mitigation_hook
        self.losses: List[float] = []
        self.step = 0
        self.restarts = 0

    # ------------------------------------------------------------------
    def try_restore(self, shardings: Optional[PyTree] = None) -> bool:
        try:
            state_like = {"params": self.params, "opt": self.opt_state}
            step, state = load_checkpoint(self.ckpt_dir, state_like,
                                          shardings=shardings)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = step
            return True
        except FileNotFoundError:
            return False

    def _save(self) -> None:
        save_checkpoint(self.ckpt_dir, self.step,
                        {"params": self.params, "opt": self.opt_state})

    # ------------------------------------------------------------------
    def run(self, n_steps: int, *,
            fail_at: Optional[int] = None,
            slow_steps: Dict[int, float] = {}) -> Dict[str, Any]:
        """Run to `self.step + n_steps`. `fail_at` raises a simulated node
        failure at that step (caller restarts via `recover_and_run`).
        `slow_steps` maps step -> extra seconds (straggler injection)."""
        target = self.step + n_steps
        while self.step < target:
            t0 = time.time()
            batch = self.dataset.batch_at(self.step)
            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"simulated node failure at step {self.step}")
            self.params, self.opt_state, loss, _metrics = self.step_fn(
                self.params, self.opt_state, batch)
            if self.step in slow_steps:
                time.sleep(slow_steps[self.step])
            loss = float(loss)
            self.losses.append(loss)
            dt = time.time() - t0
            rep = self.monitor.observe(self.step, dt)
            if rep is not None and self.mitigation_hook is not None:
                self.mitigation_hook(rep)
            self.step += 1
            if self.step % self.ckpt_every == 0:
                self._save()
        self._save()
        return {"final_loss": self.losses[-1] if self.losses else None,
                "steps": self.step,
                "stragglers": len(self.monitor.flagged),
                "restarts": self.restarts}

    def recover_and_run(self, n_steps_total_target: int,
                        shardings: Optional[PyTree] = None) -> Dict[str, Any]:
        """Checkpoint/restart path after a failure: restore latest, resume."""
        restored = self.try_restore(shardings=shardings)
        if not restored:
            self.step = 0
        self.restarts += 1
        remaining = n_steps_total_target - self.step
        return self.run(max(remaining, 0))
