from repro.runtime.trainer import TrainRunner  # noqa: F401
