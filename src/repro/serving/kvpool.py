"""Paged KV-cache pool: token-granular KV memory for the serving engine.

The slot-granular engine allocates every request a full ``(1, s_max)``
KV extent for its whole lifetime — a 6-token request on an ``s_max=128``
pool wastes 95% of its slot, and admission is bounded by ``n_slots``
regardless of how short the resident requests are. This module replaces
that layout with a vLLM-style paged pool:

    store        one device pytree shaped like ``model.init_cache(
                 n_pages, page_size)`` — each batch row of the tiny pool
                 is one PAGE holding ``page_size`` tokens of every
                 layer's KV. Page 0 is a reserved scratch page (see
                 below); data pages are 1..n_pages-1.
    page table   per active request, the ordered list of physical pages
                 backing its sequence: token position ``t`` of the
                 request lives at row ``table[t // page_size]``, offset
                 ``t % page_size``.
    alloc/free   `PagedKVPool` hands out pages token-granularly:
                 admission reserves ``ceil(need / page_size)`` pages for
                 the request's worst-case extent (prompt + clamped
                 generation budget) and frees them the step the request
                 retires. OOM fails CLOSED — an admission that does not
                 fit (respecting the free-page watermark) leaves the
                 request queued; nothing is evicted, nothing is dropped.

The decode step stays shape-static (the engines' no-JIT-on-the-serving-
path contract): `gather_pages` assembles the active rows' pages into a
dense ``(B, pages_per_seq * page_size)`` cache, the model's unmodified
``decode_step`` runs on it, and `scatter_token` writes the one new KV
entry per row back through the page table. Gather/scatter are fused into
a single jitted (or AOT-compiled) executable by the engine.

Why garbage pages are harmless (the bitwise-identity argument): a page
table row is padded with page 0 beyond the request's reserved extent,
so the gathered dense cache holds scratch/garbage there — but decode
attention masks every position ``>= pos`` by replacing its logit with
``-1e30`` *before* the fp32 softmax (see ``repro.models.attention``), so
masked lanes contribute exactly-zero weight whether the backing memory
holds zeros or a retired request's stale KV. Token streams are therefore
bitwise identical to the slot-granular engine's whenever the dense shape
matches (``s_max`` a multiple of ``page_size``) — the property
`benchmarks/live_migration.py` and tests/test_kvpool.py gate on.

Paging is sound exactly where padded prefill is: every mixer must index
KV by position (attn/MLA). SSM mixers carry recurrent state with no
sequence dim — there is nothing to page — and enc-dec prefill has its
own shape contract; `supports_paging` excludes both, and the engine
falls back to the slot-granular pool for them (fail-closed, never a
silent wrong answer).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

#: batch-axis probe sizes (mirrors `migration.batch_axis_tree`)
_B1, _B2 = 1, 3
#: seq-axis probe sizes — coprime odd sizes that head/rank dims of the
#: reduced configs never collide with on BOTH probes at once
_S1, _S2 = 7, 11

SCRATCH_PAGE = 0


class PoolOOM(RuntimeError):
    """A page allocation does not fit (free pages minus the watermark) —
    the caller must fail closed: leave the request queued, change
    nothing."""


def supports_paging(model) -> bool:
    """Whether the model's KV cache can be paged: every layer's cache
    must be positional (attn/MLA) — SSM recurrent state has no sequence
    dim to page, and enc-dec caches have a second (encoder) sequence
    contract. Mirrors `ServingEngine.supports_padded_prefill`."""
    cfg = model.cfg
    if cfg.encdec is not None:
        return False
    from repro.models.lm import layer_kinds   # local: avoid cycles
    return all(mixer in ("attn", "mla") for mixer, _ in layer_kinds(cfg))


def page_axes(model) -> Tuple[PyTree, PyTree]:
    """Per-leaf ``(page_axis, seq_axis)`` trees of the model's cache
    layout, probed via ``Model.cache_shapes`` (eval_shape — no device
    work). The page axis is the init_cache batch axis (each page is one
    batch row of a ``page_size``-long pool); the sequence axis must sit
    immediately after it for the gather's reshape-merge to be a view.

    Raises:
        ValueError: a leaf has no batch or no sequence axis, or they are
            not adjacent — the model cannot be paged (see
            `supports_paging`).
    """
    b1 = model.cache_shapes(_B1, _S1)
    b2 = model.cache_shapes(_B2, _S1)
    s2 = model.cache_shapes(_B1, _S2)

    def find(a, b, lo, hi):
        for ax in range(a.ndim):
            if a.shape[ax] == lo and b.shape[ax] == hi:
                return ax
        return -1

    pax = jax.tree.map(lambda a, b: find(a, b, _B1, _B2), b1, b2)
    sax = jax.tree.map(lambda a, b: find(a, b, _S1, _S2), b1, s2)

    def check(p, s, leaf):
        if p < 0 or s < 0 or s != p + 1:
            raise ValueError(
                f"cache leaf {leaf.shape} has no pageable (batch, seq) "
                f"axis pair (batch={p}, seq={s}) — this model cannot be "
                "paged (SSM/enc-dec state); use the slot-granular pool")
        return p

    jax.tree.map(check, pax, sax, b1)
    return pax, sax


class PagedKVPool:
    """Token-granular page allocator over one device KV store.

    The pool owns the *bookkeeping* — free list, watermark, per-token
    accounting; the device store it creates (`init_store`) lives on the
    engine as ``engine.cache`` so the existing lifecycle (drain /
    swap_plan device_put / donation through the decode executable) works
    unchanged.

    Args:
        page_size: tokens per page.
        n_pages: DATA pages (the scratch page is allocated on top, so
            the store batch dim is ``n_pages + 1``).
        watermark: free pages an admission must leave behind — headroom
            reserved for in-flight migrations and import bursts. An
            `alloc` that would dip below it raises `PoolOOM` (the
            fail-closed admission gate).
    """

    def __init__(self, page_size: int, n_pages: int, *, watermark: int = 0):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        if watermark < 0 or watermark >= n_pages:
            raise ValueError(
                f"watermark must be in [0, n_pages), got {watermark} "
                f"(n_pages={n_pages})")
        self.page_size = page_size
        self.n_pages = n_pages
        self.watermark = watermark
        # LIFO free list: recently-freed pages are re-used first (their
        # store rows are the warmest)
        self._free: List[int] = list(range(n_pages, 0, -1))

    # -- store ---------------------------------------------------------
    @property
    def store_batch(self) -> int:
        """Batch dim of the device store (data pages + the scratch page)."""
        return self.n_pages + 1

    def init_store(self, model, dtype=jnp.bfloat16) -> PyTree:
        """Build the device store: ``model.init_cache(n_pages + 1,
        page_size)`` — one batch row per page, page 0 scratch."""
        return model.init_cache(self.store_batch, self.page_size, dtype=dtype)

    # -- accounting ----------------------------------------------------
    @property
    def free_pages(self) -> int:
        """Pages currently unallocated (including watermark headroom)."""
        return len(self._free)

    @property
    def admittable_pages(self) -> int:
        """Pages an admission may take without dipping below the
        watermark (migration imports use `alloc(..., reserve=True)` to
        spend the watermark itself)."""
        return max(len(self._free) - self.watermark, 0)

    @property
    def allocated_tokens(self) -> int:
        """Token capacity currently reserved by live requests."""
        return (self.n_pages - len(self._free)) * self.page_size

    def pages_for(self, tokens: int) -> int:
        """Pages needed to back ``tokens`` KV entries."""
        return max(math.ceil(tokens / self.page_size), 1)

    # -- alloc / free --------------------------------------------------
    def alloc(self, n: int, *, reserve: bool = False) -> List[int]:
        """Take ``n`` pages off the free list.

        Args:
            n: pages to allocate.
            reserve: spend the watermark headroom too (migration imports
                — the headroom exists exactly for them); plain admission
                keeps it free.

        Returns:
            The allocated page ids (never `SCRATCH_PAGE`).

        Raises:
            PoolOOM: the pool cannot supply ``n`` pages — nothing is
                allocated (fail closed).
        """
        budget = self.free_pages if reserve else self.admittable_pages
        if n > budget:
            raise PoolOOM(
                f"need {n} pages but only {budget} admittable "
                f"({self.free_pages} free, watermark={self.watermark}, "
                f"n_pages={self.n_pages}) — failing closed")
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: Sequence[int]) -> None:
        """Return pages to the free list.

        Raises:
            ValueError: a page is out of range, the scratch page, or
                already free (double-free — a page-table bookkeeping bug
                that must not be silently absorbed).
        """
        freeing = set(pages)
        if len(freeing) != len(pages):
            raise ValueError(f"duplicate pages in free(): {sorted(pages)}")
        live = set(self._free)
        for p in pages:
            if not 1 <= p <= self.n_pages:
                raise ValueError(f"page {p} out of range [1, {self.n_pages}]")
            if p in live:
                raise ValueError(f"double free of page {p}")
        self._free.extend(pages)


# ---------------------------------------------------------------------------
# gather / scatter (shape-static pytree ops over the page store)
# ---------------------------------------------------------------------------


def gather_pages(store: PyTree, tables: jnp.ndarray,
                 pax: PyTree, sax: PyTree) -> PyTree:
    """Assemble a dense ``(B, pages_per_seq * page_size)`` cache from the
    page store: per leaf, row ``b``'s sequence is the concatenation of
    pages ``tables[b, :]`` (scratch-padded rows gather garbage beyond
    the reserved extent — masked by decode, see the module docstring).

    Args:
        store: the page-store pytree (batch dim = pages).
        tables: ``(B, pages_per_seq)`` int32 physical page ids.
        pax / sax: per-leaf page/seq axis trees (see `page_axes`).
    """
    B, npp = tables.shape

    def one(leaf, p, s):
        g = jnp.take(leaf, tables.reshape(-1), axis=p)
        # (…, B*npp, page_size, …) -> (…, B, npp*page_size, …): the page
        # and seq axes are adjacent (checked by page_axes), so this
        # merge is a reshape of contiguous dims
        shape = (leaf.shape[:p] + (B, npp * leaf.shape[s])
                 + leaf.shape[s + 1:])
        return g.reshape(shape)

    return jax.tree.map(one, store, pax, sax)


def scatter_token(store: PyTree, dense: PyTree, tables: jnp.ndarray,
                  pos: jnp.ndarray, pax: PyTree, sax: PyTree) -> PyTree:
    """Write each row's newest KV entry (position ``pos[b]`` of the
    dense cache) back into its page: physical page ``tables[b, pos[b] //
    page_size]``, offset ``pos[b] % page_size``. Rows whose table entry
    is the scratch page (inactive lanes) write garbage into page 0 —
    harmless by construction.
    """

    def one(leaf, d, p, s):
        ps = leaf.shape[s]
        idx = pos // ps                                   # (B,) page slot
        phys = jnp.take_along_axis(tables, idx[:, None], axis=1)[:, 0]
        off = pos % ps                                    # (B,) in-page
        # each row's entry at its own pos: the index lives on the PAGE
        # (row) axis and selects one seq position per row
        sel = pos.reshape((1,) * p + (-1,) + (1,) * (d.ndim - p - 1))
        tok = jnp.take_along_axis(d, sel, axis=s)         # seq dim -> 1
        tok = jnp.squeeze(tok, axis=s)
        ix = (slice(None),) * p + (phys, off)
        return leaf.at[ix].set(tok.astype(leaf.dtype))

    return jax.tree.map(one, store, dense, pax, sax)


def write_pages(store: PyTree, single: PyTree, pages: Sequence[int],
                pax: PyTree, sax: PyTree) -> PyTree:
    """Write a single-sequence cache (batch dim 1 — a prefill result or
    a fitted migration snapshot) into the store at ``pages``: the seq
    dim is padded/truncated to ``len(pages) * page_size``, split into
    page-sized rows, and scattered. Entries of ``pages`` equal to
    `SCRATCH_PAGE` absorb the slack (import writes full-width tables
    whose tail is scratch — shape-static, one compiled op).
    """
    pages_arr = jnp.asarray(pages, jnp.int32)
    n = len(pages)

    def one(leaf, c, p, s):
        ps = leaf.shape[s]
        target = n * ps
        if c.shape[s] > target:
            c = jax.lax.slice_in_dim(c, 0, target, axis=s)
        elif c.shape[s] < target:
            pad = [(0, 0)] * c.ndim
            pad[s] = (0, target - c.shape[s])
            c = jnp.pad(c, pad)
        # (…, 1, n*ps, …) -> (…, n, ps, …): batch(=1) and seq axes merge
        shape = c.shape[:p] + (n, ps) + c.shape[s + 1:]
        c = c.reshape(shape).astype(leaf.dtype)
        ix = (slice(None),) * p + (pages_arr,)
        return leaf.at[ix].set(c)

    return jax.tree.map(one, store, single, pax, sax)


def make_paged_decode(model, pax: PyTree, sax: PyTree):
    """The fused paged decode step (one jittable function — the engine's
    AOT unit): gather the active rows' pages into a dense cache, run the
    model's unmodified ``decode_step``, scatter the one new token per
    row back through the page tables.

    Signature (cache at position 2, matching the slot engine's
    ``donate_argnums=(2,)`` contract so the store is donated through
    every step): ``(params, tokens (B,1), store, pos (B,), tables
    (B, pages_per_seq)) -> (logits, new_store)``.
    """

    def paged_decode(params, tokens, store, pos, tables):
        dense = gather_pages(store, tables, pax, sax)
        logits, dense = model.decode_step(params, tokens, dense, pos)
        return logits, scatter_token(store, dense, tables, pos, pax, sax)

    return paged_decode
