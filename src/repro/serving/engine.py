"""Continuous-batching serving engine with per-request TTFT/TPOT metrics.

Slot-based decode batching: a fixed (B, S_max) KV pool; requests prefill
into a free slot and decode step-locked with the rest of the batch (the
standard TPU serving shape — static shapes, no re-compilation per request).

Privacy intents attach *labels* to requests (e.g. data-type=phi); the
orchestration layer maps labeled requests to engines whose ShardingPlan
carries the matching device constraints, and the validator checks the
engine's compiled HLO against the routing constraints.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model

PyTree = Any


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S_prompt,) int32
    max_new_tokens: int = 16
    labels: Dict[str, str] = dataclasses.field(default_factory=dict)
    # metrics
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    tokens_out: List[int] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> float:
        return self.t_first - self.t_submit

    @property
    def tpot(self) -> float:
        n = max(len(self.tokens_out) - 1, 1)
        return (self.t_done - self.t_first) / n


class ServingEngine:
    """Single-model engine; decode batch of `n_slots` sequences."""

    def __init__(self, model: Model, params: PyTree, *, n_slots: int = 4,
                 s_max: int = 128, greedy: bool = True):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.s_max = s_max
        self.greedy = greedy
        self.vocab = model.cfg.vocab_size

        self.cache = model.init_cache(n_slots, s_max)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.steps = 0
        # jitted single-sequence prefill + batched decode
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.t_submit = time.time()
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _admit(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.pop(0)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            batch = {"tokens": prompt}
            if self.model.cfg.pos_type == "mrope":
                S = prompt.shape[1]
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(S, dtype=jnp.int32)[None, None], (3, 1, S))
            logits, cache1 = self._prefill(self.params, batch)
            tok = int(jnp.argmax(logits[0, : self.vocab]))
            req.tokens_out.append(tok)
            req.t_first = time.time()
            # merge the single-sequence cache into the slot pool
            self.cache = _write_slot(self.cache, cache1, slot,
                                     prompt.shape[1], self.s_max)
            self.slot_req[slot] = req
            self.slot_pos[slot] = prompt.shape[1]

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode step over all active slots. Returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.n_slots, 1), dtype=np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].tokens_out[-1]
        # per-slot positions (inactive slots write harmlessly at index 0 —
        # their slot is re-prefilled before reuse)
        pos = jnp.asarray(self.slot_pos, dtype=jnp.int32)
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, pos)
        logits = np.asarray(logits[:, : self.vocab])
        now = time.time()
        for i in active:
            req = self.slot_req[i]
            tok = int(np.argmax(logits[i]))
            req.tokens_out.append(tok)
            self.slot_pos[i] += 1
            if (len(req.tokens_out) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.s_max - 1):
                req.t_done = now
                self.done.append(req)
                self.slot_req[i] = None
                self.slot_pos[i] = 0
        self.steps += 1
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.steps < max_steps:
            self.step()

    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, float]:
        if not self.done:
            return {"completed": 0}
        ttfts = [r.ttft for r in self.done]
        tpots = [r.tpot for r in self.done]
        return {
            "completed": len(self.done),
            "ttft_mean_s": float(np.mean(ttfts)),
            "ttft_p99_s": float(np.percentile(ttfts, 99)),
            "tpot_mean_s": float(np.mean(tpots)),
            "tpot_p99_s": float(np.percentile(tpots, 99)),
        }


def _write_slot(pool: PyTree, single: PyTree, slot: int, prompt_len: int,
                s_max: int) -> PyTree:
    """Write a 1-sequence prefill cache into batch slot `slot` of the pool."""

    def one(p, c):
        # locate batch dim: first dim where pool==n_slots and cache==1
        for ax in range(min(p.ndim, c.ndim)):
            if p.shape[ax] != c.shape[ax] and c.shape[ax] == 1:
                batch_ax = ax
                break
        else:
            return p
        # seq dims may differ (prompt_len vs s_max): pad cache to pool shape
        pads = []
        for ax in range(p.ndim):
            if ax == batch_ax:
                pads.append((0, 0))
            else:
                pads.append((0, p.shape[ax] - c.shape[ax]))
        c_pad = jnp.pad(c.astype(p.dtype), pads)
        idx = [slice(None)] * p.ndim
        idx[batch_ax] = slice(slot, slot + 1)
        return p.at[tuple(idx)].set(c_pad)

    return jax.tree.map(one, pool, single)
