"""Continuous-batching serving engine with per-request TTFT/TPOT metrics
and an explicit reconfiguration lifecycle.

Slot-based decode batching: a fixed (B, S_max) KV pool; requests prefill
into a free slot and decode step-locked with the rest of the batch (the
standard TPU serving shape — static shapes, no re-compilation per request).

Privacy intents attach *labels* to requests (e.g. data-type=phi); the
`ServingCluster` (repro.serving.cluster) maps labeled requests to engines
whose `ShardingPlan` carries the matching device constraints, and the
validator checks the engine's compiled HLO against the routing constraints.

Lifecycle (the public swap protocol — no private-attribute mutation):

    engine.pause()                    # stop stepping; submissions still queue
    engine.drain()                    # block until in-flight device work done
    engine.swap_plan(plan,            # migrate params/cache, install
                     shardings=...,   #   AOT executables compiled ahead of
                     executables=...) #   time (the swap window never compiles)
    engine.resume()

AOT executables come from `aot_executables()`: decode is fully static
(n_slots, 1) so one executable covers it; prefill is compiled per prompt
length (the engine records lengths it has seen so a reconfiguration can
pre-compile exactly the live traffic shapes).
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.obs import events as obs_events
from repro.serving import kvpool, migration
from repro.serving.migration import MigrationError, SlotSnapshot
from repro.sharding.plan import ShardingPlan, default_plan

PyTree = Any

METRIC_KEYS = ("completed", "ttft_mean_s", "ttft_p99_s",
               "tpot_mean_s", "tpot_p99_s")


class EngineStateError(RuntimeError):
    """Raised when a lifecycle method is called in the wrong state."""


@dataclasses.dataclass
class Request:
    """One generation request flowing through an engine.

    Attributes:
        rid: caller-chosen request id (metrics/bookkeeping only).
        prompt: ``(S_prompt,)`` int32 token ids.
        max_new_tokens: decode budget; generation also stops at the KV
            pool's sequence capacity.
        labels: tenancy labels (e.g. ``{"data-type": "phi"}``) — the
            cluster routes and aggregates on these.
        t_submit / t_first / t_done: wall-clock stamps set by the engine
            at submission, first token, and completion.
        tokens_out: generated token ids (first entry comes from prefill).
    """

    rid: int
    prompt: np.ndarray                 # (S_prompt,) int32
    max_new_tokens: int = 16
    labels: Dict[str, str] = dataclasses.field(default_factory=dict)
    # metrics
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    tokens_out: List[int] = dataclasses.field(default_factory=list)

    @property
    def ttft(self) -> float:
        """Time to first token (seconds): first-token stamp - submit."""
        return self.t_first - self.t_submit

    @property
    def tpot(self) -> float:
        """Mean time per output token (seconds) over the decode phase."""
        n = max(len(self.tokens_out) - 1, 1)
        return (self.t_done - self.t_first) / n


def compute_metrics(done: Sequence[Request]) -> Dict[str, float]:
    """TTFT/TPOT summary over a set of completed requests.

    Args:
        done: completed requests (``t_done`` set); any iterable window.

    Returns:
        Always the full `METRIC_KEYS` set — ``completed`` plus mean/p99
        TTFT and TPOT, with NaN for undefined statistics — so callers can
        index unconditionally (an empty window is a value, not a missing
        key).
    """
    out: Dict[str, float] = {
        "completed": len(done),
        "ttft_mean_s": math.nan, "ttft_p99_s": math.nan,
        "tpot_mean_s": math.nan, "tpot_p99_s": math.nan,
    }
    if done:
        ttfts = [r.ttft for r in done]
        tpots = [r.tpot for r in done]
        out.update(
            ttft_mean_s=float(np.mean(ttfts)),
            ttft_p99_s=float(np.percentile(ttfts, 99)),
            tpot_mean_s=float(np.mean(tpots)),
            tpot_p99_s=float(np.percentile(tpots, 99)),
        )
    return out


class ServingEngine:
    """Single-model engine; decode batch of `n_slots` sequences.

    Two KV memory layouts (see `repro.serving.kvpool`):

      * **paged** (default for attn/MLA models): KV lives in a
        `PagedKVPool` of fixed-size pages; admission is token-granular
        (a request reserves ``ceil(need / page_size)`` pages for its
        worst-case extent and frees them on retirement, failing CLOSED
        when the pool is out of pages) and active requests are packed
        into the decode batch each step — a request owns pages, not a
        lane, so ``n_slots`` is purely the decode width.
      * **slot-granular** (SSM/enc-dec models, or ``paged=False``): the
        original fixed ``(n_slots, s_max)`` pool; a request pins one
        slot for its lifetime.

    Token streams are bitwise identical between the two layouts (decode
    masks every position beyond the write cursor before the softmax, so
    page-granule garbage can never leak into a logit).

    Args:
        model: the `repro.models.Model` to serve.
        params: its parameter pytree (device arrays).
        n_slots: continuous-batching width (decode batch dim).
        s_max: KV sequence capacity per request.
        greedy: greedy sampling (the only mode currently implemented).
        plan: initial `ShardingPlan`; `default_plan()` when omitted.
        labels: tenancy labels. Under cluster routing an engine label
            only EXCLUDES requests that carry a contradicting value: an
            engine labeled ``{"data-type": "phi"}`` never receives
            ``data-type=general`` traffic, but requests without the label
            can still land on it. An unlabeled engine serves all.
        paged: force the paged pool on/off; ``None`` auto-selects
            (paged wherever `kvpool.supports_paging` holds).
        page_size: tokens per KV page (paged mode; clamped to
            ``s_max``).
        kv_tokens: token capacity of the paged pool (admission budget).
            Defaults to ``n_slots * ceil(s_max/page_size) * page_size``
            — the slot-granular pool's capacity in page units — so the
            default paged engine never admits less than the slot engine
            would. Benchmarks decouple it from ``n_slots`` to trade
            decode width against memory.
        watermark: free pages admissions must leave behind (headroom
            for migration imports, which may spend it); allocated ON TOP
            of ``kv_tokens``, so the admission budget is unaffected.
        role: serving role under disaggregated prefill/decode placement:
            ``"unified"`` (default — serves a request end to end),
            ``"prefill"`` (receives new requests; the cluster hands each
            one off to a decode engine at its first-token boundary) or
            ``"decode"`` (never routed new requests; receives in-flight
            work via migration). The engine itself serves identically in
            every role — the role only steers cluster routing/handoff.
    """

    ROLES = ("unified", "prefill", "decode")

    # cap on the prompt-length fallback set `aot_executables` compiles for:
    # a long-lived engine sees unboundedly many distinct lengths, but only
    # the most recent ones predict live traffic
    MAX_AOT_PREFILL = 8
    # smallest padded-prefill bucket (powers of two up to s_max are
    # compiled when `aot_executables(..., prefill_buckets=True)`)
    BUCKET_MIN = 8

    def __init__(self, model: Model, params: PyTree, *, n_slots: int = 4,
                 s_max: int = 128, greedy: bool = True,
                 plan: Optional[ShardingPlan] = None,
                 labels: Optional[Dict[str, str]] = None,
                 paged: Optional[bool] = None, page_size: int = 16,
                 kv_tokens: Optional[int] = None, watermark: int = 0,
                 role: str = "unified"):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.s_max = s_max
        self.greedy = greedy
        self.vocab = model.cfg.vocab_size
        self.plan = plan or default_plan()
        self.labels = dict(labels or {})
        self.role = role
        # display name for flight-recorder events/spans; the cluster
        # sets it to the registered engine name
        self.obs_name = ""

        self.paged = (kvpool.supports_paging(model) if paged is None
                      else bool(paged))
        if self.paged and paged and not kvpool.supports_paging(model):
            raise ValueError("model has non-positional cache state "
                             "(SSM/enc-dec) — it cannot be paged")
        if self.paged:
            self.page_size = min(page_size, s_max)
            self.pages_per_seq = -(-s_max // self.page_size)
            if kv_tokens is None:
                kv_tokens = n_slots * self.pages_per_seq * self.page_size
            self.pool: Optional[kvpool.PagedKVPool] = kvpool.PagedKVPool(
                self.page_size,
                -(-kv_tokens // self.page_size) + watermark,
                watermark=watermark)
            self._pax, self._sax = kvpool.page_axes(model)
            self.cache = self.pool.init_store(model)
            # per-lane page tables (scratch-padded to pages_per_seq) and
            # the owned-page lists the allocator accounting tracks
            self.page_tables = np.full((n_slots, self.pages_per_seq),
                                       kvpool.SCRATCH_PAGE, dtype=np.int32)
            self.slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
            # device-side mirror of page_tables, re-uploaded only when
            # the host copy changes (tables are stable across pure-decode
            # steps, so steady-state decode pays no host->device transfer)
            self._tables_dev: Optional[jnp.ndarray] = None
            self._paged_fn = kvpool.make_paged_decode(model, self._pax,
                                                      self._sax)
        else:
            self.pool = None
            self.cache = model.init_cache(n_slots, s_max)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.steps = 0
        self.paused = False
        self.seen_prompt_lengths: Dict[int, int] = {}   # length -> last seq
        self._submit_seq = 0
        # jitted single-sequence prefill + batched decode (JIT fallbacks);
        # AOT executables, when installed via swap_plan, take precedence
        self._prefill = jax.jit(model.prefill)
        self._decode = (jax.jit(self._paged_fn, donate_argnums=(2,))
                        if self.paged
                        else jax.jit(model.decode_step, donate_argnums=(2,)))
        self._prefill_exec: Dict[int, Callable] = {}
        self._decode_exec: Optional[Callable] = None
        # padded-bucket prefill executables: an unseen prompt length pads
        # to the smallest bucket >= its length instead of JIT-compiling
        self._bucket_exec: Dict[int, Callable] = {}
        self._bucket_lengths: List[int] = []
        # migration-path caches: the per-leaf batch axis of the KV pool is
        # a property of (model, s_max) — constant for the engine's life
        self._batch_axes: Optional[PyTree] = None
        self._migration_warm = False
        # guards executable installation vs the serving path's executable
        # selection: a background PREPARE may commit (swap_plan) from a
        # control thread while step()/_admit() pick executables
        self._exec_lock = threading.Lock()

    @property
    def role(self) -> str:
        """Disaggregation role (``"unified"``/``"prefill"``/``"decode"``);
        assignment validates fail-closed — an engine with a mistyped role
        would silently fall out of (or into) the routing pool."""
        return self._role

    @role.setter
    def role(self, value: str) -> None:
        if value not in self.ROLES:
            raise ValueError(f"unknown engine role {value!r} "
                             f"(expected one of {self.ROLES})")
        self._role = value

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def pause(self) -> None:
        """Stop stepping. Submissions still queue; nothing is dropped.
        Idempotent; `step()` raises `EngineStateError` while paused."""
        self.paused = True

    def drain(self) -> int:
        """Block until all in-flight device work has retired.

        Returns the number of requests still resident in slots (they resume
        decoding after `resume()` — drain is a device-level barrier, not an
        eviction)."""
        jax.block_until_ready(jax.tree.leaves(self.cache))
        jax.block_until_ready(jax.tree.leaves(self.params))
        return sum(r is not None for r in self.slot_req)

    def swap_plan(self, plan: Optional[ShardingPlan] = None, *,
                  shardings: Optional[Dict[str, Any]] = None,
                  executables: Optional[Dict[str, Any]] = None) -> int:
        """Install a new plan: migrate params/cache onto `shardings` and
        swap in pre-compiled `executables`. Must be called paused — this is
        the blocking window and it performs NO compilation.

        Args:
            plan: the new `ShardingPlan` to record on the engine (routing
                reads it); ``None`` keeps the current plan.
            shardings: ``{"params": sharding tree, "cache": sharding
                tree}`` to `jax.device_put` the live state onto; AOT
                executables compiled for the old layout are invalidated.
            executables: ``{"prefill": callable | {prompt_len: AOT
                executable}, "decode": callable | AOT executable,
                "prefill_buckets": {bucket_len: AOT executable}}`` — a
                plain callable replaces the JIT fallback; an AOT
                dict/executable is installed ahead of the fallback;
                bucket executables serve unseen prompt lengths padded to
                the bucket (see `aot_executables`).

        Returns:
            The number of bytes migrated (0 without ``shardings``).

        Raises:
            EngineStateError: if the engine is not paused.
        """
        if not self.paused:
            raise EngineStateError("swap_plan requires a paused engine "
                                   "(call pause(); drain() first)")
        migrated = 0
        if shardings is not None:
            migrated = _tree_bytes(self.params) + _tree_bytes(self.cache)
            if "params" in shardings:
                self.params = jax.device_put(self.params, shardings["params"])
            if "cache" in shardings:
                self.cache = jax.device_put(self.cache, shardings["cache"])
            jax.block_until_ready(jax.tree.leaves(self.params))
            jax.block_until_ready(jax.tree.leaves(self.cache))
            with self._exec_lock:
                # executables compiled for the old layout are stale
                self._prefill_exec = {}
                self._decode_exec = None
                self._bucket_exec = {}
                self._bucket_lengths = []
            self._migration_warm = False   # pool-surgery ops too
            if self.paged:
                self._tables_dev = None    # re-place beside the new cache
        if executables:
            with self._exec_lock:
                pf = executables.get("prefill")
                if isinstance(pf, dict):
                    self._prefill_exec = dict(pf)
                elif pf is not None:
                    self._prefill = pf
                    self._prefill_exec = {}
                bk = executables.get("prefill_buckets")
                if bk is not None:
                    self._bucket_exec = dict(bk)
                    self._bucket_lengths = sorted(self._bucket_exec)
                de = executables.get("decode")
                if isinstance(de, jax.stages.Compiled):
                    self._decode_exec = de
                elif de is not None:      # a jit-wrapped callable: replace
                    self._decode = de     # the fallback outright
                    self._decode_exec = None
        if plan is not None:
            self.plan = plan
        return migrated

    def resume(self) -> None:
        """Leave the paused state and serve again (idempotent)."""
        self.paused = False

    # ------------------------------------------------------------------
    # AOT compilation (PREPARE phase — runs while serving continues)
    # ------------------------------------------------------------------
    def supports_padded_prefill(self) -> bool:
        """Whether bucket-padded prefill is sound for this model: every
        mixer must be attention-style (causal attention never reads the
        padding; positions < ``true_len`` are bit-exact). SSM mixers fold
        the WHOLE padded sequence into their recurrent state, and enc-dec
        prefill has its own shape contract — both are excluded."""
        cfg = self.model.cfg
        if cfg.encdec is not None:
            return False
        from repro.models.lm import layer_kinds   # local: avoid cycles
        return all(mixer in ("attn", "mla") for mixer, _ in layer_kinds(cfg))

    def recent_prompt_lengths(self, cap: Optional[int] = None
                              ) -> Tuple[int, ...]:
        """Snapshot of the most recently seen distinct prompt lengths
        (at most ``cap``, default `MAX_AOT_PREFILL`), sorted ascending.

        A SNAPSHOT, not a live view: safe to hand to a background PREPARE
        thread while request threads keep recording new lengths."""
        cap = cap or self.MAX_AOT_PREFILL
        seen = dict(self.seen_prompt_lengths)    # atomic copy under the GIL
        return tuple(sorted(sorted(seen, key=seen.get)[-cap:]))

    def bucket_lengths(self) -> List[int]:
        """The padded-prefill bucket ladder: powers of two from
        `BUCKET_MIN` up to (and always including) ``s_max``. Empty when
        the model cannot be padded (see `supports_padded_prefill`)."""
        if not self.supports_padded_prefill():
            return []
        out: List[int] = []
        b = self.BUCKET_MIN
        while b < self.s_max:
            out.append(b)
            b *= 2
        out.append(self.s_max)
        return out

    def aot_executables(self, shardings: Dict[str, Any],
                        prefill_lengths: Sequence[int] = (), *,
                        prefill_buckets: bool = False,
                        ) -> Tuple[Dict[str, Any], int]:
        """Ahead-of-time compile decode (and prefill per prompt length)
        against the target `shardings`, via .lower().compile().

        Args:
            shardings: the target ``{"params": ..., "cache": ...}``
                sharding trees (see `plan_to_shardings`).
            prefill_lengths: prompt lengths to compile prefill for; when
                empty, falls back to the engine's most recently seen
                lengths (capped at `MAX_AOT_PREFILL`).
            prefill_buckets: also compile padded-bucket prefill
                executables (`bucket_lengths`) that take a ``true_len``
                argument, so prompt lengths never seen before ALSO avoid
                the JIT fallback on the serving path — an unseen length
                pads to the smallest bucket that holds it. No-op for
                models where padding is unsound (SSM/enc-dec).

        Returns:
            ``(executables, n_compiled)`` in the shape `swap_plan`
            accepts, so the blocking swap window installs finished
            executables only.
        """
        sds = jax.ShapeDtypeStruct
        p_sds = jax.tree.map(lambda x, s: sds(x.shape, x.dtype, sharding=s),
                             self.params, shardings["params"])
        c_sds = jax.tree.map(lambda x, s: sds(x.shape, x.dtype, sharding=s),
                             self.cache, shardings["cache"])
        tok_sds = sds((self.n_slots, 1), jnp.int32)
        pos_sds = sds((self.n_slots,), jnp.int32)
        if self.paged:
            tbl_sds = sds((self.n_slots, self.pages_per_seq), jnp.int32)
            decode = jax.jit(self._paged_fn, donate_argnums=(2,)) \
                .lower(p_sds, tok_sds, c_sds, pos_sds, tbl_sds).compile()
        else:
            decode = jax.jit(self.model.decode_step, donate_argnums=(2,)) \
                .lower(p_sds, tok_sds, c_sds, pos_sds).compile()
        n_compiled = 1

        def batch_sds(S: int, padded: bool) -> Dict[str, Any]:
            b = {"tokens": sds((1, S), jnp.int32)}
            if padded:
                b["true_len"] = sds((), jnp.int32)
            if self.model.cfg.pos_type == "mrope":
                b["positions"] = sds((3, 1, S), jnp.int32)
            return b

        prefill: Dict[int, Callable] = {}
        if prefill_lengths:
            lengths = sorted(set(prefill_lengths))
        else:
            # most recently seen distinct lengths, capped (see MAX_AOT_PREFILL)
            lengths = list(self.recent_prompt_lengths())
        for S in lengths:
            prefill[S] = jax.jit(self.model.prefill) \
                .lower(p_sds, batch_sds(S, padded=False)).compile()
            n_compiled += 1
        buckets: Dict[int, Callable] = {}
        if prefill_buckets:
            for S in self.bucket_lengths():
                buckets[S] = jax.jit(self.model.prefill) \
                    .lower(p_sds, batch_sds(S, padded=True)).compile()
                n_compiled += 1
        return {"prefill": prefill, "decode": decode,
                "prefill_buckets": buckets}, n_compiled

    def decode_hlo_text(self) -> str:
        """Post-compile HLO of the decode step, for compiled-artifact
        validation (`ServingCluster` checks registered engines' HLO
        against route constraints, not just their declared plans).

        Reuses the installed AOT executable when present; otherwise
        compiles decode once for the live layout and installs it, so the
        check never forces a later JIT on the serving path."""
        with self._exec_lock:
            exec_ = self._decode_exec
        if exec_ is None:
            tok = jax.ShapeDtypeStruct((self.n_slots, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((self.n_slots,), jnp.int32)
            if self.paged:
                tbl = jax.ShapeDtypeStruct(
                    (self.n_slots, self.pages_per_seq), jnp.int32)
                exec_ = jax.jit(self._paged_fn, donate_argnums=(2,)) \
                    .lower(self.params, tok, self.cache, pos, tbl).compile()
            else:
                exec_ = jax.jit(self.model.decode_step,
                                donate_argnums=(2,)) \
                    .lower(self.params, tok, self.cache, pos).compile()
            with self._exec_lock:
                if self._decode_exec is None:
                    self._decode_exec = exec_
        return exec_.as_text()

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue a request (stamps ``t_submit``; records its prompt
        length for future AOT prefill compilation). Works while paused —
        the request waits for `resume()`."""
        req.t_submit = time.time()
        self.note_prompt_length(len(req.prompt))
        self.queue.append(req)
        rec = obs_events.RECORDER
        if rec is not None:
            rec.emit("request.submit", engine=self.obs_name, rid=req.rid,
                     label=req.labels.get("data-type", ""),
                     prompt_len=len(req.prompt),
                     max_new_tokens=req.max_new_tokens)

    def note_prompt_length(self, length: int) -> None:
        """Record a prompt length as recently seen (feeds the default AOT
        prefill set) WITHOUT re-stamping submission metadata — used when a
        request migrates onto this engine from another one."""
        self._submit_seq += 1
        self.seen_prompt_lengths[length] = self._submit_seq

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    @property
    def load(self) -> int:
        """Queued + resident requests (the router's balance key)."""
        return len(self.queue) + sum(r is not None for r in self.slot_req)

    @property
    def free_slots(self) -> int:
        """Decode lanes currently unoccupied (decode-width capacity;
        token-granular memory capacity is `free_tokens`)."""
        return sum(r is None for r in self.slot_req)

    # -- token-granular capacity / fragmentation accounting ------------
    @property
    def kv_token_capacity(self) -> int:
        """Total KV tokens this engine can hold for admissions. Never
        negative: a pool whose watermark swallows every page (or a
        zero-page pool) reports 0 capacity, not a negative number that
        would poison the autoscaler's aggregate capacity sums."""
        if self.paged:
            return max(self.pool.n_pages - self.pool.watermark, 0) \
                * self.page_size
        return self.n_slots * self.s_max

    @property
    def free_tokens(self) -> int:
        """KV tokens still available to admissions (paged: admittable
        pages x page size; slot-granular: free slots x ``s_max``).
        Clamped to >= 0 — the rebalance-over-spawn decision sums this
        across peers and a negative entry would hide real capacity."""
        if self.paged:
            return max(self.pool.admittable_pages, 0) * self.page_size
        return self.free_slots * self.s_max

    @property
    def kv_allocated_tokens(self) -> int:
        """KV tokens reserved by resident requests (paged: their pages;
        slot-granular: a full ``s_max`` per occupied slot)."""
        if self.paged:
            return self.pool.allocated_tokens
        return sum(r is not None for r in self.slot_req) * self.s_max

    @property
    def kv_used_tokens(self) -> int:
        """KV tokens actually written by resident requests (the decode
        positions) — the numerator of `kv_utilization`."""
        return int(sum(int(self.slot_pos[i])
                       for i, r in enumerate(self.slot_req)
                       if r is not None))

    @property
    def kv_utilization(self) -> float:
        """Used / allocated KV tokens — the slot-padding-waste signal
        the planner and autoscaler read. 0.0 when nothing is resident;
        right-sized page reservations push it toward 1.0, full-``s_max``
        slot pinning keeps it low for short requests."""
        alloc = self.kv_allocated_tokens
        return self.kv_used_tokens / alloc if alloc else 0.0

    def admission_tokens(self, need: int) -> int:
        """Token capacity that admitting a request with a ``need``-token
        extent would consume here (page-rounded; a slot engine always
        spends a full slot)."""
        if self.paged:
            return self.pool.pages_for(min(need, self.s_max)) \
                * self.page_size
        return self.s_max

    def fits_inflight(self, needs: Sequence[int]) -> bool:
        """Migration pre-flight: can decoding requests with these
        capacity needs (tokens each) be imported right now — lanes AND
        memory? Imports may spend the watermark headroom (that is what
        it is reserved for), so the page budget here is the full free
        list, not `free_tokens`."""
        if len(needs) > self.free_slots:
            return False
        if self.paged:
            pages = sum(self.pool.pages_for(min(n, self.s_max))
                        for n in needs)
            return pages <= self.pool.free_pages
        return True

    @property
    def cache_batch(self) -> int:
        """Batch dim of the live KV tree (`plan_to_shardings` sizing):
        the page count for a paged pool, ``n_slots`` otherwise."""
        return self.pool.store_batch if self.paged else self.n_slots

    def single_layout(self) -> PyTree:
        """Shape tree of one request's single-sequence KV in this
        engine's layout (the migration fit target): the page-rounded
        extent for a paged pool, ``s_max`` for a slot pool."""
        S = self.pages_per_seq * self.page_size if self.paged else self.s_max
        return self.model.cache_shapes(1, S)

    def _admit(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            # attribution stamps: NON-advancing reads on the recording
            # clock (obs_events.now), so lineage's admission/prefill
            # split never perturbs a simulated run — under a FakeClock
            # both components are exactly 0 and queue wait carries the
            # simulated story; under the wall clock they are real.
            rec = obs_events.RECORDER
            t_adm0 = obs_events.now() if rec is not None else 0.0
            pages: List[int] = []
            if self.paged:
                head = self.queue[0]
                need = min(len(head.prompt) + head.max_new_tokens,
                           self.s_max)
                try:
                    pages = self.pool.alloc(self.pool.pages_for(need))
                except kvpool.PoolOOM:
                    return    # fail closed: stays queued, FIFO order kept
            req = self.queue.pop(0)
            S = len(req.prompt)
            prompt = jnp.asarray(req.prompt, jnp.int32)[None, :]
            # exact-length AOT executable first; else the smallest padded
            # bucket that holds the prompt; JIT fallback last. Selected
            # under the exec lock: a background PREPARE commit must never
            # be observed half-installed.
            batch: Dict[str, Any] = {"tokens": prompt}
            with self._exec_lock:
                prefill = self._prefill_exec.get(S)
                if prefill is None:
                    bucket = next((b for b in self._bucket_lengths
                                   if b >= S), None)
                    if bucket is not None:
                        batch = {"tokens": jnp.pad(
                                     prompt, ((0, 0), (0, bucket - S))),
                                 "true_len": jnp.asarray(S, jnp.int32)}
                        prefill = self._bucket_exec[bucket]
                    else:
                        prefill = self._prefill
            if self.model.cfg.pos_type == "mrope":
                Sp = batch["tokens"].shape[1]
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(Sp, dtype=jnp.int32)[None, None], (3, 1, Sp))
            t_pre0 = obs_events.now() if rec is not None else 0.0
            logits, cache1 = prefill(self.params, batch)
            tok = int(jnp.argmax(logits[0, : self.vocab]))
            t_pre1 = obs_events.now() if rec is not None else 0.0
            req.tokens_out.append(tok)
            req.t_first = time.time()
            if rec is not None:
                rec.emit("request.admit", engine=self.obs_name, rid=req.rid,
                         label=req.labels.get("data-type", ""),
                         queue_wait_s=req.t_first - req.t_submit,
                         admit_s=max(0.0, t_pre0 - t_adm0),
                         prefill_s=max(0.0, t_pre1 - t_pre0),
                         role=self.role)
            if self.paged:
                # scatter the single-sequence cache into the reserved
                # pages; the scratch-padded table tail absorbs bucket
                # slack (never read: decode masks by position)
                row = pages + [kvpool.SCRATCH_PAGE] \
                    * (self.pages_per_seq - len(pages))
                self.cache = kvpool.write_pages(self.cache, cache1, row,
                                                self._pax, self._sax)
                self.page_tables[slot] = row
                self.slot_pages[slot] = pages
                self._tables_dev = None
            else:
                # merge the single-sequence cache into the slot pool
                # (bucket entries beyond S are never read: masked)
                self.cache = _write_slot(self.cache, cache1, slot,
                                         S, self.s_max)
            self.slot_req[slot] = req
            self.slot_pos[slot] = S

    def _release_lane(self, slot: int) -> None:
        """Clear lane bookkeeping; a paged lane returns its pages to the
        pool the moment the request retires (token-granular free)."""
        self.slot_req[slot] = None
        self.slot_pos[slot] = 0
        if self.paged:
            self.pool.free(self.slot_pages[slot])
            self.slot_pages[slot] = []
            self.page_tables[slot] = kvpool.SCRATCH_PAGE
            self._tables_dev = None

    def _compact(self) -> None:
        """Pack active requests into the lowest decode lanes (continuous
        batching: a request owns PAGES, not a lane, so lane assignment
        is re-derived every step and the decode batch stays dense). The
        page-table rows travel with their requests; per-request streams
        are row-order independent (decode is row-wise)."""
        order = [i for i, r in enumerate(self.slot_req) if r is not None]
        if order == list(range(len(order))):
            return
        n = len(order)
        req = [self.slot_req[i] for i in order]
        pos = [int(self.slot_pos[i]) for i in order]
        pages = [self.slot_pages[i] for i in order]
        tables = self.page_tables[order].copy()
        self.slot_req = req + [None] * (self.n_slots - n)
        self.slot_pos[:] = 0
        self.slot_pos[:n] = pos
        self.slot_pages = pages + [[] for _ in range(self.n_slots - n)]
        self.page_tables[:] = kvpool.SCRATCH_PAGE
        self.page_tables[:n] = tables
        self._tables_dev = None

    # ------------------------------------------------------------------
    # live migration (export / import one request's state)
    # ------------------------------------------------------------------
    def _migration_axes(self) -> PyTree:
        """Per-leaf batch-axis tree of the KV pool (cached — a property
        of the model and ``s_max``, not of the current layout)."""
        if self._batch_axes is None:
            self._batch_axes = migration.batch_axis_tree(self.model,
                                                         self.s_max)
        return self._batch_axes

    def warm_migration(self) -> None:
        """Pre-compile the pool-surgery ops the migration path uses
        (slot slice + slot write at the live shapes/dtypes), so a later
        `export_slot`/`import_slot` pays no first-call compile — the same
        compile-ahead discipline `swap_plan` applies to executables.
        Idempotent and state-preserving (results are discarded)."""
        if self._migration_warm:
            return
        if self.paged:
            # mirror the paged export→import pipeline: full-width table
            # gather, fit to the page-rounded single layout, place, and
            # two chained full-width page scatters (results discarded —
            # scratch-row writes only ever touch page 0)
            row = np.full((1, self.pages_per_seq), kvpool.SCRATCH_PAGE,
                          dtype=np.int32)
            kv = kvpool.gather_pages(self.cache, jnp.asarray(row),
                                     self._pax, self._sax)
            jax.block_until_ready(jax.tree.leaves(kv))
            single = migration.fit_single(kv, self.single_layout())
            single = migration.place_like(single, self.cache)
            scratch_row = [kvpool.SCRATCH_PAGE] * self.pages_per_seq
            w1 = kvpool.write_pages(self.cache, single, scratch_row,
                                    self._pax, self._sax)
            w2 = kvpool.write_pages(w1, single, scratch_row,
                                    self._pax, self._sax)
            jax.block_until_ready(jax.tree.leaves(w2))
            self._migration_warm = True
            return
        axes = self._migration_axes()
        # mirror the real export→import pipeline exactly (fit/place change
        # the arrays' committed-ness, which is part of the op-cache key)
        kv = migration.slice_slot(self.cache, axes, 0)
        jax.block_until_ready(jax.tree.leaves(kv))
        single = migration.fit_single(kv, self.model.cache_shapes(1,
                                                                  self.s_max))
        single = migration.place_like(single, self.cache)
        # chain two writes: the pool operand's placement differs between
        # the first import (fresh pool) and later ones (previous write's
        # output) — both variants must be compiled before the window
        w1 = migration.write_single(self.cache, single, axes, 0)
        w2 = migration.write_single(w1, single, axes, 0)
        jax.block_until_ready(jax.tree.leaves(w2))
        self._migration_warm = True

    def export_slot(self, rid: int) -> SlotSnapshot:
        """Detach request ``rid`` from this engine as a `SlotSnapshot`.

        A resident request's KV slices are sliced out of the pool (its
        slot is freed); a queued request exports as a lightweight
        ``phase="queued"`` snapshot. In both cases ``max_new_tokens`` is
        clamped to what THIS pool could still have produced, so importing
        into a larger pool never extends the stream beyond the
        unmigrated run's.

        Returns:
            The snapshot (the `Request` object travels inside it — it is
            no longer tracked by this engine).

        Raises:
            KeyError: ``rid`` is neither resident nor queued here.
        """
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                pos = int(self.slot_pos[slot])
                room = self.s_max - 1 - pos
                if r.max_new_tokens - len(r.tokens_out) > room:
                    r.max_new_tokens = len(r.tokens_out) + room
                if self.paged:
                    # gather the request's pages into the standard
                    # single-sequence snapshot layout (full-width table:
                    # scratch-padded tail positions are >= pos — masked
                    # on the importer, so one static gather shape
                    # serves every export)
                    kv = kvpool.gather_pages(
                        self.cache,
                        jnp.asarray(self.page_tables[slot][None, :]),
                        self._pax, self._sax)
                else:
                    kv = migration.slice_slot(self.cache,
                                              self._migration_axes(), slot)
                jax.block_until_ready(jax.tree.leaves(kv))
                self._release_lane(slot)
                return SlotSnapshot(rid=rid, request=r, phase="decoding",
                                    pos=pos, kv=kv, src_s_max=self.s_max)
        for i, r in enumerate(self.queue):
            if r.rid == rid:
                self.queue.pop(i)
                r.max_new_tokens = min(r.max_new_tokens,
                                       self.s_max - len(r.prompt))
                return SlotSnapshot(rid=rid, request=r, phase="queued",
                                    pos=len(r.prompt), kv=None,
                                    src_s_max=self.s_max)
        raise KeyError(f"request {rid} is not on this engine")

    def import_slot(self, snapshot: SlotSnapshot, *,
                    kv_fitted: Optional[PyTree] = None) -> int:
        """Adopt a migrated request: re-queue a ``"queued"`` snapshot, or
        write a ``"decoding"`` snapshot's KV into a free lane (refit to
        this pool's single-sequence layout and `jax.device_put` onto it;
        a paged pool additionally reserves the request's pages — spending
        the watermark headroom if needed) and resume decode at the
        snapshot position — no recompilation, no re-run of prefill.
        Submission stamps are preserved: TTFT/TPOT still measure from
        the original submit.

        Args:
            kv_fitted: the snapshot's KV already fitted to this engine's
                `single_layout` and placed on its sharding — the batched
                multi-request transfer (`migration.migrate_many`) does
                one device_put for the whole batch and hands each
                request its slice here.

        Returns:
            KV bytes written into the pool (0 for a queued snapshot).

        Raises:
            MigrationError: fail-closed, with this engine unchanged —
                the pool's sequence capacity cannot finish the request's
                generation (e.g. migrating into a smaller ``s_max``), no
                decode lane is free, or the paged pool is out of pages.
        """
        need = migration.required_capacity(snapshot)
        if need > self.s_max:
            raise MigrationError(
                f"request {snapshot.rid} needs sequence capacity {need} "
                f"but this pool has s_max={self.s_max} — failing closed")
        req = snapshot.request
        if snapshot.phase == "queued":
            self.note_prompt_length(len(req.prompt))
            self.queue.append(req)
            return 0
        slot = self._free_slot()
        if slot is None:
            raise MigrationError(
                f"no free decode slot for request {snapshot.rid} "
                f"(n_slots={self.n_slots}) — failing closed")
        if kv_fitted is not None:
            single = kv_fitted
        else:
            single = migration.fit_single(snapshot.kv, self.single_layout())
            single = migration.place_like(single, self.cache)
        if self.paged:
            try:
                pages = self.pool.alloc(self.pool.pages_for(need),
                                        reserve=True)
            except kvpool.PoolOOM as e:
                raise MigrationError(str(e)) from e
            # full-width write (scratch-padded tail): one static scatter
            # shape serves every import; tail garbage goes to page 0
            row = pages + [kvpool.SCRATCH_PAGE] \
                * (self.pages_per_seq - len(pages))
            self.cache = kvpool.write_pages(self.cache, single, row,
                                            self._pax, self._sax)
            self.page_tables[slot] = row
            self.slot_pages[slot] = pages
            self._tables_dev = None
        else:
            self.cache = migration.write_single(
                self.cache, single, self._migration_axes(), slot)
        jax.block_until_ready(jax.tree.leaves(self.cache))
        self.slot_req[slot] = req
        self.slot_pos[slot] = snapshot.pos
        self.note_prompt_length(len(req.prompt))
        return snapshot.nbytes

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit queued requests into free slots (prefill), then run one
        decode step over all active slots.

        Returns:
            The number of slots that decoded this step.

        Raises:
            EngineStateError: if the engine is paused.
        """
        if self.paused:
            raise EngineStateError("engine is paused (resume() to serve)")
        self._admit()
        if self.paged:
            self._compact()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.n_slots, 1), dtype=np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].tokens_out[-1]
        # per-slot positions (inactive slots write harmlessly at index 0 —
        # their slot is re-prefilled before reuse; paged inactive lanes
        # point at the scratch page)
        pos = jnp.asarray(self.slot_pos, dtype=jnp.int32)
        with self._exec_lock:
            decode = self._decode_exec or self._decode
        if self.paged:
            if self._tables_dev is None:
                self._tables_dev = jnp.asarray(self.page_tables)
            logits, self.cache = decode(self.params, jnp.asarray(tokens),
                                        self.cache, pos, self._tables_dev)
        else:
            logits, self.cache = decode(self.params, jnp.asarray(tokens),
                                        self.cache, pos)
        logits = np.asarray(logits[:, : self.vocab])
        now = time.time()
        rec = obs_events.RECORDER
        for i in active:
            req = self.slot_req[i]
            tok = int(np.argmax(logits[i]))
            req.tokens_out.append(tok)
            self.slot_pos[i] += 1
            if (len(req.tokens_out) >= req.max_new_tokens
                    or self.slot_pos[i] >= self.s_max - 1):
                req.t_done = now
                self.done.append(req)
                self._release_lane(i)
                if rec is not None:
                    rec.emit("request.complete", engine=self.obs_name,
                             rid=req.rid,
                             label=req.labels.get("data-type", ""),
                             ttft_s=req.ttft, tpot_s=req.tpot,
                             tokens_out=len(req.tokens_out),
                             role=self.role)
        self.steps += 1
        if rec is not None and self.steps % rec.decode_stride == 0:
            rec.emit("engine.decode", engine=self.obs_name,
                     step=self.steps, active=len(active))
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        """Step until the queue and all slots are empty (or the engine's
        lifetime step count reaches ``max_steps``).

        Raises:
            EngineStateError: if the engine is paused.
        """
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.steps < max_steps:
            self.step()

    # ------------------------------------------------------------------
    def metrics(self) -> Dict[str, float]:
        """Full `METRIC_KEYS` summary over everything completed so far."""
        return compute_metrics(self.done)


def _tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def _write_slot(pool: PyTree, single: PyTree, slot: int, prompt_len: int,
                s_max: int) -> PyTree:
    """Write a 1-sequence prefill cache into batch slot `slot` of the pool."""

    def one(p, c):
        # locate batch dim: first dim where pool==n_slots and cache==1
        for ax in range(min(p.ndim, c.ndim)):
            if p.shape[ax] != c.shape[ax] and c.shape[ax] == 1:
                batch_ax = ax
                break
        else:
            return p
        # seq dims may differ (prompt_len vs s_max): pad cache to pool shape
        pads = []
        for ax in range(p.ndim):
            if ax == batch_ax:
                pads.append((0, 0))
            else:
                pads.append((0, p.shape[ax] - c.shape[ax]))
        c_pad = jnp.pad(c.astype(p.dtype), pads)
        idx = [slice(None)] * p.ndim
        idx[batch_ax] = slice(slot, slot + 1)
        return p.at[tuple(idx)].set(c_pad)

    return jax.tree.map(one, pool, single)
