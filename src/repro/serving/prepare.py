"""Concurrent PREPARE: background AOT compilation overlapped with serving.

The paper's <50 ms downtime budget holds because SWAP is cheap — but an
*inline* PREPARE still serializes compilation with serving on the wall
clock even though the phases are correctly split. This module makes
PREPARE truly concurrent (FlexPipe-style inflight refactoring; the
serverless-LLM cold-start lever of overlapping compilation with serving):

    PrepareTicket   the per-request handle of the pending-swap state
                    machine:

                        PREPARING ──compile done──► READY ──commit──► SWAPPED
                            │                         │
                            └──────── cancel() ───────┴──► CANCELLED
                            │
                            └── prepare raised ─────────► FAILED

                    A ticket that is CANCELLED (explicitly, or superseded
                    by a newer plan for the same engine) discards its
                    payload — its executables are NEVER installed.

    PrepareWorker   a small thread-pool executor that runs the PREPARE
                    closures (`plan_to_shardings` + `aot_executables`)
                    off the serving thread. XLA compilation releases the
                    GIL, so decode keeps flowing while the worker
                    compiles.

The cluster (`ServingCluster.reconfigure_async` / `spawn_engine_async`)
creates tickets, hands the compile closure to the worker, and commits
READY tickets at the next safe step boundary (`step()` / `run()` /
`commit_ready()`). The blocking SWAP window is unchanged — pause, drain,
install finished executables, resume — it just no longer waits for the
compiler.
"""
from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, Optional

from repro.obs import events as obs_events

# ticket states
PREPARING = "preparing"   # compile in flight on the worker
READY = "ready"           # executables finished; awaiting a step boundary
SWAPPED = "swapped"       # committed — the engine runs the new plan
CANCELLED = "cancelled"   # explicit cancel or superseded; never installed
FAILED = "failed"         # the PREPARE closure (or spawn commit) raised

TERMINAL = (SWAPPED, CANCELLED, FAILED)


class PrepareCancelled(RuntimeError):
    """The awaited ticket was cancelled (or superseded by a newer plan)
    before its swap committed — its executables were never installed."""


class PrepareTicket:
    """Handle for one pending swap (reconfigure or spawn).

    Returned immediately by `ServingCluster.reconfigure_async` /
    `spawn_engine_async`; the caller keeps serving and either polls
    (`state` / `done()`) while stepping the cluster, or blocks on
    `wait()` / `result()`.

    Attributes:
        engine: target engine name.
        kind: ``"reconfigure"`` | ``"spawn"``.
        plan: the target `ShardingPlan`.
        prepare_s: background compile time, set when the worker finishes.
        report: the committed swap's `DowntimeReport` (state SWAPPED).
        error: the exception that failed the ticket (state FAILED), or a
            post-commit verification error recorded after SWAPPED (the
            swap window was really paid; the engine is quarantined).
        superseded_by: the newer ticket that cancelled this one, if any.
    """

    def __init__(self, engine: str, kind: str, plan: Any = None, *,
                 engine_obj: Any = None):
        self._cond = threading.Condition()
        self._state = PREPARING
        self._payload: Optional[Dict[str, Any]] = None
        self._committing = False
        self.engine = engine
        self.kind = kind
        self.plan = plan
        self.prepare_s = 0.0
        self.report = None
        self.error: Optional[BaseException] = None
        self.superseded_by: Optional["PrepareTicket"] = None
        # the not-yet-registered ServingEngine a spawn ticket carries
        self._engine_obj = engine_obj
        self._emit_state(PREPARING)

    def _emit_state(self, state: str, **data: Any) -> None:
        """Flight-recorder hook: one ``ticket.<state>`` event per
        state-machine transition (no-op when recording is off)."""
        rec = obs_events.RECORDER
        if rec is not None:
            rec.emit(f"ticket.{state}", engine=self.engine,
                     ticket_kind=self.kind, **data)

    def __repr__(self) -> str:
        return (f"PrepareTicket({self.kind} {self.engine!r} "
                f"state={self.state})")

    # -- observation ---------------------------------------------------
    @property
    def state(self) -> str:
        """Current state (one of preparing/ready/swapped/cancelled/failed)."""
        with self._cond:
            return self._state

    def done(self) -> bool:
        """True once the ticket reached a terminal state."""
        return self.state in TERMINAL

    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        """Block until the background compile finishes (or the ticket
        dies). Returns True iff the executables are (or were) ready."""
        with self._cond:
            self._cond.wait_for(lambda: self._state != PREPARING, timeout)
            return self._state in (READY, SWAPPED)

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until terminal (swap committed, cancelled, or failed).

        NB: a READY ticket only commits at a cluster step boundary —
        `wait()` from the thread that is supposed to drive `step()`
        would deadlock; poll `done()` while stepping instead (or call
        `ServingCluster.run(wait_pending=True)`).
        """
        with self._cond:
            self._cond.wait_for(lambda: self._state in TERMINAL, timeout)
            return self._state in TERMINAL

    def result(self, timeout: Optional[float] = None):
        """`wait()`, then return the committed `DowntimeReport`.

        Fail-closed parity with the sync paths: a swap that committed
        but then failed post-swap HLO verification (engine quarantined)
        re-raises that error here, exactly as the blocking
        `reconfigure()` would — the report stays readable on
        ``self.report``.

        Raises:
            TimeoutError: not terminal within ``timeout``.
            PrepareCancelled: the ticket was cancelled/superseded.
            Exception: whatever failed the PREPARE closure, or the
                post-commit verification error.
        """
        if not self.wait(timeout):
            raise TimeoutError(f"{self!r} still pending after {timeout}s")
        if self._state == CANCELLED:
            raise PrepareCancelled(
                f"{self.kind} of engine {self.engine!r} was cancelled"
                + (" (superseded)" if self.superseded_by is not None else ""))
        if self._state == FAILED or self.error is not None:
            raise self.error
        return self.report

    # -- cancellation / supersession ------------------------------------
    def cancel(self, *, superseded_by: Optional["PrepareTicket"] = None
               ) -> bool:
        """Cancel a not-yet-committed ticket, discarding its payload so
        its executables can never be installed. Returns False when the
        ticket already committed/terminated (or its commit has begun)."""
        with self._cond:
            if self._state in TERMINAL or self._committing:
                return False
            self._state = CANCELLED
            self._payload = None           # executables discarded, provably
            self.superseded_by = superseded_by
            self._cond.notify_all()
        self._emit_state(CANCELLED, superseded=superseded_by is not None)
        return True

    # -- worker/cluster internals ---------------------------------------
    def _set_ready(self, payload: Dict[str, Any], prepare_s: float) -> None:
        with self._cond:
            self.prepare_s = prepare_s
            if self._state != PREPARING:   # cancelled mid-compile: discard
                return
            self._payload = payload
            self._state = READY
            self._cond.notify_all()
        self._emit_state(READY, prepare_s=prepare_s)

    def _fail(self, error: BaseException) -> None:
        with self._cond:
            if self._state in TERMINAL:
                return
            self.error = error
            self._state = FAILED
            self._payload = None
            self._cond.notify_all()
        self._emit_state(FAILED, error=repr(error))

    def _take_for_commit(self) -> Optional[Dict[str, Any]]:
        """Atomically claim a READY ticket for committing (cancel() can
        no longer land). Returns the payload, or None if not READY."""
        with self._cond:
            if self._state != READY or self._committing:
                return None
            self._committing = True
            return self._payload

    def _committed(self, report) -> None:
        with self._cond:
            self.report = report
            self._state = SWAPPED
            self._payload = None
            self._cond.notify_all()
        self._emit_state(SWAPPED,
                         downtime_s=getattr(report, "downtime_s", 0.0))

    def _commit_failed(self, error: BaseException) -> None:
        with self._cond:
            self.error = error
            self._state = FAILED
            self._payload = None
            self._committing = False
            self._cond.notify_all()
        self._emit_state(FAILED, error=repr(error))

    def _abandon(self) -> None:
        """The commit found the ticket's target gone (engine retired
        between READY and the step boundary): back to cancelled."""
        with self._cond:
            self._state = CANCELLED
            self._payload = None
            self._committing = False
            self._cond.notify_all()
        self._emit_state(CANCELLED, abandoned=True)


class PrepareWorker:
    """Thread-pool executor for PREPARE closures.

    The pool is created lazily (a cluster that never goes async never
    spawns a thread) and shared: compiles from several engines/clusters
    can be in flight at once, bounded by ``max_workers``.
    """

    def __init__(self, max_workers: Optional[int] = None):
        self._max = max_workers or min(4, os.cpu_count() or 1)
        self._pool: Optional[ThreadPoolExecutor] = None
        self._lock = threading.Lock()

    def submit(self, ticket: PrepareTicket,
               fn: Callable[[], Dict[str, Any]]) -> None:
        """Run ``fn`` on a worker thread; its return value becomes the
        ticket's payload (ticket -> READY), its exception fails it."""
        with self._lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self._max,
                    thread_name_prefix="prepare-worker")
            pool = self._pool
        pool.submit(self.run_inline, ticket, fn)

    @staticmethod
    def run_inline(ticket: PrepareTicket,
                   fn: Callable[[], Dict[str, Any]]) -> None:
        """Execute one PREPARE closure on the calling thread (the sync
        `reconfigure`/`spawn_engine` paths reuse the exact ticket state
        machine without a thread hop)."""
        if ticket.state != PREPARING:      # cancelled before it started
            return
        t0 = time.perf_counter()
        try:
            payload = fn()
        except BaseException as e:         # noqa: BLE001 - ticket carries it
            ticket._fail(e)
            return
        ticket._set_ready(payload, time.perf_counter() - t0)

    def shutdown(self, wait: bool = True) -> None:
        """Join the pool (in-flight compiles finish; nothing new starts)."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=wait)


_default_worker: Optional[PrepareWorker] = None
_default_lock = threading.Lock()


def default_worker() -> PrepareWorker:
    """The process-wide shared `PrepareWorker` (lazily created)."""
    global _default_worker
    with _default_lock:
        if _default_worker is None:
            _default_worker = PrepareWorker()
        return _default_worker
