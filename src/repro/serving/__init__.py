from repro.serving.autoscaler import (  # noqa: F401
    Autoscaler,
    ElasticPolicy,
    LoadTracker,
    ScaleDecision,
)
from repro.serving.clock import (  # noqa: F401
    SYSTEM_CLOCK,
    FakeClock,
    SystemClock,
    install_clock,
    installed_clock,
    simulated_time,
)
from repro.serving.cluster import (  # noqa: F401
    DowntimeReport,
    RoutingError,
    ServingCluster,
)
from repro.serving.engine import (  # noqa: F401
    METRIC_KEYS,
    EngineStateError,
    Request,
    ServingEngine,
    compute_metrics,
)
from repro.serving.migration import (  # noqa: F401
    MigrationError,
    MigrationRecord,
    SlotSnapshot,
)
from repro.serving.prepare import (  # noqa: F401
    PrepareCancelled,
    PrepareTicket,
    PrepareWorker,
)
