"""First-class clock abstraction for the serving layer.

Every timing-derived quantity in the serving stack — `Request` TTFT/TPOT
stamps, `DowntimeReport` blocking windows, migration pauses, PREPARE
durations — flows through the ``time`` attribute of the serving modules
(`engine`, `cluster`, `migration`, `prepare` — plus the flight
recorder's `repro.obs.events`; see ``CLOCKED_MODULE_NAMES``). That
indirection is what
lets a 10^5–10^6-request replay run on a **simulated clock**: install a
`FakeClock` and wall-clock never gates scale (``cluster.run``'s idle
sleep becomes a virtual advance, not a real one).

Two clock implementations share the same duck-typed surface
(``time() / perf_counter() / monotonic() / sleep(dt)`` plus the
simulation-only ``advance(dt)`` / ``now``):

    SystemClock   delegates to the real :mod:`time` module — the default;
    FakeClock     deterministic simulated time: every read advances by a
                  fixed ``tick``, ``sleep`` jumps instead of blocking.
                  (Promoted from the private test harness in
                  ``tests/conftest.py``; the ``fake_clock`` fixture now
                  installs THIS class.)

`install_clock` swaps the serving modules' time source and returns a
restore callable; `simulated_time` is the context-manager form. The
`Autoscaler` and `WorkloadPlanner` take a ``clock=`` constructor argument
directly — their dwell/cooldown hysteresis is counted in virtual ticks
and timestamped on the injected clock, so the decision path performs no
wall-clock reads at all.
"""
from __future__ import annotations

import contextlib
import threading
import time as _time
from typing import Callable, Iterator, Optional


class SystemClock:
    """The real wall clock, with the same surface as `FakeClock` (minus
    ``advance`` — real time cannot be jumped; `is_simulated` tells the
    two apart)."""

    is_simulated = False

    time = staticmethod(_time.time)
    perf_counter = staticmethod(_time.perf_counter)
    monotonic = staticmethod(_time.monotonic)
    sleep = staticmethod(_time.sleep)

    @property
    def now(self) -> float:
        return _time.time()


#: Process-wide default clock (the serving modules start on it).
SYSTEM_CLOCK = SystemClock()


class FakeClock:
    """Drop-in for the ``time`` module inside the serving layer: every
    read advances the clock by ``tick`` seconds, so timestamps are
    strictly increasing AND fully deterministic (no wall-clock jitter in
    TTFT/TPOT/downtime assertions). Thread-safe.

    Args:
        start: initial simulated epoch, seconds.
        tick: seconds added per ``time()``/``perf_counter()`` read.
    """

    is_simulated = True

    def __init__(self, start: float = 1_000.0, tick: float = 1e-3):
        self._now = float(start)
        self.tick = float(tick)
        self._lock = threading.Lock()

    def time(self) -> float:
        with self._lock:
            self._now += self.tick
            return self._now

    perf_counter = time
    monotonic = time

    def sleep(self, dt: float) -> None:
        """A simulated sleep never blocks: it jumps the clock."""
        self.advance(dt)

    def advance(self, dt: float) -> None:
        """Jump the clock forward without a read."""
        with self._lock:
            self._now += float(dt)

    @property
    def now(self) -> float:
        with self._lock:
            return self._now


#: Module names whose ``time`` attribute `install_clock` swaps — the
#: registry `scripts/check_clock_discipline.py` enforces: any file under
#: ``src/repro/serving`` or ``src/repro/obs`` that touches :mod:`time`
#: must appear here (or be this file), or CI fails.
CLOCKED_MODULE_NAMES = (
    "repro.serving.engine",
    "repro.serving.cluster",
    "repro.serving.migration",
    "repro.serving.prepare",
    "repro.obs.events",
    "repro.obs.lineage",
    "repro.obs.alerts",
)


def _serving_modules():
    import importlib

    return tuple(importlib.import_module(name)
                 for name in CLOCKED_MODULE_NAMES)


def install_clock(clock) -> Callable[[], None]:
    """Install ``clock`` as the time source of the serving layer
    (engine / cluster / migration / prepare stamp requests, downtime
    windows, migration pauses, and PREPARE durations through it; the
    flight recorder — `repro.obs` — timestamps its events on it too,
    via non-advancing reads).

    Returns:
        A zero-argument restore callable that puts the previous time
        sources back (call it in a ``finally``; `simulated_time` wraps
        this pattern).
    """
    mods = _serving_modules()
    previous = [(m, m.time) for m in mods]
    for m in mods:
        m.time = clock

    def restore() -> None:
        for m, prev in previous:
            m.time = prev

    return restore


def installed_clock():
    """The serving layer's current time source (the real :mod:`time`
    module unless a clock was installed)."""
    return _serving_modules()[0].time


@contextlib.contextmanager
def simulated_time(clock: Optional[FakeClock] = None,
                   ) -> Iterator[FakeClock]:
    """Run the body on a simulated serving-layer clock; restores the
    previous time source on exit.

    >>> with simulated_time() as clock:
    ...     clock.advance(3600.0)        # an hour passes instantly
    """
    clock = clock if clock is not None else FakeClock()
    restore = install_clock(clock)
    try:
        yield clock
    finally:
        restore()
