"""Live in-flight request migration: move per-request KV state between
engines instead of draining.

The paper's online-reconfiguration story only fully lands when a *stateful*
request can leave its engine mid-generation: retirement latency is
otherwise bounded below by the longest in-flight decode. This module is
the state-transfer primitive (FlexPipe-style inflight refactoring):

    export   `ServingEngine.export_slot(rid)` snapshots everything one
             request owns — the KV slices of its decode slot (sliced out
             of the (n_slots, s_max) pool along the per-leaf batch axis),
             its decode position, generated tokens, and metric stamps —
             and frees the slot. Queued requests export as lightweight
             ``phase="queued"`` snapshots (no KV yet).
    reshard  `fit_single` reshapes the snapshot onto the target pool's
             single-sequence layout (differing ``s_max`` pads/truncates);
             `place_like` `jax.device_put`s each leaf onto the target
             pool's sharding (specs that do not divide the slice shape
             degrade to replication on that dim).
    import   `ServingEngine.import_slot(snapshot)` writes the KV into a
             free slot and resumes decode at the snapshot position — no
             recompilation (decode is shape-static) and no re-run of
             prefill.
    resume   the request decodes on the target; the generated-token
             stream is bitwise identical to an unmigrated run (the KV
             prefix is copied verbatim and decode is deterministic
             per batch row).

Fail-closed rules (enforced at import, before any state is dropped):

  * the request's remaining token budget must fit the target pool's
    sequence capacity — migrating into a smaller ``s_max`` that cannot
    hold the rest of the generation raises `MigrationError`;
  * `export_slot` clamps ``max_new_tokens`` to what the SOURCE pool could
    have produced, so a larger target can never extend a stream beyond
    what the unmigrated run would have emitted;
  * a failed import restores the snapshot onto the source (the caller —
    `ServingCluster.migrate_requests` — re-imports on the source engine,
    which always fits its own snapshot).

Route-constraint compliance is the cluster's job (`migrate_requests`
checks the destination with the same fail-closed predicate the router
uses); this module only moves state.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import events as obs_events

if TYPE_CHECKING:                      # no runtime import: engine.py imports us
    from repro.serving.engine import Request

PyTree = Any


def _record_migration(record: "MigrationRecord") -> None:
    """Flight-recorder hook: one ``migration.pause`` event + a span whose
    duration is EXACTLY ``record.pause_s`` (the span is synthesized from
    the measured pause, so trace totals match `MigrationRecord` sums to
    the millisecond by construction)."""
    rec = obs_events.RECORDER
    if rec is None:
        return
    end = obs_events.now()
    rec.emit("migration.pause", engine=record.src, rid=record.rid,
             pause_s=record.pause_s, dst=record.dst, phase=record.phase,
             bytes_moved=record.bytes_moved, batch=record.batch,
             reason=record.reason)
    rec.span_at("migration.pause", end - record.pause_s, record.pause_s,
                track=record.src or "migration", cat="migration",
                rid=record.rid, dst=record.dst, reason=record.reason)


class MigrationError(RuntimeError):
    """A snapshot cannot be imported (capacity/slot/layout mismatch) —
    the request stays on (or is restored to) its source engine."""


@dataclasses.dataclass
class SlotSnapshot:
    """Everything one in-flight request owns, detached from its engine.

    Attributes:
        rid: the request id (lookup key for export).
        request: the live `Request` object — tokens generated so far and
            the metric stamps travel with it; nothing is re-stamped.
        phase: ``"decoding"`` (was resident in a slot; ``kv`` holds its
            cache slices) or ``"queued"`` (not yet prefilled; no KV).
        pos: the decode write position (``slot_pos``) for a decoding
            snapshot; the prompt length for a queued one.
        kv: single-sequence cache pytree sliced from the source pool
            (batch dim == 1, seq dims == the source ``s_max``); ``None``
            for queued snapshots.
        src_s_max: the source pool's sequence capacity (import refits
            seq dims from this to the target's).
        src_engine: source engine name (telemetry only).
        t_export: wall-clock stamp when the snapshot was taken.
    """

    rid: int
    request: "Request"
    phase: str
    pos: int
    kv: Optional[PyTree]
    src_s_max: int
    src_engine: str = ""
    t_export: float = dataclasses.field(default_factory=time.time)

    @property
    def nbytes(self) -> int:
        """Bytes of KV state carried by this snapshot (0 when queued)."""
        if self.kv is None:
            return 0
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(self.kv))

    def remaining_tokens(self) -> int:
        """Decode budget left after the tokens already generated."""
        return max(self.request.max_new_tokens - len(self.request.tokens_out), 0)


@dataclasses.dataclass(frozen=True)
class MigrationRecord:
    """Telemetry for one migrated request (the per-request pause is the
    paper's <50 ms budget; benchmarks assert it).

    Attributes:
        rid: the migrated request.
        src / dst: engine names.
        phase: ``"decoding"`` or ``"queued"`` at export time.
        pause_s: the request's blocking window — export + reshard +
            import, measured wall-clock (the request makes no progress
            inside it). Under a batched transfer (`migrate_many`) the
            shared device_put window is amortized: each request's pause
            is its own export + import plus a ``1/batch`` share of the
            one transfer.
        bytes_moved: KV bytes transferred (0 for queued requests).
        batch: decoding requests that shared this record's device_put
            (1 == an unbatched transfer).
        reason: why the request moved — ``""`` for an operator-initiated
            migration/retirement, ``"handoff"`` for the cluster's
            first-token prefill→decode handoff (the SLO ledger buckets
            pause time by this).
    """

    rid: int
    src: str
    dst: str
    phase: str
    pause_s: float
    bytes_moved: int
    batch: int = 1
    reason: str = ""


# ---------------------------------------------------------------------------
# pool surgery (shape-driven, architecture-agnostic)
# ---------------------------------------------------------------------------


def batch_axis_tree(model, s_max: int) -> PyTree:
    """Per-leaf batch-axis index of a model's KV cache layout.

    Probes `Model.cache_shapes` (eval_shape — no device work) at two batch
    sizes; the axis that tracks the probe is the batch axis. ``-1`` marks
    leaves with no batch dim (replicated state)."""
    one = model.cache_shapes(1, s_max)
    three = model.cache_shapes(3, s_max)

    def find(a, b):
        for ax in range(a.ndim):
            if a.shape[ax] == 1 and b.shape[ax] == 3:
                return ax
        return -1

    return jax.tree.map(find, one, three)


def slice_slot(pool: PyTree, axes: PyTree, slot: int) -> PyTree:
    """Slice one batch slot out of a KV pool, keeping the batch dim at
    size 1 (the single-sequence layout `ServingEngine._admit` also uses)."""

    def one(p, ax):
        if ax < 0:
            return p
        idx = [slice(None)] * p.ndim
        idx[ax] = slice(slot, slot + 1)
        return p[tuple(idx)]

    return jax.tree.map(one, pool, axes)


def fit_single(kv: PyTree, dst_single: PyTree) -> PyTree:
    """Refit a single-sequence cache onto a target single-sequence layout:
    longer dims are truncated (valid entries live in the prefix — decode
    masks by position), shorter ones zero-padded; dtypes follow the target.

    Raises:
        MigrationError: if the pytrees are not congruent (different
            architectures cannot exchange KV state).
    """

    def one(k, d):
        for ax in range(k.ndim):
            if k.shape[ax] > d.shape[ax]:
                k = jax.lax.slice_in_dim(k, 0, d.shape[ax], axis=ax)
            elif k.shape[ax] < d.shape[ax]:
                pad = [(0, 0)] * k.ndim
                pad[ax] = (0, d.shape[ax] - k.shape[ax])
                k = jnp.pad(k, pad)
        return k.astype(d.dtype)

    try:
        return jax.tree.map(one, kv, dst_single)
    except ValueError as e:
        raise MigrationError(
            f"snapshot cache layout is not congruent with the target "
            f"engine's (different model architecture?): {e}") from e


def place_like(kv: PyTree, pool: PyTree) -> PyTree:
    """`jax.device_put` each snapshot leaf onto the target pool's sharding.

    The pool's `NamedSharding` specs are re-derived for the slice shape:
    a spec entry whose mesh-axis extent does not divide the slice dim
    (e.g. a sharded batch dim collapsed to 1) degrades to replication on
    that dim, so the transfer is always expressible."""
    from jax.sharding import NamedSharding, PartitionSpec

    def one(k, p):
        sh = getattr(p, "sharding", None)
        if isinstance(sh, NamedSharding):
            parts = []
            for ax in range(k.ndim):
                entry = sh.spec[ax] if ax < len(sh.spec) else None
                names = entry if isinstance(entry, (tuple, list)) else (
                    (entry,) if entry is not None else ())
                size = 1
                for nm in names:
                    size *= sh.mesh.shape[nm]
                parts.append(entry if k.shape[ax] % size == 0 else None)
            return jax.device_put(k, NamedSharding(sh.mesh,
                                                   PartitionSpec(*parts)))
        if sh is not None:
            return jax.device_put(k, sh)
        return jnp.asarray(k)

    return jax.tree.map(one, kv, pool)


def write_single(pool: PyTree, single: PyTree, axes: PyTree,
                 slot: int) -> PyTree:
    """Write a single-sequence cache into batch slot ``slot`` of a pool
    (the inverse of `slice_slot`; trailing dims already fit the pool)."""

    def one(p, c, ax):
        if ax < 0:
            return p
        idx = [slice(None)] * p.ndim
        idx[ax] = slice(slot, slot + 1)
        return p.at[tuple(idx)].set(c.astype(p.dtype))

    return jax.tree.map(one, pool, single, axes)


def needed_capacity(request: "Request", phase: str, pos: int,
                    src_s_max: int) -> int:
    """The minimum target ``s_max`` that can finish this request's
    generation without ever hitting the pool's sequence cap — computable
    BEFORE export (it applies the same source-pool budget clamp
    `ServingEngine.export_slot` will).

    For a decoding request the remaining tokens write positions
    ``pos .. pos+rem-1`` and the engine stops when ``slot_pos >=
    s_max - 1``; a queued request additionally gets its first token from
    prefill. Importing below this capacity would truncate the stream, so
    `ServingEngine.import_slot` fails closed instead."""
    if phase == "queued":
        # prefill emits token 1 at pos=len(prompt); rem-1 decode steps follow
        rem = min(max(request.max_new_tokens - len(request.tokens_out), 0),
                  src_s_max - len(request.prompt))
        return len(request.prompt) + max(rem, 1)
    rem = min(max(request.max_new_tokens - len(request.tokens_out), 0),
              src_s_max - 1 - pos)
    return pos + rem + 1


def required_capacity(snapshot: SlotSnapshot) -> int:
    """`needed_capacity` of an already-exported snapshot."""
    return needed_capacity(snapshot.request, snapshot.phase, snapshot.pos,
                           snapshot.src_s_max)


def migrate_one(src_engine, dst_engine, rid: int, *,
                src: str = "", dst: str = "",
                reason: str = "") -> MigrationRecord:
    """Export `rid` from ``src_engine`` and import it into ``dst_engine``,
    restoring it to the source if the import fails closed.

    This is the primitive `ServingCluster.migrate_requests` loops over;
    eligibility (labels, route constraints, free slots) is the caller's
    responsibility — state transfer and honest pause accounting are ours.

    Returns:
        The `MigrationRecord` (pause measured export→import, blocking).

    Raises:
        KeyError: ``rid`` is not on the source engine.
        MigrationError: the destination cannot hold the request (it has
            been restored to the source, unchanged).
    """
    t0 = time.perf_counter()
    snap = src_engine.export_slot(rid)
    if src:
        snap.src_engine = src
    try:
        moved = dst_engine.import_slot(snap)
    except MigrationError:
        src_engine.import_slot(snap)   # the source always fits its own state
        raise
    record = MigrationRecord(rid=rid, src=src, dst=dst, phase=snap.phase,
                             pause_s=time.perf_counter() - t0,
                             bytes_moved=moved, reason=reason)
    _record_migration(record)
    return record


def migrate_many(src_engine, dst_engine, rids: Sequence[int], *,
                 src: str = "", dst: str = "",
                 reason: str = "") -> List[MigrationRecord]:
    """Move a batch of requests between one engine pair with ONE
    `jax.device_put` for all of their KV state, instead of one per
    request (`ServingCluster.migrate_requests` calls this).

    Pipeline: export every snapshot, fit each decoding snapshot onto the
    destination's single-sequence layout, CONCATENATE them along the
    batch axis, place the whole batch on the destination's sharding in
    one transfer, then slice per request and import. The per-request
    ``pause_s`` is honest under batching: each request's own export +
    import window plus a ``1/batch`` share of the shared transfer (the
    batching is exactly what makes the shared window small).

    Fail-closed: if any import fails, that request AND every
    not-yet-imported one are restored to the source (which always fits
    its own state) before the error propagates — nothing is ever lost
    mid-batch. Requests imported before the failure remain moved.

    Returns:
        One `MigrationRecord` per request, in ``rids`` order, with
        ``batch`` set to the number of decoding requests that shared
        the transfer.

    Raises:
        KeyError: a ``rid`` is not on the source engine (raised during
            export; earlier exports are restored).
        MigrationError: an import failed closed (see above).
    """
    # Empty cohort (every candidate filtered out upstream, e.g. by route
    # predicates): nothing pauses, nothing moves — return before any
    # warm-up or telemetry so no degenerate batch record or pause span
    # is ever emitted for a migration that did not happen.
    if not rids:
        return []
    # Warm everything that can compile BEFORE the first export, while the
    # requests are still live and serving: the destination layout/axes
    # lookups and — for cohorts of 2+ — the per-request batched gather
    # (its first use at a new cohort shape costs ~200 ms of XLA compile,
    # which would otherwise land inside the shared transfer window that
    # pause_s shares out across the cohort).
    n_dec = sum(1 for rid in rids
                if any(r is not None and r.rid == rid
                       for r in src_engine.slot_req))
    layout = axes = None
    if n_dec:
        layout = dst_engine.single_layout()
        axes = dst_engine._migration_axes()
        if n_dec > 1:
            dummy = jax.tree.map(
                lambda ax, l: (np.zeros(
                    l.shape[:ax] + (n_dec,) + l.shape[ax + 1:],
                    dtype=l.dtype) if ax >= 0 else l),
                axes, layout)
            warm = place_like(dummy, dst_engine.cache)
            warm = jax.tree.map(
                lambda ax, b: (jnp.take(b, jnp.asarray([0], jnp.int32),
                                        axis=ax) if ax >= 0 else b),
                axes, warm)
            jax.block_until_ready(jax.tree.leaves(warm))

    snaps: List[SlotSnapshot] = []
    t_export: Dict[int, float] = {}
    for rid in rids:
        t0 = time.perf_counter()
        try:
            snap = src_engine.export_slot(rid)
        except KeyError:
            for s in snaps:            # unwind: nothing moved
                src_engine.import_slot(s)
            raise
        if src:
            snap.src_engine = src
        t_export[rid] = time.perf_counter() - t0
        snaps.append(snap)

    decoding = [s for s in snaps if s.phase == "decoding"]
    fitted: Dict[int, PyTree] = {}
    t_share = 0.0
    if decoding:
        t0 = time.perf_counter()
        if layout is None:             # unreachable unless phases shifted
            layout = dst_engine.single_layout()   # between count and export
            axes = dst_engine._migration_axes()
        fits = [fit_single(s.kv, layout) for s in decoding]
        if len(fits) == 1:
            batched = fits[0]
        else:
            # concatenate on the HOST: np.concatenate never compiles, so
            # the pause window stays compile-free for ANY cohort size
            # (an XLA concat/slice would build one executable per batch
            # size and per index — all inside the measured pause)
            batched = jax.tree.map(
                lambda ax, *ls: (np.concatenate(
                    [np.asarray(l) for l in ls], axis=ax)
                    if ax >= 0 else ls[0]),
                axes, *fits)
        placed = place_like(batched, dst_engine.cache)   # ONE device_put
        jax.block_until_ready(jax.tree.leaves(placed))
        for i, s in enumerate(decoding):
            if len(decoding) == 1:
                fitted[s.rid] = placed
            else:
                # index passed as device DATA, not a baked constant: one
                # gather executable per leaf shape serves every i
                idx = jnp.asarray([i], dtype=jnp.int32)
                fitted[s.rid] = jax.tree.map(
                    lambda ax, b: (jnp.take(b, idx, axis=ax)
                                   if ax >= 0 else b),
                    axes, placed)
        t_share = (time.perf_counter() - t0) / len(decoding)

    records: List[MigrationRecord] = []
    for k, snap in enumerate(snaps):
        t0 = time.perf_counter()
        try:
            moved = dst_engine.import_slot(snap,
                                           kv_fitted=fitted.get(snap.rid))
        except MigrationError:
            for s in snaps[k:]:        # this one + every not-yet-imported
                src_engine.import_slot(s)
            raise
        decode_share = t_share if snap.phase == "decoding" else 0.0
        record = MigrationRecord(
            rid=snap.rid, src=src, dst=dst, phase=snap.phase,
            pause_s=t_export[snap.rid] + decode_share
            + (time.perf_counter() - t0),
            bytes_moved=moved,
            batch=len(decoding) if snap.phase == "decoding" else 1,
            reason=reason)
        _record_migration(record)
        records.append(record)
    return records
