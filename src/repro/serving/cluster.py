"""`ServingCluster`: the intent-driven serving control plane.

This is the runtime the orchestrator programs (ROADMAP north-star layer):

  * engines register with tenancy labels and a `ShardingPlan`;
  * labeled `Request`s are routed only to engines whose plan satisfies the
    route constraint compiled from the matching intent (phi -> pod-local
    engines); routing is FAIL-CLOSED — with no compliant engine the request
    is rejected, never silently served on a non-compliant one;
  * `reconfigure()` swaps a live engine onto a new plan with the
    compile-ahead + blocking-swap protocol:

      PREPARE (serving continues): materialize shardings from the plan
          (`plan_to_shardings`) and AOT-compile prefill/decode executables;
      SWAP (the downtime window):  pause -> drain -> migrate params + KV
          pool -> install executables — no compilation in this window;
      RESUME.

    The returned `DowntimeReport` is finalized automatically: metrics_after
    snapshots at resume and is refreshed with the post-swap completion
    window by the next `run()`/`step()` that retires requests.

Typical flow (three lines of control plane):

    cluster.register("edge0", engine, plan=default_plan())
    orch.submit("Phi traffic must remain inside the pod.", apply_to=cluster)
    cluster.run()          # keep serving; routing now enforces the intent
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.serving.engine import (
    Request,
    ServingEngine,
    compute_metrics,
)
from repro.sharding.plan import (
    ShardingPlan,
    plan_satisfies,
    plan_to_shardings,
)

PyTree = Any


class RoutingError(RuntimeError):
    """No registered engine satisfies the request's route constraint."""


@dataclasses.dataclass
class DowntimeReport:
    """Cost of one online reconfiguration (paper metrics: downtime + the
    TTFT/TPOT band before vs after the swap)."""

    prepare_s: float          # background compile time (serving continues)
    downtime_s: float         # blocking window (drain + migrate + install)
    migrate_bytes: int
    metrics_before: Dict[str, float]
    metrics_after: Dict[str, float]
    engine: str = ""
    compiled_in_prepare: int = 0   # executables AOT-compiled ahead of swap

    def summary(self) -> str:
        return (f"engine={self.engine or '?'} "
                f"prepare={self.prepare_s:.3f}s (aot x{self.compiled_in_prepare}) "
                f"downtime={self.downtime_s*1e3:.1f}ms "
                f"migrated={self.migrate_bytes/2**20:.1f}MiB")


@dataclasses.dataclass
class _EngineEntry:
    name: str
    engine: ServingEngine
    pending_report: Optional[DowntimeReport] = None
    swap_t: float = 0.0

    # plan and labels read the live engine — one source of truth, so
    # updates after registration are visible to the router
    @property
    def plan(self) -> ShardingPlan:
        return self.engine.plan

    @property
    def labels(self) -> Dict[str, str]:
        return self.engine.labels

    def serves(self, labels: Dict[str, str]) -> bool:
        """Tenancy check: an engine label that contradicts a request label
        disqualifies; absent engine labels mean 'serves all'."""
        for k, v in labels.items():
            if k in self.labels and self.labels[k] != v:
                return False
        return True


def _default_mesh() -> jax.sharding.Mesh:
    """1-device mesh carrying the full production axis names, so plan specs
    (which reference pod/data/model) always resolve."""
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1, 1),
        ("pod", "data", "model"))


class ServingCluster:
    """Multi-engine serving runtime with label-based, fail-closed routing
    and online per-engine reconfiguration."""

    ROUTE_KEY = "data-type"   # the label routing constraints key on

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None):
        self.mesh = mesh or _default_mesh()
        self._entries: Dict[str, _EngineEntry] = {}
        self._routes: Dict[str, ShardingPlan] = {}   # label value -> required
        self.history: List[DowntimeReport] = []
        self.rejected: List[Request] = []

    # ------------------------------------------------------------------
    # registration / introspection
    # ------------------------------------------------------------------
    def register(self, name: str, engine: ServingEngine, *,
                 plan: Optional[ShardingPlan] = None,
                 labels: Optional[Dict[str, str]] = None) -> None:
        if name in self._entries:
            raise ValueError(f"engine {name!r} already registered")
        if plan is not None:
            engine.plan = plan
        if labels:
            engine.labels.update(labels)
        self._entries[name] = _EngineEntry(name, engine)

    def engine(self, name: str) -> ServingEngine:
        return self._entries[name].engine

    def engines(self) -> List[str]:
        return list(self._entries)

    def route_constraints(self) -> Dict[str, ShardingPlan]:
        return dict(self._routes)

    def set_route_constraint(self, value: str,
                             required: ShardingPlan) -> None:
        """Require that requests labeled ``data-type=value`` be served only
        by engines whose plan satisfies `required` (see `plan_satisfies`)."""
        self._routes[value] = required

    # ------------------------------------------------------------------
    # routing (fail-closed)
    # ------------------------------------------------------------------
    def eligible(self, req: Request) -> List[str]:
        route_val = req.labels.get(self.ROUTE_KEY)
        required = self._routes.get(route_val) if route_val else None
        out = []
        for e in self._entries.values():
            if not e.serves(req.labels):
                continue
            if required is not None and not plan_satisfies(e.plan, required):
                continue
            out.append(e.name)
        return out

    def route(self, req: Request) -> str:
        names = self.eligible(req)
        if not names:
            self.rejected.append(req)
            raise RoutingError(
                f"no compliant engine for request {req.rid} "
                f"(labels={req.labels}, constraint="
                f"{self._routes.get(req.labels.get(self.ROUTE_KEY))!r}) — "
                "failing closed")
        # balance over compliant engines, preferring ones actively serving;
        # a paused engine still queues (documented lifecycle) but only when
        # no running engine qualifies
        running = [n for n in names if not self._entries[n].engine.paused]
        return min(running or names,
                   key=lambda n: self._entries[n].engine.load)

    def submit(self, req: Request) -> str:
        """Route + enqueue; returns the chosen engine name."""
        name = self.route(req)
        self._entries[name].engine.submit(req)
        return name

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode step across all running engines. Returns #active."""
        n = 0
        for e in self._entries.values():
            if not e.engine.paused:
                n += e.engine.step()
        return n

    def run(self, max_steps: int = 10_000) -> None:
        """Serve until every *running* engine's queue and slots are empty.

        Work queued on a paused engine stays queued (nothing is dropped)
        and is served by the `run()` after that engine's `resume()`."""
        for _ in range(max_steps):
            busy = any(
                e.engine.queue or any(r is not None
                                      for r in e.engine.slot_req)
                for e in self._entries.values() if not e.engine.paused)
            if not busy:
                break
            self.step()
        self._refresh_reports()

    def metrics(self, name: Optional[str] = None) -> Dict[str, float]:
        if name is not None:
            return self._entries[name].engine.metrics()
        done: List[Request] = []
        for e in self._entries.values():
            done.extend(e.engine.done)
        return compute_metrics(done)

    # ------------------------------------------------------------------
    # online reconfiguration (compile-ahead + blocking swap)
    # ------------------------------------------------------------------
    def reconfigure(self, name: str, plan: ShardingPlan, *,
                    shardings: Optional[Dict[str, Any]] = None,
                    prefill_lengths: Sequence[int] = (),
                    ) -> DowntimeReport:
        entry = self._entries[name]
        eng = entry.engine
        # a still-pending previous report gets its honest final window now
        # (possibly empty — completed=0/NaN — if no traffic ran under it),
        # rather than being silently dropped by the overwrite below
        if entry.pending_report is not None:
            entry.pending_report.metrics_after = compute_metrics(
                [r for r in eng.done if r.t_done >= entry.swap_t])
            entry.pending_report = None
        # window since the previous swap (everything, on the first one), so
        # repeated reconfigurations compare like-for-like traffic windows
        metrics_before = compute_metrics(
            [r for r in eng.done if r.t_done >= entry.swap_t])

        # ---- 1. PREPARE (background — serving continues) ----
        t0 = time.time()
        if shardings is None:
            shardings = plan_to_shardings(
                eng.model.cfg, plan, self.mesh, n_slots=eng.n_slots)
        executables, n_compiled = eng.aot_executables(
            shardings, prefill_lengths=prefill_lengths)
        prepare_s = time.time() - t0

        # ---- 2. SWAP (blocking window — no compilation here) ----
        t0 = time.time()
        eng.pause()
        try:
            eng.drain()
            migrate_bytes = eng.swap_plan(plan, shardings=shardings,
                                          executables=executables)
        finally:
            # a failed swap must never strand the engine paused — traffic
            # routed to it would otherwise sit queued with no error
            eng.resume()
        downtime_s = time.time() - t0

        # ---- 3. RESUME + auto-finalized report ----
        report = DowntimeReport(
            prepare_s=prepare_s, downtime_s=downtime_s,
            migrate_bytes=migrate_bytes,
            metrics_before=metrics_before,
            # auto-finalized to the empty post-swap window (full key set);
            # _refresh_reports replaces it with real post-swap traffic
            metrics_after=compute_metrics([]),
            engine=name, compiled_in_prepare=n_compiled)
        entry.pending_report = report
        entry.swap_t = time.time()
        self.history.append(report)
        return report

    def _refresh_reports(self) -> None:
        """Re-finalize pending reports once post-swap completions exist, so
        metrics_after reflects traffic served *under the new plan*. Runs
        when `run()` drains (not per step, so the window isn't cut short
        while requests are still in flight)."""
        for e in self._entries.values():
            if e.pending_report is None:
                continue
            window = [r for r in e.engine.done if r.t_done >= e.swap_t]
            if window:
                e.pending_report.metrics_after = compute_metrics(window)
                e.pending_report = None

    # ------------------------------------------------------------------
    # intent application (called by Orchestrator.submit(apply_to=...))
    # ------------------------------------------------------------------
    def apply_policy(self, policy, components: Sequence = ()
                     ) -> Dict[str, DowntimeReport]:
        """Program the cluster from a validated `CompiledPolicy`:

        1. translate the policy's plan updates into per-label route
           constraints (`flows/<data-type>` entries and component plans
           merge on the component's data-type label);
        2. reconfigure every engine that could serve a constrained label
           but whose current plan does not satisfy the constraint.

        Returns {engine name: DowntimeReport} for engines that were swapped.
        """
        by_name = {c.name: c for c in components}
        merged: Dict[str, Dict[str, set]] = {}
        for key, p in policy.plan_updates.items():
            if key.startswith("flows/"):
                value = key[len("flows/"):]
            else:
                comp = by_name.get(key)
                value = comp.labels.get(self.ROUTE_KEY) if comp else None
            if not value or value == "*":
                continue
            m = merged.setdefault(value, {"axes": set(), "pins": set()})
            m["axes"].update(p.forbidden_collective_axes)
            if p.device_constraints:
                m["pins"].add(tuple(p.device_constraints))

        for value, m in merged.items():
            # a single consistent pin becomes a placement requirement;
            # conflicting pins (components load-balanced over several pods)
            # degrade to confinement on the pinned axes — still fail-closed:
            # an engine must be pinned *somewhere* on those axes to qualify
            pins = next(iter(m["pins"])) if len(m["pins"]) == 1 else ()
            axes = set(m["axes"])
            if len(m["pins"]) > 1:
                axes |= {axis for pin in m["pins"] for axis, _ in pin}
            if not pins and not axes:
                continue      # nothing enforceable — never install a
                              # vacuous constraint every engine satisfies
            self.set_route_constraint(value, ShardingPlan(
                device_constraints=pins,
                forbidden_collective_axes=tuple(sorted(axes))))

        # one swap per engine: merge ALL unsatisfied constraints into a
        # single target plan (per-constraint swaps would let a later pin
        # overwrite an earlier one and churn the engine through repeated
        # migrations). Pins that conflict across constraints are dropped in
        # favor of forbidding the axis — the engine then satisfies neither
        # pinned constraint and those labels fail closed at routing time,
        # which is the correct outcome for one engine asked to be in two
        # places at once.
        reports: Dict[str, DowntimeReport] = {}
        for e in list(self._entries.values()):
            axes = set(e.plan.forbidden_collective_axes)
            pins: Dict[str, int] = dict(e.plan.device_constraints)
            conflicts: set = set()
            needs_swap = False
            for value, required in self._routes.items():
                if not e.serves({self.ROUTE_KEY: value}):
                    continue
                if plan_satisfies(e.plan, required):
                    continue
                needs_swap = True
                axes.update(required.forbidden_collective_axes)
                for axis, coord in required.device_constraints:
                    if axis in pins and pins[axis] != coord:
                        conflicts.add(axis)
                    else:
                        pins[axis] = coord
            if not needs_swap:
                continue
            for axis in conflicts:
                pins.pop(axis, None)
                axes.add(axis)
            new_plan = e.plan.with_(
                device_constraints=tuple(sorted(pins.items())),
                forbidden_collective_axes=tuple(sorted(axes)))
            reports[e.name] = self.reconfigure(e.name, new_plan)
        return reports
