"""`ServingCluster`: the intent-driven serving control plane.

This is the runtime the orchestrator programs (ROADMAP north-star layer):

  * engines register with tenancy labels and a `ShardingPlan`;
  * labeled `Request`s are routed only to engines whose plan satisfies the
    route constraint compiled from the matching intent (phi -> pod-local
    engines); routing is FAIL-CLOSED — with no compliant engine the request
    is rejected, never silently served on a non-compliant one;
  * `reconfigure()` swaps a live engine onto a new plan with the
    compile-ahead + blocking-swap protocol:

      PREPARE (serving continues): materialize shardings from the plan
          (`plan_to_shardings`) and AOT-compile prefill/decode executables;
      SWAP (the downtime window):  pause -> drain -> migrate params + KV
          pool -> install executables — no compilation in this window;
      RESUME.

    PREPARE is truly CONCURRENT with serving: `reconfigure_async` /
    `spawn_engine_async` return a `PrepareTicket` immediately, the
    compile runs on the background `PrepareWorker` (repro.serving.prepare)
    while requests keep flowing, and the swap commits at the next safe
    step boundary. A newer plan for the same engine supersedes (cancels)
    the older pending ticket — its executables are never installed. The
    sync `reconfigure`/`spawn_engine` run the SAME state machine inline.

    The returned `DowntimeReport` is finalized automatically: metrics_after
    snapshots at resume and is refreshed with the post-swap completion
    window by the next `run()`/`step()` that retires requests.

  * the cluster is ELASTIC: `spawn_engine` brings a new engine online with
    the same PREPARE-phase AOT path (a spawn never JITs on the serving
    path), `retire_engine` puts an engine into a DRAINING state — it stops
    receiving new requests, serves out its queue, and is deregistered once
    empty (its completions are retained for cluster metrics) — and
    `rebalance` retargets an idle engine at a different label via the swap
    protocol. `repro.serving.autoscaler` drives these from per-label load.

Typical flow (three lines of control plane):

    cluster.register("edge0", engine, plan=default_plan())
    orch.submit("Phi traffic must remain inside the pod.", apply_to=cluster)
    cluster.run()          # keep serving; routing now enforces the intent

See docs/architecture.md for the end-to-end dataflow and
docs/reconfiguration.md for the lifecycle state machine.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.obs import events as obs_events
from repro.obs.metrics import RequestAggregate
from repro.serving.engine import (
    Request,
    ServingEngine,
    compute_metrics,
)
from repro.serving.migration import (
    MigrationError,
    MigrationRecord,
    migrate_many,
    needed_capacity,
)
from repro.serving.prepare import (
    CANCELLED,
    FAILED,
    READY,
    PrepareTicket,
    PrepareWorker,
    default_worker,
)
from repro.sharding.plan import (
    ShardingPlan,
    merge_restrictions,
    plan_satisfies,
    plan_to_shardings,
)

PyTree = Any


class RoutingError(RuntimeError):
    """No registered engine satisfies the request's route constraint."""


@dataclasses.dataclass
class DowntimeReport:
    """Cost of one online scale/reconfiguration event (paper metrics:
    downtime + the TTFT/TPOT band before vs after the swap).

    Attributes:
        prepare_s: background compile time; serving continues throughout.
        downtime_s: the blocking window. For a reconfigure/rebalance:
            drain + migrate + install. For a retirement the HONEST
            blocking cost: 0 for drain-mode (draining never blocks other
            engines), the measured relocation window for migrate-mode.
        migrate_bytes: bytes moved in the blocking window — params + KV
            pool for a swap, the migrated requests' KV state for a
            migrate-mode retirement.
        metrics_before: `compute_metrics` over the traffic window since the
            engine's previous scale event (empty-window NaNs for a spawn).
        metrics_after: `compute_metrics` over traffic served *after* the
            event. Auto-finalized: seeded with the empty window and
            refreshed by the next `ServingCluster.run()` that retires
            post-event completions (or at reap time for a retirement).
        engine: name of the affected engine.
        compiled_in_prepare: executables AOT-compiled ahead of the swap.
        event: "reconfigure" | "spawn" | "retire" | "rebalance".
        migrations: per-request `MigrationRecord`s for migrate-mode
            retirements / explicit `migrate_requests` events — each
            carries the request's own pause (the paper's <50 ms budget).
    """

    prepare_s: float          # background compile time (serving continues)
    downtime_s: float         # blocking window (drain + migrate + install)
    migrate_bytes: int
    metrics_before: Dict[str, float]
    metrics_after: Dict[str, float]
    engine: str = ""
    compiled_in_prepare: int = 0   # executables AOT-compiled ahead of swap
    event: str = "reconfigure"
    migrations: Tuple[MigrationRecord, ...] = ()

    def summary(self) -> str:
        """One-line human-readable digest of the event cost."""
        s = (f"engine={self.engine or '?'} event={self.event} "
             f"prepare={self.prepare_s:.3f}s (aot x{self.compiled_in_prepare}) "
             f"downtime={self.downtime_s*1e3:.1f}ms "
             f"migrated={self.migrate_bytes/2**20:.1f}MiB")
        if self.migrations:
            s += (f" moved={len(self.migrations)}req "
                  f"pause_max={max(m.pause_s for m in self.migrations)*1e3:.1f}ms")
        return s


@dataclasses.dataclass
class _EngineEntry:
    name: str
    engine: ServingEngine
    pending_report: Optional[DowntimeReport] = None
    swap_t: float = 0.0
    draining: bool = False    # retiring: serves out its queue, gets no new work
    # compiled-HLO validation failed after registration (e.g. a constraint
    # was installed later): the engine is unroutable until a reconfigure
    # passes verification — fail-closed beats serving on a disproven claim
    quarantined: bool = False
    # the pending-swap state machine (one ticket per engine; a newer plan
    # supersedes — i.e. cancels — the old ticket before it is applied)
    pending_ticket: Optional[PrepareTicket] = None
    # True only inside the blocking SWAP window of a commit; the router
    # must never choose a mid-swap engine (asserted by the stress tests)
    swapping: bool = False
    # completions already folded into the cluster's incremental per-label
    # aggregates (a consumed prefix of ``engine.done``)
    metrics_seen: int = 0

    # plan and labels read the live engine — one source of truth, so
    # updates after registration are visible to the router
    @property
    def plan(self) -> ShardingPlan:
        return self.engine.plan

    @property
    def labels(self) -> Dict[str, str]:
        return self.engine.labels

    def serves(self, labels: Dict[str, str]) -> bool:
        """Tenancy check: an engine label that contradicts a request label
        disqualifies; absent engine labels mean 'serves all'."""
        for k, v in labels.items():
            if k in self.labels and self.labels[k] != v:
                return False
        return True


def _default_mesh() -> jax.sharding.Mesh:
    """1-device mesh carrying the full production axis names, so plan specs
    (which reference pod/data/model) always resolve."""
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1, 1),
        ("pod", "data", "model"))


class ServingCluster:
    """Multi-engine serving runtime with label-based fail-closed routing,
    online per-engine reconfiguration, and elastic spawn/retire lifecycle.

    The unlabeled-traffic bucket is tracked under the label value ``"*"``
    in the per-label views (`metrics_by_label`, `queue_depth_by_label`,
    `arrivals`).
    """

    ROUTE_KEY = "data-type"   # the label routing constraints key on
    #: pseudo-label under which `metrics_by_label` surfaces the flight
    #: recorder's ring health (drop counters) when recording is active
    OBS_LABEL = "obs:recorder"
    # retention cap on completions of retired engines: under continuous
    # spawn/retire churn the raw request list would otherwise grow with
    # total traffic ever served; beyond the cap the oldest completions
    # age out and cluster-level aggregates become windowed approximations
    RETIRED_DONE_CAP = 10_000

    def __init__(self, mesh: Optional[jax.sharding.Mesh] = None, *,
                 prepare_worker: Optional[PrepareWorker] = None):
        self.mesh = mesh or _default_mesh()
        self._entries: Dict[str, _EngineEntry] = {}
        self._routes: Dict[str, ShardingPlan] = {}   # label value -> required
        # route constraints beyond the single ROUTE_KEY value: each entry
        # is (selector, required) where selector is a multi-key label
        # mapping (ALL keys must match the request's labels) or an
        # arbitrary predicate callable(labels) -> bool. Matching
        # constraints MERGE with the data-type constraint (fail-closed:
        # conflicting pins degrade to unroutable axes).
        self._selector_routes: List[Tuple[Any, ShardingPlan]] = []
        self.history: List[DowntimeReport] = []
        self.rejected: List[Request] = []
        # serializes the control plane (routing decisions, swap commits,
        # registry mutation) against request threads: a submit observes
        # the cluster strictly before or strictly after a swap, never
        # mid-window. Reentrant: commits call back into routing helpers.
        self._lock = threading.RLock()
        # serializes engine-state surgery (swap commits, KV migration,
        # queue redistribution) against in-flight decode steps: any of
        # these may be driven from a control thread (e.g. an autoscaler
        # loop calling `commit_ready()`/`retire_engine`) while another
        # thread is inside `step()` — surgery landing mid-decode would
        # let the step's output clobber freshly migrated state.
        # Reentrant: a spawn commit redistributes queues under the lock
        # it already holds. Ordering: _lock is always taken BEFORE
        # _step_lock, never the reverse.
        self._step_lock = threading.RLock()
        # fast-path flag for the per-step commit hook: False until an
        # async PREPARE is staged, so pure-sync serving never pays the
        # pending-ticket scan on its hot path
        self._prepare_dirty = False
        # background-PREPARE machinery: worker pool (lazily the process
        # default) + spawn tickets for engines not yet in the registry
        self._prepare_worker = prepare_worker
        self._pending_spawns: Dict[str, PrepareTicket] = {}
        # routing decisions that picked an engine inside its blocking swap
        # window; structurally 0 (the lock serializes) — the concurrency
        # stress tests assert it stays that way
        self.midswap_routes = 0
        # completions of engines that have since been retired — retained so
        # cluster-level metrics never lose traffic to a scale-down
        self._retired_done: List[Request] = []
        # per-label demand counters (submissions, INCLUDING fail-closed
        # rejections — rejected demand is still demand the autoscaler may
        # fix by spawning a compliant engine)
        self._arrivals: Dict[str, int] = {}
        # per-label recently seen prompt lengths (length -> last-seen seq),
        # so a spawn can AOT-compile exactly the live traffic shapes
        self._label_lengths: Dict[str, Dict[int, int]] = {}
        self._length_seq = 0
        # incremental per-label completion aggregates: each engine's done
        # list is folded in once (entry.metrics_seen marks the consumed
        # prefix), so `metrics_by_label` is O(new completions) per call
        # instead of O(all completions ever)
        self._label_folds: Dict[str, RequestAggregate] = {}

    # ------------------------------------------------------------------
    # registration / introspection
    # ------------------------------------------------------------------
    def register(self, name: str, engine: ServingEngine, *,
                 plan: Optional[ShardingPlan] = None,
                 labels: Optional[Dict[str, str]] = None,
                 role: Optional[str] = None,
                 verify_hlo: bool = True) -> None:
        """Add an engine to the routing pool (no AOT warm-up — see
        `spawn_engine` for the elastic path that never JITs while serving).

        Args:
            name: unique engine name.
            engine: the `ServingEngine` to serve through.
            plan: if given, installed as ``engine.plan`` (routing reads the
                live engine, so this is the plan the router checks).
            labels: merged into ``engine.labels`` (tenancy restriction).
            role: if given, installed as ``engine.role`` —
                ``"prefill"``/``"decode"`` engines participate in the
                cluster's disaggregated first-token handoff (see `step`):
                new requests route only to prefill-capable engines
                (``role != "decode"``), and every request resident on a
                prefill-role engine is handed to a decode-role engine at
                its first-token boundary via the batched migration path.
                Non-unified engines get their migration ops pre-warmed
                here so the first handoff never compiles.
            verify_hlo: check the engine's *compiled HLO* against any
                already-installed route constraint it would serve under
                (see `verify_engine_hlo`) — the declared plan alone is a
                claim; the compiled artifact is the proof. Skipped
                automatically when no constraint applies (the common
                register-then-constrain order pays nothing).

        Raises:
            ValueError: if ``name`` is already registered (or reserved by
                an in-flight `spawn_engine_async`), ``role`` is unknown,
                or (fail-closed) the compiled HLO violates an applicable
                route constraint — the engine is NOT registered then.
        """
        with self._lock:
            self._drop_dead_spawns()
            if name in self._entries or name in self._pending_spawns:
                raise ValueError(f"engine {name!r} already registered")
            if plan is not None:
                engine.plan = plan
            if labels:
                engine.labels.update(labels)
            if role is not None:
                engine.role = role         # validates fail-closed
            # insert + verify atomically: the router must never observe
            # (and queue onto) an engine whose registration is about to
            # be rolled back fail-closed
            engine.obs_name = name
            self._entries[name] = _EngineEntry(name, engine)
            if verify_hlo:
                try:
                    self.verify_engine_hlo(name)
                except ValueError:
                    del self._entries[name]
                    raise
        if engine.role != "unified":
            # PREPARE-equivalent for the handoff path: warm the pool
            # surgery ops now, off the serving path, so the first
            # first-token handoff pays no compile inside its pause
            engine.warm_migration()

    def verify_engine_hlo(self, name: str, *, hlo_text: Optional[str] = None,
                          mesh_shape: Optional[Sequence[int]] = None,
                          axis_names: Optional[Sequence[str]] = None,
                          ) -> Optional[str]:
        """Validate an engine's COMPILED decode HLO against the forbidden
        collective axes of every route constraint it could serve under
        (the paper's post-deployment compliance check, applied at
        registration: a plan's restriction fields are a declaration — the
        compiled module's collectives are the artifact-level proof).

        Only constraints whose label the engine serves AND whose plan the
        engine claims to satisfy are checked (a non-eligible engine never
        receives that traffic — the router already fails closed).

        Args:
            name: the registered engine to check.
            hlo_text: override the HLO module text (defaults to the
                engine's `decode_hlo_text`, i.e. the installed/compiled
                decode executable).
            mesh_shape / axis_names: topology to attribute collective
                replica groups to mesh axes (defaults to the cluster
                mesh).

        Returns:
            The check detail string, or ``None`` when no constraint
            applied (nothing to prove).

        Raises:
            KeyError: ``name`` is not registered.
            ValueError: fail-closed — a collective in the compiled module
                crosses a forbidden axis.
        """
        from repro.core.validator import check_hlo_axes   # local: no cycle
        entry = self._entries[name]
        axes: set = set()
        for value, required in self._routes.items():
            if entry.serves({self.ROUTE_KEY: value}) \
                    and plan_satisfies(entry.plan, required):
                axes |= set(required.forbidden_collective_axes)
        for sel, required in self._selector_routes:
            if not plan_satisfies(entry.plan, required):
                continue
            # mapping selectors scope by engine tenancy; a predicate's
            # label space cannot be enumerated — check conservatively
            # (more proof, never less: fail-closed)
            if callable(sel) or entry.serves(dict(sel)):
                axes |= set(required.forbidden_collective_axes)
        if not axes:
            return None
        text = hlo_text if hlo_text is not None \
            else entry.engine.decode_hlo_text()
        ok, msg = check_hlo_axes(
            text, sorted(axes),
            tuple(mesh_shape) if mesh_shape else self.mesh.devices.shape,
            tuple(axis_names) if axis_names else self.mesh.axis_names)
        if not ok:
            raise ValueError(
                f"engine {name!r} failed compiled-HLO validation against "
                f"route constraints (fail-closed): {msg}")
        return msg

    def engine(self, name: str) -> ServingEngine:
        """Return the registered engine ``name``.

        Raises:
            KeyError: if no engine of that name is registered (it may have
                been retired).
        """
        return self._entries[name].engine

    def engines(self) -> List[str]:
        """Names of all registered engines (including draining ones)."""
        with self._lock:
            return list(self._entries)

    def draining(self) -> List[str]:
        """Names of engines currently draining toward retirement."""
        with self._lock:
            return [n for n, e in self._entries.items() if e.draining]

    def route_constraints(self) -> Dict[str, ShardingPlan]:
        """Installed ``data-type`` route constraints: label value ->
        required plan (see `route_predicates` for the selector-based
        ones)."""
        return dict(self._routes)

    def route_predicates(self) -> List[Tuple[Any, ShardingPlan]]:
        """Installed selector-based route constraints: ``(selector,
        required plan)`` pairs, where selector is a multi-key label
        mapping or a predicate callable."""
        with self._lock:
            return list(self._selector_routes)

    @staticmethod
    def _selector_matches(selector: Any, labels: Dict[str, str]) -> bool:
        """Does a request's label set fall under a selector?  Mapping
        selectors require EVERY key to be present with the exact value
        (plain subset semantics — no ontology expansion on request
        labels); callables are arbitrary predicates over the label
        dict."""
        if callable(selector):
            return bool(selector(dict(labels)))
        return all(labels.get(k) == v for k, v in dict(selector).items())

    def required_for(self, labels: Dict[str, str]
                     ) -> Optional[ShardingPlan]:
        """THE route-constraint lookup: the merged required plan for a
        request carrying ``labels`` — its ``data-type`` constraint plus
        every matching selector constraint, merged with the fail-closed
        `merge_restrictions` semantics (conflicting pins degrade to
        unroutable axis forbids). ``None`` when nothing applies."""
        with self._lock:
            reqs: List[ShardingPlan] = []
            value = labels.get(self.ROUTE_KEY)
            if value is not None and value in self._routes:
                reqs.append(self._routes[value])
            for sel, required in self._selector_routes:
                if self._selector_matches(sel, labels):
                    reqs.append(required)
        if not reqs:
            return None
        if len(reqs) == 1:
            return reqs[0]
        return merge_restrictions(ShardingPlan(), *reqs)

    def set_route_constraint(self, value: str,
                             required: ShardingPlan, *,
                             verify_hlo: bool = True) -> None:
        """Require that requests labeled ``data-type=value`` be served only
        by engines whose plan satisfies `required` (see `plan_satisfies`).

        The register-then-constrain order is as fail-closed as the
        reverse: installing a constraint re-validates the compiled HLO of
        every registered engine that would serve it and claims to satisfy
        it. An engine whose compiled artifact disproves its declared plan
        is QUARANTINED (unroutable until a reconfigure passes
        verification) and a ValueError is raised — the constraint stays
        installed either way.

        Raises:
            ValueError: an engine failed compiled-HLO validation (it has
                been quarantined; other engines were still checked).
        """
        self._routes[value] = required
        if not (verify_hlo and required.forbidden_collective_axes):
            return
        self._reverify_engines({self.ROUTE_KEY: value}, required)

    def set_route_predicate(self, selector, required: ShardingPlan, *,
                            verify_hlo: bool = True) -> None:
        """Install a route constraint scoped by a SELECTOR instead of a
        single ``data-type`` value: requests whose labels fall under
        ``selector`` may only be served by engines whose plan satisfies
        ``required`` — fail-closed exactly like `set_route_constraint`
        (no compliant engine means the request is rejected, never
        silently served).

        Args:
            selector: a multi-key label mapping (every key must match
                the request's labels, e.g. ``{"data-type": "phi",
                "app": "patient"}``) or an arbitrary predicate
                ``callable(labels) -> bool``.
            required: the constraint plan (restriction fields only).
            verify_hlo: re-validate the compiled HLO of registered
                engines that would serve under the selector (mapping
                selectors only — a predicate's label space cannot be
                enumerated, so its engines are checked conservatively:
                every engine whose plan claims satisfaction).

        Raises:
            ValueError: an engine failed compiled-HLO validation (it has
                been quarantined; the constraint stays installed).
        """
        with self._lock:
            self._selector_routes.append((selector, required))
        if not (verify_hlo and required.forbidden_collective_axes):
            return
        probe = dict(selector) if not callable(selector) else None
        self._reverify_engines(probe, required)

    def _reverify_engines(self, serve_labels: Optional[Dict[str, str]],
                          required: ShardingPlan) -> None:
        """Re-validate compiled HLO of engines affected by a newly
        installed constraint (``serve_labels=None`` == cannot scope by
        labels; check every plan-satisfying engine, fail-closed)."""
        errors = []
        for e in list(self._entries.values()):
            if e.quarantined or not plan_satisfies(e.plan, required):
                continue
            if serve_labels is not None and not e.serves(serve_labels):
                continue
            try:
                self.verify_engine_hlo(e.name)
            except ValueError as err:
                e.quarantined = True
                errors.append(str(err))
        if errors:
            raise ValueError("; ".join(errors))

    # ------------------------------------------------------------------
    # routing (fail-closed)
    # ------------------------------------------------------------------
    def _entry_eligible(self, e: _EngineEntry, labels: Dict[str, str],
                        required: Optional[ShardingPlan]) -> bool:
        """THE routing-eligibility predicate (one copy, shared by request
        routing, migration, and the autoscaler's capacity view): not
        draining, not HLO-quarantined, tenancy labels don't contradict,
        plan satisfies the route constraint."""
        return (not e.draining and not e.quarantined and e.serves(labels)
                and (required is None or plan_satisfies(e.plan, required)))

    def eligible(self, req: Request) -> List[str]:
        """Engines allowed to serve ``req``: tenancy labels must not
        contradict, the engine's plan must satisfy every route
        constraint matching the request's labels (the ``data-type``
        constraint AND any selector/predicate constraints, merged), and
        the engine must not be draining. A ``role="decode"`` engine is
        never eligible for a NEW request — it has no routed prefill
        duty; it receives in-flight work only through the first-token
        handoff / migration paths (fail-closed: with only decode
        engines for a label, routing rejects rather than mis-placing)."""
        required = self.required_for(dict(req.labels))
        with self._lock:
            return [e.name for e in self._entries.values()
                    if self._entry_eligible(e, req.labels, required)
                    and e.engine.role != "decode"]

    def engines_for_label(self, value: str) -> List[str]:
        """Non-draining engines that could serve traffic labeled
        ``data-type=value`` under the current route constraints (the
        autoscaler's per-label capacity view)."""
        required = self.required_for({self.ROUTE_KEY: value})
        with self._lock:
            return [e.name for e in self._entries.values()
                    if self._entry_eligible(e, {self.ROUTE_KEY: value},
                                            required)]

    def route(self, req: Request) -> str:
        """Pick the least-loaded eligible engine for ``req``.

        Returns:
            The chosen engine name. Running engines are preferred; a paused
            engine still queues (documented lifecycle) but only when no
            running engine qualifies. Draining engines are never chosen.

        Raises:
            RoutingError: if no engine qualifies (fail-closed); the request
                is recorded in ``self.rejected``.
        """
        with self._lock:
            rec = obs_events.RECORDER
            if rec is None:
                return self._route_locked(req)
            # the span opens AFTER the cluster lock is held: routing and
            # swap commits serialize on the same lock, so a route span can
            # never overlap a swap-commit span (the trace PROVES the
            # no-mid-swap-routing invariant; stress tests check it)
            with rec.span("route", track="cluster", rid=req.rid) as args:
                name = self._route_locked(req)
                args["engine"] = name
                return name

    def _route_locked(self, req: Request) -> str:
        names = self.eligible(req)
        if not names:
            self.rejected.append(req)
            raise RoutingError(
                f"no compliant engine for request {req.rid} "
                f"(labels={req.labels}, constraint="
                f"{self._routes.get(req.labels.get(self.ROUTE_KEY))!r}) "
                "— failing closed")
        # an engine inside its blocking swap window is avoided while
        # any alternative exists (queueing on it is still legal — a
        # paused engine queues — but the lock means this is unreachable
        # in practice; the counter proves it to the stress tests)
        avail = [n for n in names if not self._entries[n].swapping]
        running = [n for n in (avail or names)
                   if not self._entries[n].engine.paused]
        chosen = min(running or avail or names,
                     key=lambda n: self._entries[n].engine.load)
        if self._entries[chosen].swapping:
            self.midswap_routes += 1
        return chosen

    def submit(self, req: Request) -> str:
        """Route + enqueue; returns the chosen engine name.

        Demand accounting happens BEFORE routing: per-label arrival counts
        and prompt lengths are recorded even when routing fails closed, so
        the autoscaler can see (and fix) rejected demand.

        Raises:
            RoutingError: if no engine qualifies (fail-closed).
        """
        with self._lock:
            value = req.labels.get(self.ROUTE_KEY, "*")
            self._arrivals[value] = self._arrivals.get(value, 0) + 1
            self._length_seq += 1
            self._label_lengths.setdefault(value, {})[len(req.prompt)] = \
                self._length_seq
            try:
                name = self.route(req)
            except RoutingError:
                rec = obs_events.RECORDER
                if rec is not None:
                    rec.emit("request.reject", rid=req.rid,
                             label="" if value == "*" else value)
                raise
            self._entries[name].engine.submit(req)
            return name

    def arrivals(self) -> Dict[str, int]:
        """Cumulative per-label submission counts (``"*"`` = unlabeled),
        including fail-closed rejections. The `LoadTracker` differences
        these to form arrival rates."""
        with self._lock:
            return dict(self._arrivals)

    def label_prompt_lengths(self, value: str,
                             cap: int = ServingEngine.MAX_AOT_PREFILL
                             ) -> List[int]:
        """Most recently seen distinct prompt lengths for a label (at most
        ``cap``), for AOT-compiling a spawned engine against live shapes."""
        with self._lock:
            seen = dict(self._label_lengths.get(value, {}))
        recent = sorted(seen, key=seen.get)[-cap:]
        return sorted(recent)

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def step(self) -> int:
        """One decode step across all running engines (draining engines
        keep stepping — they must serve out their queues). Returns the
        number of active engine-steps; reaps any engine that finished
        draining.

        A step is the SAFE BOUNDARY of the concurrent-PREPARE state
        machine: any pending swap whose background compile has finished
        (ticket READY) is committed here, before the engines step. It is
        also the handoff boundary of disaggregated serving: after the
        engines step, every request resident on a ``role="prefill"``
        engine (all are past their first token — prefill emits it at
        admission) is handed to a decode-role engine through the batched
        migration path (`_handoff_ready`)."""
        self._commit_ready()
        n = 0
        with self._step_lock:     # a commit never lands mid-decode
            for e in list(self._entries.values()):
                if not e.engine.paused:
                    n += e.engine.step()
        self._handoff_ready()
        with self._lock:
            self._reap_drained()
        return n

    def handoff_ready(self) -> List[MigrationRecord]:
        """Public handoff hook: move every handoff-eligible request from
        prefill-role engines onto decode-role engines now (``step()``
        already does this each step — call directly only when driving
        engines without the cluster step loop). Returns the per-request
        `MigrationRecord`s (``reason="handoff"``)."""
        return self._handoff_ready()

    def _handoff_ready(self) -> List[MigrationRecord]:
        """First-token handoff sweep (disaggregated serving): collect
        decoding residents of every ``role="prefill"`` engine — each
        already holds its first token, stamped by prefill at admission —
        pick the least-loaded eligible ``role="decode"`` destination per
        request, and move each (src, dst) cohort with ONE batched
        migration (`migrate_many` semantics via `_migrate_locked`, so
        the pre-warmed cohort gather keeps the pause compile-free and
        streams stay bitwise identical).

        Never lossy, never truncating: a request no decode engine can
        legally hold (route constraints, lanes, KV memory, or a
        sequence extent beyond the destination's ``s_max``) simply
        stays and finishes decoding on the prefill engine — fail-closed
        placement beats a truncated stream. Draining prefill engines
        still hand off (it accelerates their drain)."""
        with self._lock:
            sources = [e for e in self._entries.values()
                       if e.engine.role == "prefill"
                       and any(r is not None for r in e.engine.slot_req)]
            if not sources:
                return []
            decodes = [e for e in self._entries.values()
                       if e.engine.role == "decode"
                       and not e.draining and not e.quarantined
                       and not e.engine.paused]
            if not decodes:
                return []
            # capacity bookkeeping mirrors `_relocate_for_retirement`:
            # lanes AND token-granular memory per destination, debited
            # as requests are assigned (imports may spend the paged
            # watermark, so budget the full free page list)
            free = {e.name: e.engine.free_slots for e in decodes}
            free_tok = {e.name: (e.engine.pool.free_pages
                                 * e.engine.page_size
                                 if e.engine.paged else e.engine.free_tokens)
                        for e in decodes}
            extra = {e.name: 0 for e in decodes}
            cohorts: Dict[Tuple[str, str], List[int]] = {}
            for se in sources:
                eng = se.engine
                for i, req in enumerate(eng.slot_req):
                    if req is None:
                        continue
                    pos = int(eng.slot_pos[i])
                    need = needed_capacity(req, "decoding", pos, eng.s_max)
                    required = self.required_for(dict(req.labels))
                    cands = [e for e in decodes
                             if self._entry_eligible(e, req.labels,
                                                     required)
                             and need <= e.engine.s_max
                             and free[e.name] > 0
                             and free_tok[e.name]
                             >= e.engine.admission_tokens(need)]
                    if not cands:
                        continue           # decodes in place, fail-closed
                    dst = min(cands,
                              key=lambda e: e.engine.load + extra[e.name])
                    cohorts.setdefault((se.name, dst.name),
                                       []).append(req.rid)
                    extra[dst.name] += 1
                    free[dst.name] -= 1
                    free_tok[dst.name] -= dst.engine.admission_tokens(need)
            records: List[MigrationRecord] = []
            for (src, dst), rids in cohorts.items():
                try:
                    records.extend(self._migrate_locked(src, dst, rids,
                                                        reason="handoff"))
                except (MigrationError, RoutingError):
                    continue       # kept/restored on the prefill engine
            if records:
                rec = obs_events.RECORDER
                if rec is not None:
                    rec.emit("cluster.handoff", moved=len(records),
                             pause_max_s=max(m.pause_s for m in records),
                             bytes_moved=sum(m.bytes_moved
                                             for m in records))
            return records

    def run(self, max_steps: int = 10_000, *,
            wait_pending: bool = False) -> None:
        """Serve until every *running* engine's queue and slots are empty.

        Work queued on a paused engine stays queued (nothing is dropped)
        and is served by the `run()` after that engine's `resume()`.
        Draining engines are stepped until empty, then reaped. Pending
        `DowntimeReport`s are re-finalized with the post-swap window.

        Args:
            max_steps: decode-step budget (idle waiting does not count).
            wait_pending: also wait for in-flight background PREPAREs —
                the loop keeps serving while the worker compiles and only
                returns once every pending ticket reached a terminal
                state (its swap committed at a step boundary)."""
        steps = 0
        while steps < max_steps:
            with self._lock:   # registry may be mutated by a commit
                entries = list(self._entries.values())
            busy = any(
                e.engine.queue or any(r is not None
                                      for r in e.engine.slot_req)
                for e in entries if not e.engine.paused)
            if busy:
                self.step()                # commits READY swaps itself
                steps += 1
            elif wait_pending and self.prepare_pending():
                time.sleep(0.001)          # idle but a compile is in flight
                self._commit_ready()
            else:
                break
        with self._lock:
            self._reap_drained()
            self._refresh_reports()

    def metrics(self, name: Optional[str] = None) -> Dict[str, float]:
        """TTFT/TPOT summary (full `METRIC_KEYS` set, NaN when undefined).

        Args:
            name: a specific engine's metrics; with ``None``, the
                cluster-wide aggregate over every registered engine —
                including engines registered after traffic started — plus
                the retained completions of retired engines.

        Raises:
            KeyError: if ``name`` is given but not registered.
        """
        if name is not None:
            return self._entries[name].engine.metrics()
        with self._lock:
            done: List[Request] = list(self._retired_done)
            for e in self._entries.values():
                done.extend(e.engine.done)
        return compute_metrics(done)

    def _known_labels(self, extra: Sequence[str] = ()) -> set:
        with self._lock:
            vals = set(extra) | set(self._routes) | set(self._arrivals)
            for sel, _ in self._selector_routes:
                if not callable(sel):
                    v = dict(sel).get(self.ROUTE_KEY)
                    if v:
                        vals.add(v)
            for e in self._entries.values():
                v = e.labels.get(self.ROUTE_KEY)
                if v:
                    vals.add(v)
            return vals

    def _fold_completions_locked(self) -> None:
        """Fold each engine's not-yet-consumed completions (the
        ``done[metrics_seen:]`` suffix) into the per-label incremental
        aggregates. Called under ``self._lock``."""
        for e in self._entries.values():
            done = e.engine.done
            if e.metrics_seen >= len(done):
                continue
            role = e.engine.role
            for r in done[e.metrics_seen:]:
                v = r.labels.get(self.ROUTE_KEY, "*")
                agg = self._label_folds.get(v)
                if agg is None:
                    agg = self._label_folds[v] = RequestAggregate()
                agg.observe(r.ttft, r.tpot)
                # disaggregated serving: completions on role-tagged
                # engines additionally aggregate under a "role:<role>"
                # pseudo-label so `metrics_by_label` surfaces per-role
                # TTFT/TPOT (unified engines add no extra keys — the
                # legacy label universe is unchanged)
                if role != "unified":
                    rv = f"role:{role}"
                    ragg = self._label_folds.get(rv)
                    if ragg is None:
                        ragg = self._label_folds[rv] = RequestAggregate()
                    ragg.observe(r.ttft, r.tpot)
            e.metrics_seen = len(done)

    def metrics_by_label(self, extra_labels: Sequence[str] = ()
                         ) -> Dict[str, Dict[str, float]]:
        """Per-label TTFT/TPOT aggregation over live + retired completions.

        Every known label (route constraints, engine labels, observed
        arrivals, plus ``extra_labels``) is present in the result —
        zero-filled (``completed=0``, NaN stats) when it has no traffic —
        so the `LoadTracker` can index unconditionally. Unlabeled traffic
        aggregates under ``"*"``.

        Incremental: each completion is folded into a per-label
        `repro.obs.metrics.RequestAggregate` exactly once, so a call
        costs O(completions since the previous call), not O(every
        completion ever) — means are exact, p99 comes from the log-
        bucketed sketch (~5% relative error vs the old full rescan).
        """
        with self._lock:
            self._fold_completions_locked()
            labels = self._known_labels(extra_labels) | set(self._label_folds)
            out = {v: (self._label_folds[v].metrics()
                       if v in self._label_folds else compute_metrics([]))
                   for v in labels}
        # recorder ring health rides along under a pseudo-label (same
        # pattern as the "role:<role>" keys): silent event/span drops
        # would corrupt attribution and the SLO ledger, so they must be
        # visible wherever per-label metrics are consumed
        rec = obs_events.RECORDER
        if rec is not None:
            out[self.OBS_LABEL] = dict(
                compute_metrics([]),
                events_emitted=float(rec.bus.emitted),
                events_dropped=float(rec.bus.dropped),
                spans_added=float(rec.trace.added),
                spans_dropped=float(rec.trace.dropped))
        return out

    def drain_completed(self) -> List[Request]:
        """Pop and return every retained completed request (live engines'
        done lists + the retired-engine retention buffer), in no
        particular order.

        The scale-replay harness consumes completions incrementally
        through this method: at 10^5+ requests the cumulative
        `metrics_by_label` scan is O(total completions) per call, while
        draining is O(completions since the last drain) and keeps
        resident memory bounded. After a drain, the cumulative
        ``metrics*`` views only see completions retired later — callers
        own the popped requests and any windowed aggregation over them
        (pending `DowntimeReport`s are unaffected: they auto-finalize
        with the empty window at commit time)."""
        with self._step_lock:      # same order as step(): step -> registry
            with self._lock:
                out: List[Request] = list(self._retired_done)
                self._retired_done.clear()
                for e in self._entries.values():
                    if e.engine.done:
                        out.extend(e.engine.done)
                        e.engine.done.clear()
                    e.metrics_seen = 0
                # drained completions leave the cumulative views entirely
                # (documented semantics) — the incremental folds restart
                self._label_folds.clear()
        return out

    def queue_depth_by_label(self, extra_labels: Sequence[str] = ()
                             ) -> Dict[str, int]:
        """Queued + resident request counts per label across all engines
        (zero-filled over the same label universe as `metrics_by_label`)."""
        out: Dict[str, int] = {v: 0 for v in self._known_labels(extra_labels)}
        with self._lock:
            for e in self._entries.values():
                live = list(e.engine.queue) + [r for r in e.engine.slot_req
                                               if r is not None]
                for r in live:
                    v = r.labels.get(self.ROUTE_KEY, "*")
                    out[v] = out.get(v, 0) + 1
        return out

    def queued_tokens_by_label(self, extra_labels: Sequence[str] = ()
                               ) -> Dict[str, int]:
        """Token-granular queue depth: outstanding KV tokens per label —
        a queued request demands its full clamped extent (prompt +
        generation budget, capped at the engine's ``s_max``), a resident
        one its remaining extent. Same zero-filled label universe as
        `queue_depth_by_label`; this is the demand signal a paged pool's
        admission actually meters (two short requests are half the load
        of one long one, which request counts cannot see)."""
        out: Dict[str, int] = {v: 0 for v in self._known_labels(extra_labels)}
        with self._lock:
            for e in self._entries.values():
                s_max = e.engine.s_max
                for r in e.engine.queue:
                    v = r.labels.get(self.ROUTE_KEY, "*")
                    out[v] = out.get(v, 0) + min(
                        len(r.prompt) + r.max_new_tokens, s_max)
                for i, r in enumerate(e.engine.slot_req):
                    if r is None:
                        continue
                    v = r.labels.get(self.ROUTE_KEY, "*")
                    need = min(len(r.prompt) + r.max_new_tokens, s_max)
                    out[v] = out.get(v, 0) + max(
                        need - int(e.engine.slot_pos[i]), 0)
        return out

    def kv_utilization(self) -> Dict[str, float]:
        """Per-engine KV utilization (used / allocated tokens) plus the
        allocation-weighted cluster aggregate under ``"*"`` — the
        slot-padding-waste signal (a slot-granular engine full of short
        requests reads low; a paged engine's right-sized reservations
        read high). Engines with nothing resident report 0.0 and weigh
        nothing in the aggregate.

        Only ROUTABLE capacity is reported: draining (retired-but-
        unreaped) and quarantined engines are excluded from the map and
        the aggregate — their residual allocations are not capacity the
        autoscaler can rebalance onto, and a stale entry here would
        poison the rebalance-over-spawn decision."""
        with self._lock:
            entries = [e for e in self._entries.values()
                       if not e.draining and not e.quarantined]
        out: Dict[str, float] = {}
        used = alloc = 0
        for e in entries:
            out[e.name] = e.engine.kv_utilization
            used += e.engine.kv_used_tokens
            alloc += e.engine.kv_allocated_tokens
        out["*"] = used / alloc if alloc else 0.0
        return out

    # ------------------------------------------------------------------
    # online reconfiguration (compile-ahead + blocking swap)
    #
    # One pending-swap state machine serves every caller: the sync paths
    # (`reconfigure`, `spawn_engine`, `rebalance`, `apply_policy`) stage
    # a ticket, run PREPARE inline and commit immediately; the async
    # paths (`reconfigure_async`, `spawn_engine_async`) hand PREPARE to
    # the `PrepareWorker` and the swap commits at the next safe step
    # boundary (`step()` / `run()` / `commit_ready()`).
    # ------------------------------------------------------------------
    def _worker(self) -> PrepareWorker:
        if self._prepare_worker is None:
            self._prepare_worker = default_worker()
        return self._prepare_worker

    def _prepare_closure(self, engine: ServingEngine, plan: ShardingPlan,
                         lengths: Sequence[int], prefill_buckets: bool,
                         shardings: Optional[Dict[str, Any]] = None,
                         warm: Optional[Any] = None):
        """THE PREPARE body (one copy for reconfigure and spawn): run the
        optional out-of-process warmer, materialize shardings, AOT-compile
        — returns the payload dict `_commit_ticket` installs."""
        def _prepare() -> Dict[str, Any]:
            if warm is not None:
                warm()
            sh = shardings
            if sh is None:
                sh = plan_to_shardings(
                    engine.model.cfg, plan, self.mesh,
                    n_slots=engine.cache_batch)
            # pre-compile the device_put TRANSFER programs for the
            # target layout (jax caches them by shape/dtype/sharding):
            # the blocking swap window migrates the live trees with
            # these exact transfers and must not pay their first-call
            # compile — the same compile-ahead discipline the
            # executables get. Probe trees are freed immediately.
            import jax.numpy as jnp
            for key, tree in (("params", engine.params),
                              ("cache", engine.cache)):
                if key in sh:
                    probe = jax.device_put(
                        jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype),
                                     tree), sh[key])
                    jax.block_until_ready(jax.tree.leaves(probe))
                    del probe
            executables, n_compiled = engine.aot_executables(
                sh, prefill_lengths=lengths,
                prefill_buckets=prefill_buckets)
            return {"shardings": sh, "executables": executables,
                    "n_compiled": n_compiled}
        return _prepare

    def _stage_reconfigure(self, name: str, plan: ShardingPlan, *,
                           shardings: Optional[Dict[str, Any]],
                           prefill_lengths: Sequence[int],
                           prefill_buckets: bool,
                           inline: bool,
                           warm: Optional[Any] = None) -> PrepareTicket:
        """Create the pending-swap ticket for an engine (superseding any
        older pending ticket) and start its PREPARE."""
        with self._lock:
            entry = self._entries[name]
            if entry.draining:
                raise ValueError(f"engine {name!r} is draining — a "
                                 "retiring engine cannot be reconfigured")
            eng = entry.engine
            # snapshot on THIS thread: the worker must never iterate the
            # live seen-lengths dict while request threads mutate it
            lengths = tuple(prefill_lengths) or eng.recent_prompt_lengths()
            ticket = PrepareTicket(name, "reconfigure", plan)
            if entry.pending_ticket is not None:
                # a newer plan supersedes the old pending swap — its
                # executables (finished or not) are never installed
                entry.pending_ticket.cancel(superseded_by=ticket)
            entry.pending_ticket = ticket
            self._prepare_dirty = True
        prepare = self._prepare_closure(eng, plan, lengths, prefill_buckets,
                                        shardings=shardings, warm=warm)
        if inline:
            PrepareWorker.run_inline(ticket, prepare)
        else:
            self._worker().submit(ticket, prepare)
        return ticket

    def reconfigure_async(self, name: str, plan: ShardingPlan, *,
                          shardings: Optional[Dict[str, Any]] = None,
                          prefill_lengths: Sequence[int] = (),
                          prefill_buckets: bool = False,
                          warm: Optional[Any] = None,
                          ) -> PrepareTicket:
        """Swap a live engine onto ``plan`` WITHOUT blocking the caller:
        PREPARE runs on the background `PrepareWorker` while serving
        continues, and the blocking SWAP commits at the next safe step
        boundary after the compile finishes.

        If the engine already has a pending (uncommitted) swap, the older
        ticket is CANCELLED — superseded by this one — and its
        executables are never installed.

        Args: as `reconfigure`, plus:
            warm: optional zero-arg callable the worker runs BEFORE the
                in-process compile. On accelerator hosts compilation is
                host-side work and never contends with device decode; on
                CPU-only hosts pass a warmer that compiles the same
                modules in a SUBPROCESS against JAX's persistent
                compilation cache, so the in-process compile (which must
                hold the GIL through tracing/lowering) becomes a cheap
                cache hit — see benchmarks/overlap_prepare.py for the
                worked pattern.

        Returns:
            The `PrepareTicket`; poll ``ticket.done()`` while stepping
            (or ``cluster.run(wait_pending=True)``), then
            ``ticket.result()`` for the `DowntimeReport`.

        Raises:
            KeyError: if ``name`` is not registered.
            ValueError: if the engine is draining toward retirement.
        """
        return self._stage_reconfigure(
            name, plan, shardings=shardings,
            prefill_lengths=prefill_lengths,
            prefill_buckets=prefill_buckets, inline=False, warm=warm)

    def reconfigure(self, name: str, plan: ShardingPlan, *,
                    shardings: Optional[Dict[str, Any]] = None,
                    prefill_lengths: Sequence[int] = (),
                    prefill_buckets: bool = False,
                    ) -> DowntimeReport:
        """Swap a live engine onto ``plan`` (PREPARE / SWAP / RESUME),
        blocking until the swap committed (the async path is
        `reconfigure_async`; both run the same state machine).

        Args:
            name: the engine to reconfigure.
            plan: the target `ShardingPlan`.
            shardings: pre-materialized sharding trees; derived from the
                plan via `plan_to_shardings` when omitted.
            prefill_lengths: prompt lengths to AOT-compile; defaults to the
                engine's recently seen lengths.
            prefill_buckets: also AOT-compile the padded-bucket prefill
                ladder so prompt lengths never seen before avoid the JIT
                fallback too (see `ServingEngine.aot_executables`).

        Returns:
            The (auto-finalizing) `DowntimeReport` for this swap.

        Raises:
            KeyError: if ``name`` is not registered.
            ValueError: if the engine is draining toward retirement — a
                retiring engine never pays a swap window — or the
                post-swap compiled-HLO verification failed (the engine
                is quarantined, fail-closed).
            PrepareCancelled: a concurrent caller superseded this swap
                (issued a newer plan) or retired the engine before the
                commit — nothing was installed.
        """
        ticket = self._stage_reconfigure(
            name, plan, shardings=shardings,
            prefill_lengths=prefill_lengths,
            prefill_buckets=prefill_buckets, inline=True)
        if ticket.state == FAILED:         # PREPARE raised: propagate as-is
            with self._lock:
                entry = self._entries.get(name)
                if entry is not None and entry.pending_ticket is ticket:
                    entry.pending_ticket = None
            raise ticket.error
        report = self._commit_ticket(ticket)
        if report is None:
            # superseded/cancelled (result() raises PrepareCancelled), or
            # a concurrently stepping thread won the commit race — then
            # result() returns that thread's report, re-raising any
            # post-swap verification failure it recorded (fail-closed,
            # same contract as the direct-commit path above)
            report = ticket.result()
        return report

    def _commit_ticket(self, ticket: PrepareTicket
                       ) -> Optional[DowntimeReport]:
        """Commit one READY ticket's blocking swap; returns None when the
        ticket is not READY (or its target vanished, abandoning it).

        Raises:
            ValueError: the post-swap compiled-HLO verification failed —
                the swap WAS paid and its report recorded, but the engine
                is quarantined (fail-closed routing). For a spawn the
                engine is rolled back out of the pool instead and the
                ticket marked FAILED.
        """
        payload = ticket._take_for_commit()
        if payload is None:
            return None
        if ticket.kind == "spawn":
            return self._commit_spawn(ticket, payload)
        with self._lock:
            entry = self._entries.get(ticket.engine)
            if (entry is None or entry.draining
                    or entry.pending_ticket is not ticket):
                ticket._abandon()          # retired/superseded meanwhile
                return None
            eng = entry.engine
            # a still-pending previous report gets its honest final window
            # now (possibly empty) rather than being silently dropped
            self._finalize_pending(entry)
            # window since the previous swap (everything, on the first),
            # so repeated reconfigurations compare like-for-like windows
            metrics_before = compute_metrics(
                [r for r in eng.done if r.t_done >= entry.swap_t])

            # ---- SWAP (blocking window — no compilation here) ----
            entry.swapping = True
            t0 = time.time()
            try:
                with self._step_lock:   # never lands mid-decode-step
                    eng.pause()
                    try:
                        eng.drain()
                        migrate_bytes = eng.swap_plan(
                            ticket.plan, shardings=payload["shardings"],
                            executables=payload["executables"])
                    finally:
                        # a failed swap must never strand the engine
                        # paused — traffic routed to it would otherwise
                        # sit queued forever
                        eng.resume()
            except BaseException as err:
                # a failed install must never wedge the state machine:
                # the ticket fails (result() re-raises this), the engine
                # keeps serving under its old plan/executables
                entry.pending_ticket = None
                ticket._commit_failed(err)
                raise
            finally:
                entry.swapping = False
            downtime_s = time.time() - t0
            rec = obs_events.RECORDER
            if rec is not None:
                # recorded under the SAME cluster lock as routing: a
                # swap-commit span can never interleave a route span
                rec.span_at("swap.commit", t0, downtime_s,
                            track=ticket.engine, cat="reconfig",
                            engine=ticket.engine)
                rec.emit("cluster.swap", engine=ticket.engine,
                         downtime_s=downtime_s, prepare_s=ticket.prepare_s,
                         compiled_in_prepare=payload["n_compiled"])

            # ---- RESUME + auto-finalized report ----
            report = DowntimeReport(
                prepare_s=ticket.prepare_s, downtime_s=downtime_s,
                migrate_bytes=migrate_bytes,
                metrics_before=metrics_before,
                # auto-finalized to the empty post-swap window (full key
                # set); _refresh_reports swaps in real post-swap traffic
                metrics_after=compute_metrics([]),
                engine=ticket.engine, compiled_in_prepare=payload["n_compiled"])
            entry.pending_report = report
            entry.swap_t = time.time()
            entry.pending_ticket = None
            self.history.append(report)

            # the freshly installed executable must prove whatever route
            # constraints the new plan claims (clears a quarantine on
            # pass; quarantines on failure — fail-closed, the plan stays
            # installed but the router skips the engine). The report is
            # recorded either way: the blocking window was really paid.
            # Verified BEFORE the ticket wakes its waiters, so a racing
            # caller can never observe SWAPPED with the error still unset.
            verify_error: Optional[ValueError] = None
            try:
                self.verify_engine_hlo(ticket.engine)
                entry.quarantined = False
            except ValueError as err:
                entry.quarantined = True
                ticket.error = err
                verify_error = err
            ticket._committed(report)
            if verify_error is not None:
                raise verify_error
            return report

    def _commit_ready(self) -> List[DowntimeReport]:
        """Commit every READY pending swap (the safe-step-boundary hook
        `step()`/`run()` call). Terminal leftovers (cancelled/failed
        tickets) are unlinked. Verification failures quarantine the
        engine and are recorded on the ticket, never raised here — the
        serving loop must keep turning."""
        if not self._prepare_dirty:        # pure-sync serving: free
            return []
        out: List[DowntimeReport] = []
        with self._lock:
            pending = [(e, e.pending_ticket)
                       for e in list(self._entries.values())
                       if e.pending_ticket is not None]
            spawns = list(self._pending_spawns.items())
            if not pending and not spawns:
                self._prepare_dirty = False
                return []
        for entry, t in pending:
            if t.state in (CANCELLED, FAILED):
                with self._lock:
                    if entry.pending_ticket is t:
                        entry.pending_ticket = None
            elif t.state == READY:
                try:
                    report = self._commit_ticket(t)
                except Exception:
                    # recorded on the ticket: either FAILED (install
                    # error — report stays None) or SWAPPED + quarantined
                    # (verify failure after a really-paid window)
                    report = t.report
                if report is not None:
                    out.append(report)
        for name, t in spawns:
            if t.state in (CANCELLED, FAILED):
                with self._lock:
                    if self._pending_spawns.get(name) is t:
                        del self._pending_spawns[name]
            elif t.state == READY:
                try:
                    report = self._commit_ticket(t)
                except Exception:
                    report = None          # rolled back; ticket FAILED
                if report is not None:
                    out.append(report)
        return out

    def commit_ready(self) -> List[DowntimeReport]:
        """Public step-boundary hook: commit every pending swap whose
        background PREPARE has finished. Returns the committed reports
        (usually empty — `step()`/`run()` already call this)."""
        return self._commit_ready()

    def prepare_pending(self) -> List[PrepareTicket]:
        """Tickets still in flight (PREPARING or READY-but-uncommitted),
        reconfigures and spawns alike. Empty == nothing pending."""
        with self._lock:
            out = [e.pending_ticket for e in self._entries.values()
                   if e.pending_ticket is not None
                   and not e.pending_ticket.done()]
            out.extend(t for t in self._pending_spawns.values()
                       if not t.done())
            return out

    # ------------------------------------------------------------------
    # elastic lifecycle (spawn / retire / rebalance) — autoscaler hooks
    # ------------------------------------------------------------------
    def _stage_spawn(self, name: str, engine: ServingEngine, *,
                     plan: Optional[ShardingPlan],
                     labels: Optional[Dict[str, str]],
                     prefill_lengths: Sequence[int],
                     prefill_buckets: bool,
                     inline: bool,
                     warm: Optional[Any] = None,
                     role: Optional[str] = None) -> PrepareTicket:
        with self._lock:
            self._drop_dead_spawns()
            if name in self._entries or name in self._pending_spawns:
                raise ValueError(f"engine {name!r} already registered")
            if plan is not None:
                engine.plan = plan
            if labels:
                engine.labels.update(labels)
            if role is not None:
                engine.role = role         # validates fail-closed
            ticket = PrepareTicket(name, "spawn", engine.plan,
                                   engine_obj=engine)
            self._pending_spawns[name] = ticket
            self._prepare_dirty = True
        prepare = self._prepare_closure(engine, engine.plan,
                                        tuple(prefill_lengths),
                                        prefill_buckets, warm=warm)
        if inline:
            PrepareWorker.run_inline(ticket, prepare)
        else:
            self._worker().submit(ticket, prepare)
        return ticket

    def _commit_spawn(self, ticket: PrepareTicket,
                      payload: Dict[str, Any]) -> Optional[DowntimeReport]:
        """Install a READY spawn and join it to the routing pool."""
        with self._lock:
            name = ticket.engine
            if self._pending_spawns.get(name) is not ticket \
                    or name in self._entries:
                ticket._abandon()          # cancelled/replaced meanwhile
                return None
            engine: ServingEngine = ticket._engine_obj

            # ---- install + join the routing pool ----
            # under the step lock: joining the pool redistributes queued
            # work across live engines, which must not interleave with a
            # decode step admitting from those same queues
            t0 = time.time()
            with self._step_lock:
                engine.pause()
                try:
                    migrate_bytes = engine.swap_plan(
                        engine.plan, shardings=payload["shardings"],
                        executables=payload["executables"])
                except BaseException as err:
                    # never wedge the state machine on a failed install:
                    # the spawn fails (result() re-raises), nothing
                    # joined the pool
                    del self._pending_spawns[name]
                    ticket._commit_failed(err)
                    raise
                finally:
                    engine.resume()
                engine.obs_name = name
                entry = _EngineEntry(name, engine)
                self._entries[name] = entry
                try:
                    # the compiled artifact (already in hand from
                    # PREPARE) must prove the route constraints its plan
                    # claims
                    self.verify_engine_hlo(name)
                except ValueError as err:
                    del self._entries[name]
                    del self._pending_spawns[name]
                    ticket._commit_failed(err)
                    raise
                downtime_s = time.time() - t0
                rec = obs_events.RECORDER
                if rec is not None:
                    rec.span_at("spawn.commit", t0, downtime_s,
                                track=name, cat="reconfig", engine=name)
                    rec.emit("cluster.spawn", engine=name,
                             downtime_s=downtime_s,
                             prepare_s=ticket.prepare_s,
                             compiled_in_prepare=payload["n_compiled"])

                report = DowntimeReport(
                    prepare_s=ticket.prepare_s, downtime_s=downtime_s,
                    migrate_bytes=migrate_bytes,
                    metrics_before=compute_metrics([]),
                    metrics_after=compute_metrics([]),
                    engine=name, compiled_in_prepare=payload["n_compiled"],
                    event="spawn")
                entry.pending_report = report
                entry.swap_t = time.time()
                del self._pending_spawns[name]
                self.history.append(report)
                ticket._committed(report)
                # disaggregated roles: warm the pool-surgery ops now
                # (AFTER swap_plan, which invalidates the warm flag),
                # outside the measured downtime, so the engine's first
                # handoff never compiles
                if engine.role != "unified":
                    engine.warm_migration()
                # new capacity takes its share of the backlog at once
                if engine.labels.get(self.ROUTE_KEY):
                    self.redistribute_queued(engine.labels[self.ROUTE_KEY])
                else:
                    for value in self._known_labels():
                        self.redistribute_queued(value)
            return report

    def spawn_engine_async(self, name: str, engine: ServingEngine, *,
                           plan: Optional[ShardingPlan] = None,
                           labels: Optional[Dict[str, str]] = None,
                           prefill_lengths: Sequence[int] = (),
                           prefill_buckets: bool = False,
                           warm: Optional[Any] = None,
                           role: Optional[str] = None,
                           ) -> PrepareTicket:
        """Bring a NEW engine online WITHOUT blocking the caller: its
        PREPARE-phase AOT compile runs on the background `PrepareWorker`
        and the engine joins the routing pool at the next safe step
        boundary after the compile finishes (a scale-up never stalls the
        tick loop). Until then the engine is invisible to routing; the
        reserved name is listed by `pending_spawns`.

        Args: as `spawn_engine`; ``warm`` as in `reconfigure_async` (the
        out-of-process compile-cache warmer for CPU-only hosts);
        ``role`` as in `register`.

        Returns:
            The `PrepareTicket` (``kind="spawn"``); ``ticket.result()``
            is the spawn's `DowntimeReport` once committed.

        Raises:
            ValueError: ``name`` is registered or already pending.
        """
        return self._stage_spawn(
            name, engine, plan=plan, labels=labels,
            prefill_lengths=prefill_lengths,
            prefill_buckets=prefill_buckets, inline=False, warm=warm,
            role=role)

    def spawn_engine(self, name: str, engine: ServingEngine, *,
                     plan: Optional[ShardingPlan] = None,
                     labels: Optional[Dict[str, str]] = None,
                     prefill_lengths: Sequence[int] = (),
                     prefill_buckets: bool = False,
                     role: Optional[str] = None,
                     ) -> DowntimeReport:
        """Bring a NEW engine online through the PREPARE-phase AOT path.

        The engine's params/cache are migrated onto shardings materialized
        from its plan and its prefill/decode executables are AOT-compiled
        BEFORE it joins the routing pool — a spawned engine never JITs on
        the serving path. Existing engines keep serving throughout; the
        report's ``downtime_s`` only covers the spawn's own install window.
        (`spawn_engine_async` is the non-blocking variant; both run the
        same pending-swap state machine.)

        Args:
            name: unique engine name.
            engine: a freshly built `ServingEngine` (e.g. from an
                autoscaler factory).
            plan: installed as the engine's plan before materialization.
            labels: merged into the engine's labels (e.g. dedicate it to
                one ``data-type``).
            prefill_lengths: prompt lengths to AOT-compile (typically
                `label_prompt_lengths` of the label being scaled).
            prefill_buckets: also AOT-compile the padded-bucket prefill
                ladder (unseen lengths never JIT either).
            role: if given, installed as ``engine.role`` before the
                engine joins the pool (see `register` — a non-unified
                engine joins with its handoff migration ops pre-warmed).

        Returns:
            A `DowntimeReport` with ``event="spawn"`` (``metrics_before``
            is the empty window; ``metrics_after`` finalizes once the
            engine serves traffic).

        Raises:
            ValueError: if ``name`` is already registered, or (fail-closed)
                the AOT-compiled decode HLO violates an applicable route
                constraint (`verify_engine_hlo` — the spawn is rolled
                back).
        """
        ticket = self._stage_spawn(
            name, engine, plan=plan, labels=labels,
            prefill_lengths=prefill_lengths,
            prefill_buckets=prefill_buckets, inline=True, role=role)
        if ticket.state == FAILED:         # PREPARE raised: propagate as-is
            with self._lock:
                if self._pending_spawns.get(name) is ticket:
                    del self._pending_spawns[name]
            raise ticket.error
        report = self._commit_ticket(ticket)
        if report is None:                 # cancelled before our commit
            return ticket.result()         # raises PrepareCancelled
        return report

    def _drop_dead_spawns(self) -> None:
        """Unlink CANCELLED/FAILED spawn reservations (requires _lock):
        a failed spawn must not squat on its name until the next step
        boundary happens to sweep it."""
        for n, t in list(self._pending_spawns.items()):
            if t.state in (CANCELLED, FAILED):
                del self._pending_spawns[n]

    def pending_spawns(self) -> List[str]:
        """Names reserved by in-flight `spawn_engine_async` tickets (the
        engines are NOT yet in the routing pool)."""
        with self._lock:
            self._drop_dead_spawns()
            return list(self._pending_spawns)

    def pending_spawn_labels(self) -> Dict[str, int]:
        """In-flight spawn capacity per ``data-type`` label: how many
        `spawn_engine_async` tickets are still compiling toward each
        label (unlabeled spawns count under ``"*"``). Capacity that is
        already being built — the ticket-aware `ElasticPolicy` and the
        `WorkloadPlanner` count it as existing so bursty load cannot
        trigger duplicate spawns beyond the suppression window."""
        with self._lock:
            self._drop_dead_spawns()
            out: Dict[str, int] = {}
            for t in self._pending_spawns.values():
                if t.done():
                    continue
                labels = getattr(t._engine_obj, "labels", {}) or {}
                v = labels.get(self.ROUTE_KEY, "*")
                out[v] = out.get(v, 0) + 1
            return out

    def pending_spawn_roles(self) -> Dict[str, Dict[str, int]]:
        """In-flight spawn capacity per label, split by engine role:
        ``{label: {role: count}}`` over `spawn_engine_async` tickets
        still compiling. The role-aware `WorkloadPlanner` counts a
        pending prefill spawn as existing prefill capacity (and so on),
        so a slow compile cannot trigger duplicate role spawns."""
        with self._lock:
            self._drop_dead_spawns()
            out: Dict[str, Dict[str, int]] = {}
            for t in self._pending_spawns.values():
                if t.done():
                    continue
                eng = t._engine_obj
                labels = getattr(eng, "labels", {}) or {}
                v = labels.get(self.ROUTE_KEY, "*")
                role = getattr(eng, "role", "unified")
                by_role = out.setdefault(v, {})
                by_role[role] = by_role.get(role, 0) + 1
            return out

    def migrate_requests(self, src: str, dst: str,
                         rids: Optional[Sequence[int]] = None, *,
                         reason: str = ""
                         ) -> List[MigrationRecord]:
        """Live-migrate in-flight requests from ``src`` to ``dst``:
        export each request's per-slot state (KV slices, decode position,
        generated tokens, metric stamps), reshard it onto the
        destination pool's layout, and resume decode there — no
        recompilation, no re-run of prefill, token streams bitwise
        identical to an unmigrated run.

        Fail-closed, and ATOMIC with respect to validation: every request
        is pre-flighted — destination eligibility (the same predicate the
        router uses: tenancy labels + route-constraint `plan_satisfies`),
        pool capacity, and free decode slots — BEFORE any state moves, so
        a rejected batch leaves the cluster exactly as it was. A transfer
        failure mid-batch (exceptional after pre-flight) restores that
        request to ``src``; earlier requests of the batch remain moved.

        Args:
            src: source engine (may be draining — that is the retire
                fast path).
            dst: destination engine (must not be draining).
            rids: requests to move; every resident + queued request on
                ``src`` when omitted. An explicitly empty batch is a
                no-op: no pause span, no downtime, no engine touched.
            reason: stamped on each `MigrationRecord` and its
                ``migration.pause`` event (``"handoff"`` for the
                first-token prefill→decode handoff — the SLO ledger
                buckets pause time by it).

        Returns:
            One `MigrationRecord` per moved request (pause measured
            export→import).

        Raises:
            KeyError: unknown engine or ``rids`` entry not on ``src``
                (nothing moved).
            ValueError: ``src == dst``, ``dst`` is draining, or ``rids``
                contains duplicates (nothing moved).
            RoutingError: ``dst`` is not eligible for a request's labels
                (fail-closed; nothing moved).
            MigrationError: ``dst`` cannot hold the batch — a request's
                sequence capacity or the free-slot count (nothing moved);
                or a transfer failed mid-batch (that request restored).
        """
        if src == dst:
            raise ValueError("source and destination are the same engine")
        with self._lock:
            return self._migrate_locked(src, dst, rids, reason=reason)

    def _migrate_locked(self, src: str, dst: str,
                        rids: Optional[Sequence[int]], *,
                        reason: str = ""
                        ) -> List[MigrationRecord]:
        se, de = self._entries[src], self._entries[dst]
        if de.draining:
            raise ValueError(f"destination {dst!r} is draining — a "
                             "retiring engine cannot receive migrations")
        if rids is None:
            rids = [r.rid for r in se.engine.slot_req if r is not None] \
                + [r.rid for r in se.engine.queue]
        if not rids:
            # empty cohort (nothing in flight, or every candidate was
            # filtered upstream): a migration that moves nothing must
            # cost nothing — no warm-up, no drain barrier, no pause
            # span, downtime identically 0
            return []
        if len(set(rids)) != len(rids):
            raise ValueError(f"duplicate rids in migration batch: {rids}")
        # ---- pre-flight: validate the WHOLE batch before moving anything
        resident = {r.rid: i for i, r in enumerate(se.engine.slot_req)
                    if r is not None}
        queued = {r.rid: r for r in se.engine.queue}
        decode_needs: List[int] = []   # per decoding request, in tokens
        for rid in rids:
            if rid in resident:
                slot = resident[rid]
                req = se.engine.slot_req[slot]
                phase, pos = "decoding", int(se.engine.slot_pos[slot])
            elif rid in queued:
                req, phase = queued[rid], "queued"
                pos = len(req.prompt)
            else:
                raise KeyError(f"request {rid} is not on engine {src!r}")
            required = self.required_for(dict(req.labels))
            if not self._entry_eligible(de, req.labels, required):
                raise RoutingError(
                    f"engine {dst!r} may not serve request {rid} "
                    f"(labels={req.labels}, constraint={required!r}) — "
                    "failing closed, nothing moved")
            # role discipline: a decode-role engine cannot prefill, so a
            # queued (not-yet-prefilled) request may never land on one;
            # a decoding request on a prefill-role engine would only be
            # handed straight off again — both refused, nothing moved
            if phase == "queued" and de.engine.role == "decode":
                raise RoutingError(
                    f"request {rid} is still queued (needs prefill) but "
                    f"{dst!r} has role='decode' — failing closed, "
                    "nothing moved")
            if phase == "decoding" and de.engine.role == "prefill":
                raise RoutingError(
                    f"request {rid} is decoding but {dst!r} has "
                    "role='prefill' (it would be handed off again) — "
                    "failing closed, nothing moved")
            need = needed_capacity(req, phase, pos, se.engine.s_max)
            if need > de.engine.s_max:
                raise MigrationError(
                    f"request {rid} needs sequence capacity {need} but "
                    f"{dst!r} has s_max={de.engine.s_max} — failing "
                    "closed, nothing moved")
            if phase == "decoding":
                decode_needs.append(need)
        # token-granular admission: lanes AND KV memory (a paged pool
        # counts the batch's page reservations; a slot pool only lanes)
        if not de.engine.fits_inflight(decode_needs):
            raise MigrationError(
                f"batch needs {len(decode_needs)} decode lanes / "
                f"{sum(decode_needs)} KV tokens but {dst!r} has "
                f"{de.engine.free_slots} lanes / {de.engine.free_tokens} "
                "tokens free — failing closed, nothing moved")
        # ---- transfer
        # under the step lock: KV surgery must never interleave with a
        # decode step writing the same pools from the serving thread
        with self._step_lock:
            # compile-ahead: the pool-surgery ops must already be warm
            # when the per-request pause clock starts (nothing compiles
            # inside it)
            se.engine.warm_migration()
            de.engine.warm_migration()
            # device barrier: pending decode work on either side must
            # retire before export — waiting for it is drain cost
            # (counted by the caller's blocking window), not per-request
            # transfer cost
            se.engine.drain()
            de.engine.drain()
            # one batched device_put for the whole pair (per-request
            # pauses amortize the shared transfer; see migrate_many)
            return migrate_many(se.engine, de.engine, rids, src=src,
                                dst=dst, reason=reason)

    def _relocate_for_retirement(self, entry: _EngineEntry
                                 ) -> List[MigrationRecord]:
        """Move a retiring engine's in-flight work onto eligible peers,
        batched per destination (one warm + drain barrier per engine
        pair, not per request). A resident request resumes decode, so it
        needs a RUNNING peer with a free slot and enough sequence
        capacity — a paused one would strand it; a queued request only
        needs routing (running peers preferred, router parity). Requests
        no peer may legally hold (the route-constraint merge semantics of
        `merge_restrictions` keep conflicting placements unroutable) stay
        behind and drain — fail-closed beats mis-placement."""
        eng = entry.engine
        work = [(r, "decoding", int(eng.slot_pos[i]))
                for i, r in enumerate(eng.slot_req) if r is not None] \
            + [(r, "queued", len(r.prompt)) for r in eng.queue]
        free = {e.name: e.engine.free_slots for e in self._entries.values()}
        # token-granular capacity alongside lanes: a paged destination
        # admits by pages, so short requests pack in where whole slots
        # would not fit (imports may spend the watermark — mirror
        # `fits_inflight` by budgeting the full free page list)
        free_tok = {e.name: (e.engine.pool.free_pages * e.engine.page_size
                             if e.engine.paged else e.engine.free_tokens)
                    for e in self._entries.values()}
        extra = {e.name: 0 for e in self._entries.values()}
        assignments: Dict[str, List[int]] = {}
        for req, phase, pos in work:
            required = self.required_for(dict(req.labels))
            need = needed_capacity(req, phase, pos, eng.s_max)
            cands = [e for e in self._entries.values()
                     if e.name != entry.name
                     and self._entry_eligible(e, req.labels, required)
                     and need <= e.engine.s_max]
            if phase == "decoding":
                # role discipline mirrors `_migrate_locked`'s preflight:
                # a decoding request never relocates onto a prefill-role
                # engine (it would only be handed off again)
                cands = [e for e in cands
                         if e.engine.role != "prefill"
                         and not e.engine.paused and free[e.name] > 0
                         and free_tok[e.name]
                         >= e.engine.admission_tokens(need)]
            else:
                # a queued request still needs prefill — never a
                # decode-role destination
                cands = [e for e in cands if e.engine.role != "decode"]
                running = [e for e in cands if not e.engine.paused]
                cands = running or cands
            if not cands:
                continue                   # stays behind; drains in place
            dst = min(cands, key=lambda e: e.engine.load + extra[e.name])
            assignments.setdefault(dst.name, []).append(req.rid)
            extra[dst.name] += 1
            if phase == "decoding":
                free[dst.name] -= 1
                free_tok[dst.name] -= dst.engine.admission_tokens(need)
        records: List[MigrationRecord] = []
        for dst, rids in assignments.items():
            try:
                records.extend(self.migrate_requests(entry.name, dst,
                                                     rids=rids))
            except (MigrationError, RoutingError):
                continue                   # kept/restored on source; drains
        return records

    def retire_engine(self, name: str, mode: str = "drain"
                      ) -> DowntimeReport:
        """Begin retirement: the engine stops receiving new requests
        immediately (the router skips draining engines) and is
        deregistered once empty; its completions are retained for
        cluster-level metrics.

        Modes:
          * ``"drain"`` (default): the engine serves out its queue and
            resident slots first — retirement latency is bounded by the
            longest in-flight decode, but nothing ever blocks
            (``downtime_s`` is honestly 0).
          * ``"migrate"``: in-flight work is live-migrated to eligible
            peers (`migrate_requests` semantics — fail-closed on route
            constraints) and the engine is reaped IMMEDIATELY when
            everything moved. ``downtime_s`` reports the measured
            relocation window; per-request pauses are in
            ``report.migrations``. Requests no peer can legally hold
            stay behind and drain in place (the engine then retires the
            drain way for them).

        A paused engine is resumed so it can actually drain.

        Returns:
            A `DowntimeReport` with ``event="retire"``; ``metrics_after``
            finalizes at reap time with the drain-window traffic (empty if
            the engine was already idle).

        Raises:
            KeyError: if ``name`` is not registered.
            ValueError: if the engine is already draining, or ``mode`` is
                unknown.
        """
        if mode not in ("drain", "migrate"):
            raise ValueError(f"unknown retirement mode {mode!r} "
                             "(expected 'drain' or 'migrate')")
        with self._lock:
            return self._retire_locked(name, mode)

    def _retire_locked(self, name: str, mode: str) -> DowntimeReport:
        entry = self._entries[name]
        if entry.draining:
            raise ValueError(f"engine {name!r} is already draining")
        if entry.pending_ticket is not None:
            # a retiring engine never swaps: the pending background
            # PREPARE is cancelled and its executables never installed
            entry.pending_ticket.cancel()
            entry.pending_ticket = None
        if entry.engine.paused:
            entry.engine.resume()
        self._finalize_pending(entry)
        metrics_before = compute_metrics(
            [r for r in entry.engine.done if r.t_done >= entry.swap_t])
        entry.draining = True              # router skips it from here on
        downtime_s = 0.0
        records: List[MigrationRecord] = []
        if mode == "migrate":
            # PREPARE-equivalent: warm the pool-surgery ops on the source
            # and every peer that could actually receive one of its
            # in-flight requests, BEFORE the blocking window
            entry.engine.warm_migration()
            inflight = [r for r in entry.engine.slot_req
                        if r is not None] + list(entry.engine.queue)
            for e in self._entries.values():
                if e is entry or e.draining:
                    continue
                if any(self._entry_eligible(
                        e, r.labels, self.required_for(dict(r.labels)))
                       for r in inflight):
                    e.engine.warm_migration()
            t0 = time.perf_counter()
            records = self._relocate_for_retirement(entry)
            # honest accounting: when nothing could legally move (zero
            # eligible peers) the retirement falls back to pure draining,
            # which never blocks anyone — downtime is 0, not the cost of
            # discovering there was nowhere to go
            downtime_s = time.perf_counter() - t0 if records else 0.0
        report = DowntimeReport(
            prepare_s=0.0, downtime_s=downtime_s,
            migrate_bytes=sum(m.bytes_moved for m in records),
            metrics_before=metrics_before,
            metrics_after=compute_metrics([]),
            engine=name, event="retire", migrations=tuple(records))
        entry.pending_report = report
        entry.swap_t = time.time()
        self.history.append(report)
        rec = obs_events.RECORDER
        if rec is not None:
            rec.emit("cluster.retire", engine=name, mode=mode,
                     downtime_s=downtime_s, migrated=len(records))
        self._reap_drained()           # emptied/idle engines retire at once
        return report

    def rebalance(self, name: str, plan: ShardingPlan, *,
                  labels: Optional[Dict[str, str]] = None,
                  prefill_lengths: Sequence[int] = ()) -> DowntimeReport:
        """Retarget a live engine at a different workload class: update its
        tenancy labels and swap it onto ``plan`` via `reconfigure`. The
        autoscaler uses this when resizing an idle engine beats a cold
        spawn (no new params to initialize, one swap window).

        Args / Raises: as `reconfigure`; ``labels`` as in `register`.

        Returns:
            The swap's `DowntimeReport` with ``event="rebalance"``.
        """
        entry = self._entries[name]
        if labels:
            entry.engine.labels.update(labels)
        report = self.reconfigure(name, plan, prefill_lengths=prefill_lengths)
        report.event = "rebalance"
        value = entry.labels.get(self.ROUTE_KEY)
        if value:
            self.redistribute_queued(value)
        return report

    def redistribute_queued(self, value: str) -> int:
        """Re-route queued (not yet prefilled) requests labeled
        ``data-type=value`` across the currently eligible engines, so new
        capacity immediately shares the backlog instead of only absorbing
        future arrivals. Requests already resident in decode slots stay
        where they are (their KV state lives on that engine).

        Submission timestamps are preserved — a moved request's TTFT still
        measures from its original submit. A request that no engine can
        serve anymore stays on its current engine (never dropped).

        Returns:
            The number of requests moved through the router.
        """
        # both locks: queue surgery must not race request threads'
        # submits (_lock) nor a decode step admitting from the same
        # queues on the serving thread (_step_lock)
        with self._lock, self._step_lock:
            moved: List[Tuple[_EngineEntry, Request]] = []
            for e in self._entries.values():
                keep: List[Request] = []
                for r in e.engine.queue:
                    if r.labels.get(self.ROUTE_KEY, "*") == value:
                        moved.append((e, r))
                    else:
                        keep.append(r)
                e.engine.queue[:] = keep
            for src, r in moved:
                try:
                    name = self.route(r)
                except RoutingError:
                    self.rejected.pop()  # a requeue miss is no rejection
                    src.engine.queue.append(r)
                    continue
                dest = self._entries[name].engine
                # the destination must learn the prompt length, or a
                # later default-lengths reconfigure would omit it from
                # the AOT set and JIT prefill on the serving path
                dest.note_prompt_length(len(r.prompt))
                dest.queue.append(r)
            return len(moved)

    def pending_reports(self) -> List[str]:
        """Engine names whose latest `DowntimeReport` still awaits its
        post-event traffic window (empty list == all reports finalized)."""
        with self._lock:
            return [n for n, e in self._entries.items()
                    if e.pending_report is not None]

    def _finalize_pending(self, entry: _EngineEntry) -> None:
        """Close an entry's pending report with its honest final window
        (possibly empty) before a new scale event overwrites it."""
        if entry.pending_report is not None:
            entry.pending_report.metrics_after = compute_metrics(
                [r for r in entry.engine.done if r.t_done >= entry.swap_t])
            entry.pending_report = None

    def _reap_drained(self) -> None:
        """Deregister draining engines that have gone empty, finalizing
        their retire reports with the drain-window traffic and retaining
        their completions for cluster metrics."""
        for name in [n for n, e in self._entries.items() if e.draining]:
            entry = self._entries[name]
            eng = entry.engine
            if eng.queue or any(r is not None for r in eng.slot_req):
                continue               # still draining
            self._finalize_pending(entry)
            # consume the retiring engine's tail into the per-label folds
            # BEFORE its entry (and metrics_seen cursor) disappears
            self._fold_completions_locked()
            self._retired_done.extend(eng.done)
            if len(self._retired_done) > self.RETIRED_DONE_CAP:
                del self._retired_done[:-self.RETIRED_DONE_CAP]
            del self._entries[name]

    def _refresh_reports(self) -> None:
        """Re-finalize pending reports once post-swap completions exist, so
        metrics_after reflects traffic served *under the new plan*. Runs
        when `run()` drains (not per step, so the window isn't cut short
        while requests are still in flight)."""
        for e in self._entries.values():
            if e.pending_report is None:
                continue
            window = [r for r in e.engine.done if r.t_done >= e.swap_t]
            if window:
                e.pending_report.metrics_after = compute_metrics(window)
                e.pending_report = None

    # ------------------------------------------------------------------
    # intent application (called by Orchestrator.submit(apply_to=...))
    # ------------------------------------------------------------------
    def apply_policy(self, policy, components: Sequence = (), *,
                     async_prepare: bool = False
                     ) -> Dict[str, DowntimeReport]:
        """Program the cluster from a validated `CompiledPolicy`:

        1. translate the policy's plan updates into per-label route
           constraints (`flows/<data-type>` entries and component plans
           merge on the component's data-type label);
        2. reconfigure every engine that could serve a constrained label
           but whose current plan does not satisfy the constraint.

        With ``async_prepare`` the swaps ride the concurrent-PREPARE path
        (`reconfigure_async`): serving continues while the worker
        compiles and each swap commits at the next step boundary.

        Returns {engine name: DowntimeReport} for engines that were
        swapped — or {engine name: PrepareTicket} when ``async_prepare``
        (each ticket's ``report`` finalizes on commit).
        """
        by_name = {c.name: c for c in components}
        merged: Dict[str, Dict[str, set]] = {}
        for key, p in policy.plan_updates.items():
            if key.startswith("flows/"):
                value = key[len("flows/"):]
            else:
                comp = by_name.get(key)
                value = comp.labels.get(self.ROUTE_KEY) if comp else None
            if not value or value == "*":
                continue
            m = merged.setdefault(value, {"axes": set(), "pins": set()})
            m["axes"].update(p.forbidden_collective_axes)
            if p.device_constraints:
                m["pins"].add(tuple(p.device_constraints))

        for value, m in merged.items():
            # a single consistent pin becomes a placement requirement;
            # conflicting pins (components load-balanced over several pods)
            # degrade to confinement on the pinned axes — still fail-closed:
            # an engine must be pinned *somewhere* on those axes to qualify
            pins = next(iter(m["pins"])) if len(m["pins"]) == 1 else ()
            axes = set(m["axes"])
            if len(m["pins"]) > 1:
                axes |= {axis for pin in m["pins"] for axis, _ in pin}
            if not pins and not axes:
                continue      # nothing enforceable — never install a
                              # vacuous constraint every engine satisfies
            self.set_route_constraint(value, ShardingPlan(
                device_constraints=pins,
                forbidden_collective_axes=tuple(sorted(axes))))

        # one swap per engine: merge ALL unsatisfied constraints into a
        # single target plan (per-constraint swaps would let a later pin
        # overwrite an earlier one and churn the engine through repeated
        # migrations); `merge_restrictions` degrades conflicting pins to
        # axis confinement, which stays fail-closed at routing time
        reports: Dict[str, DowntimeReport] = {}
        for e in list(self._entries.values()):
            if e.draining:
                continue               # a retiring engine never swaps
            unsatisfied = [
                required for value, required in self._routes.items()
                if e.serves({self.ROUTE_KEY: value})
                and not plan_satisfies(e.plan, required)]
            if not unsatisfied:
                continue
            new_plan = merge_restrictions(e.plan, *unsatisfied)
            if async_prepare:
                reports[e.name] = self.reconfigure_async(e.name, new_plan)
            else:
                reports[e.name] = self.reconfigure(e.name, new_plan)
        return reports
